//! No-op stand-in for the `xla` crate (xla_extension 0.5.1 bindings).
//!
//! The real bindings link against a prebuilt `libxla_extension` that is
//! not available in the offline build environment. This stub keeps the
//! `pjrt` feature of `batchrep` *compiling* — the whole API surface
//! `runtime::Engine` uses exists with the right shapes — while every
//! runtime entry point returns [`Error`]. The first call a PJRT engine
//! makes ([`PjRtClient::cpu`]) fails, so no stubbed computation is ever
//! silently wrong: you either get the real backend or an error, never a
//! fake number.
//!
//! To run against real XLA, replace this path dependency with the
//! actual `xla` crate (the package name matches); no source change in
//! `batchrep` is needed.

use std::fmt;

/// The single error every stub entry point returns.
#[derive(Debug, Clone)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {} (this build vendors the no-op xla crate; link the real xla_extension bindings to execute PJRT artifacts)", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &'static str) -> Result<T, Error> {
    Err(Error(what))
}

/// Parsed HLO module text (stub: retains nothing).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Always errors: the stub cannot parse HLO text.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation handle (stub: empty).
pub struct XlaComputation(());

impl XlaComputation {
    /// Infallible wrap, matching the real signature; the computation is
    /// inert and compiling it errors.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Host-side tensor value (stub: holds no data).
pub struct Literal(());

impl Literal {
    /// Infallible construction, matching the real signature. The value
    /// is inert — it can only flow into calls that error.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Always errors.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    /// Always errors.
    pub fn shape(&self) -> Result<Shape, Error> {
        unavailable("Literal::shape")
    }

    /// Always errors.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    /// Always errors.
    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        unavailable("Literal::to_tuple2")
    }

    /// Always errors.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    /// Always errors.
    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        unavailable("Literal::get_first_element")
    }
}

/// Array-vs-tuple result shape.
pub enum Shape {
    /// Tupled entry root.
    Tuple(Vec<Shape>),
    /// Bare array root (the stub never distinguishes element types).
    Array,
}

/// Device-side result buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Always errors.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub: inert).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Always errors.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the first call every
/// engine makes, so construction failing here guarantees no stub value
/// ever reaches a caller.
pub struct PjRtClient(());

impl PjRtClient {
    /// Always errors: no PJRT runtime is linked.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    /// Always errors.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtLoadedExecutable compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_not_fakes() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[1, 2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.get_first_element::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("xla stub"), "{msg}");
    }
}
