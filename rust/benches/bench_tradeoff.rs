//! E4/E5 bench — closed-form optimizer performance: B*(Δµ) sweeps and
//! the inclusion–exclusion unbalanced analysis.
use batchrep::analysis;
use batchrep::assignment::skewed;
use batchrep::benchkit::{black_box, Suite};
use batchrep::dist::ServiceSpec;

fn main() {
    let mut suite = Suite::new("bench_tradeoff — analysis closed forms");
    let spec = ServiceSpec::shifted_exp(1.0, 0.2);
    suite.bench("spectrum N=24 (8 divisors)", 8, || {
        black_box(analysis::spectrum(24, &spec).unwrap());
    });
    suite.bench("optimum_b N=240", 1, || {
        black_box(analysis::optimum_b(240, &spec).unwrap());
    });
    suite.bench("bstar_sweep 10 points", 10, || {
        black_box(
            analysis::bstar_sweep(24, 1.0, &[0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0])
                .unwrap(),
        );
    });
    let a12 = skewed(12, 6).unwrap();
    suite.bench("assignment_stats inclusion-exclusion B=6", 1, || {
        black_box(analysis::assignment_stats(&a12, &spec, 12).unwrap());
    });
    let a20 = skewed(20, 10).unwrap();
    suite.bench("assignment_stats inclusion-exclusion B=10", 1, || {
        black_box(analysis::assignment_stats(&a20, &spec, 20).unwrap());
    });
    suite.finish();
}
