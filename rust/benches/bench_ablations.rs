//! E8 bench — regenerate the ablation tables (batch model, cancellation
//! cost, speculative vs upfront, heterogeneous cluster).
use batchrep::benchkit::Suite;
use batchrep::experiments::{ablations, ExpContext};

fn main() {
    let fast = std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let ctx = ExpContext {
        out_dir: "results/bench_ablations".into(),
        trials: if fast { 2_000 } else { 50_000 },
        seed: 42,
    };
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    let mut suite = Suite::new("bench_ablations — E8 tables");
    suite.bench("ablation tables (4)", ctx.trials, || {
        ablations::run(&ctx).unwrap();
    });
    suite.finish();
}
