//! E3 bench — Theorem 2 spectrum (Exp service) regeneration.
use batchrep::benchkit::Suite;
use batchrep::experiments::{spectrum, ExpContext};

fn main() {
    let fast = std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let ctx = ExpContext {
        out_dir: "results/bench_spectrum".into(),
        trials: if fast { 5_000 } else { 100_000 },
        seed: 42,
    };
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    let mut suite = Suite::new("bench_diversity_exp — Theorems 2/3/4 tables");
    suite.bench("spectrum tables (E3+E4+E5)", ctx.trials * 8, || {
        spectrum::run(&ctx).unwrap();
    });
    suite.finish();
}
