//! PJRT runtime latency/throughput: artifact compile once, then
//! per-execution cost of the grad/mapsum jobs at every batch size —
//! the compute-side numbers behind the live-system overhead column.
//! Skips (cleanly) when artifacts are missing.
use batchrep::benchkit::{black_box, Suite};
use batchrep::runtime::{default_artifact_dir, Engine};
use batchrep::util::rng::Rng;

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP bench_runtime: no artifacts (run `make artifacts`)");
        return;
    }
    let mut engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            // Built without the `pjrt` feature (or artifacts unusable).
            eprintln!("SKIP bench_runtime: {e}");
            return;
        }
    };
    let mut suite = Suite::new("bench_runtime — PJRT execution");
    let mut rng = Rng::new(1);
    let dim = 64usize;
    let w: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    for rows in [512usize, 1024, 2048, 4096] {
        if engine.manifest().find("grad", rows, dim).is_err() {
            continue;
        }
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..rows).map(|_| rng.normal() as f32).collect();
        engine.prepare("grad", rows, dim).unwrap();
        suite.bench(&format!("grad rows={rows} d={dim}"), rows as u64, || {
            black_box(engine.grad(rows, dim, &x, &y, &w).unwrap());
        });
    }
    let rows = 1024usize;
    if engine.manifest().find("mapsum", rows, dim).is_ok() {
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.normal() as f32).collect();
        let a = vec![0.1f32; dim];
        let b = vec![0.2f32; dim];
        engine.prepare("mapsum", rows, dim).unwrap();
        suite.bench(&format!("mapsum rows={rows} d={dim}"), rows as u64, || {
            black_box(engine.mapsum(rows, dim, &x, &a, &b).unwrap());
        });
    }
    suite.finish();
}
