//! Live coordinator overhead: wall-clock of a full System1 round with
//! zero injected straggle (mock backend) — isolates dispatch, channel,
//! cancellation, and aggregation costs. §Perf target: ≤ 50 µs/task.
use batchrep::assignment::Policy;
use batchrep::benchkit::Suite;
use batchrep::config::SystemConfig;
use batchrep::coordinator::{Backend, Coordinator};
use batchrep::dist::ServiceSpec;
use batchrep::worker::JobSpec;
use std::sync::Arc;

fn main() {
    let mut suite = Suite::new("bench_coordinator — dispatch overhead (mock, zero delay)");
    for (n, b) in [(4usize, 2usize), (8, 4), (16, 4), (32, 8)] {
        let cfg = SystemConfig {
            n_workers: n,
            n_batches: b,
            policy: Policy::BalancedDisjoint,
            service: ServiceSpec::Deterministic { value: 0.0 },
            time_scale: 1.0,
            n_samples: n * 8,
            dim: 4,
            seed: 1,
            ..SystemConfig::default()
        };
        let mut coord = Coordinator::new(cfg, Backend::Mock).unwrap();
        let w = Arc::new(vec![0.0f32; 4]);
        suite.bench(&format!("round N={n} B={b}"), n as u64, || {
            coord
                .run_round(JobSpec::Grad { w: w.clone() })
                .unwrap();
        });
        coord.shutdown();
    }
    suite.finish();
}
