//! Monte-Carlo sampler throughput: the retained scalar reference vs the
//! block kernel vs auto-threaded sharding, on the fixed fig2-scale
//! reference scenario. `BATCHREP_BENCH_FAST=1` shrinks it for CI.
use batchrep::benchkit::{black_box, mc, Suite};
use batchrep::des::montecarlo;
use batchrep::evaluator::MonteCarloEvaluator;

fn main() {
    let fast = std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let trials: u64 = if fast { 5_000 } else { 50_000 };
    let scn = mc::reference_scenario();
    let threads = MonteCarloEvaluator::auto_threads();
    let mut suite = Suite::new("bench_mc — completion-time sampler throughput");
    suite.bench("scalar reference", trials, || {
        black_box(montecarlo::run_trials_reference(&scn, trials, 1));
    });
    suite.bench("block kernel (1 thread)", trials, || {
        black_box(montecarlo::run_trials(&scn, trials, 1));
    });
    suite.bench(&format!("block kernel ({threads} threads)"), trials, || {
        black_box(montecarlo::run_trials_parallel(&scn, trials, 1, threads));
    });
    suite.finish();
}
