//! DES substrate performance: Monte-Carlo sampler and event engine
//! throughput — the §Perf L3 targets (DESIGN.md §6).
//!
//! The engine rows compare the retained heap + scalar-draw reference
//! against the flat-queue + block-kernel engine and its parallel
//! sharding; the measured trajectory artifact is `BENCH_des.json`
//! (`batchrep bench-des`).
use batchrep::benchkit::{black_box, Suite};
use batchrep::des::engine::{
    simulate_many, simulate_many_parallel, simulate_many_reference, simulate_one_with,
    EngineConfig, Redundancy, Workspace,
};
use batchrep::des::{montecarlo, Scenario};
use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::util::rng::Rng;

fn main() {
    let mut suite = Suite::new("bench_des — simulator hot paths");
    let spec = ServiceSpec::shifted_exp(1.0, 0.2);

    for (n, b) in [(24usize, 6usize), (240, 24), (1024, 128)] {
        let scn =
            Scenario::paper_balanced(n, b, BatchService::paper(spec.clone())).unwrap();
        let mut rng = Rng::new(1);
        suite.bench(&format!("mc trial N={n} B={b} (disjoint)"), n as u64, || {
            black_box(montecarlo::sample_completion(&scn, &mut rng));
        });
    }

    let overlap = {
        let layout = batchrep::batching::overlapping(64, 64, 8).unwrap();
        let assignment = batchrep::assignment::balanced(64, 64).unwrap();
        Scenario::new(layout, assignment, BatchService::paper(spec.clone())).unwrap()
    };
    let mut rng = Rng::new(2);
    suite.bench("mc trial N=64 overlapping windows", 64, || {
        black_box(montecarlo::sample_completion(&overlap, &mut rng));
    });

    let scn = Scenario::paper_balanced(24, 6, BatchService::paper(spec.clone())).unwrap();
    let cfg = EngineConfig::default();
    let mut rng3 = Rng::new(3);
    let mut ws = Workspace::default();
    suite.bench("engine trial N=24 B=6 upfront+cancel", 24, || {
        black_box(simulate_one_with(&scn, &cfg, &mut rng3, &mut ws));
    });
    let spec_cfg = EngineConfig {
        redundancy: Redundancy::Speculative { deadline_factor: 1.5 },
        ..EngineConfig::default()
    };
    let mut rng4 = Rng::new(4);
    let mut ws4 = Workspace::default();
    suite.bench("engine trial N=24 B=6 speculative", 24, || {
        black_box(simulate_one_with(&scn, &spec_cfg, &mut rng4, &mut ws4));
    });

    // Engine trajectory: retained reference vs flat-queue + block kernel
    // vs 4-way deterministic sharding (the bench-des harness paths).
    suite.bench("engine 10k trials reference (heap+scalar)", 10_000, || {
        black_box(simulate_many_reference(&scn, &cfg, 10_000, 7));
    });
    suite.bench("engine 10k trials flat+block single", 10_000, || {
        black_box(simulate_many(&scn, &cfg, 10_000, 7));
    });
    suite.bench("engine 10k trials flat+block x4", 10_000, || {
        black_box(simulate_many_parallel(&scn, &cfg, 10_000, 7, 4));
    });
    suite.bench("engine 10k trials speculative flat+block", 10_000, || {
        black_box(simulate_many(&scn, &spec_cfg, 10_000, 7));
    });

    // Parallel Monte-Carlo scaling (4 threads vs 1).
    let big = Scenario::paper_balanced(24, 6, BatchService::paper(spec.clone())).unwrap();
    suite.bench("run_trials 100k sequential", 100_000, || {
        black_box(montecarlo::run_trials(&big, 100_000, 7));
    });
    suite.bench("run_trials 100k parallel x4", 100_000, || {
        black_box(montecarlo::run_trials_parallel(&big, 100_000, 7, 4));
    });

    // Raw substrate: distribution sampling.
    let mut rng5 = Rng::new(5);
    suite.bench("sexp sample", 1, || {
        black_box(spec.sample(&mut rng5));
    });
    suite.finish();
}
