//! E1 bench — regenerates paper Fig. 2 (E[T] vs B, SExp, per-Δµ curves)
//! and times the sweep. `BATCHREP_BENCH_FAST=1` shrinks it for CI.
use batchrep::benchkit::Suite;
use batchrep::experiments::{fig2, ExpContext};

fn main() {
    let fast = std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let ctx = ExpContext {
        out_dir: "results/bench_fig2".into(),
        trials: if fast { 5_000 } else { 100_000 },
        seed: 42,
    };
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    let mut suite = Suite::new("bench_fig2 — Fig. 2 regeneration");
    suite.bench("fig2 full sweep", ctx.trials * 5 * 8, || {
        fig2::run(&ctx).unwrap();
    });
    suite.finish();
}
