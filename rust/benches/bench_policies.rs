//! E2 bench — Theorem 1 policy table regeneration + per-policy sampling
//! throughput.
use batchrep::benchkit::{black_box, Suite};
use batchrep::des::{montecarlo, Scenario};
use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::experiments::{policies, ExpContext};
use batchrep::util::rng::Rng;

fn main() {
    let fast = std::env::var("BATCHREP_BENCH_FAST").is_ok();
    let ctx = ExpContext {
        out_dir: "results/bench_policies".into(),
        trials: if fast { 5_000 } else { 50_000 },
        seed: 42,
    };
    std::fs::create_dir_all(&ctx.out_dir).unwrap();
    let mut suite = Suite::new("bench_policies — Theorem 1 table");
    suite.bench("policy table (all dists x policies)", ctx.trials * 24, || {
        policies::run(&ctx).unwrap();
    });

    // Micro: single-trial sampling cost per policy class.
    let spec = ServiceSpec::shifted_exp(1.0, 0.2);
    for (name, b, overlap) in
        [("disjoint B=4", 4usize, false), ("overlapping B=12", 12, true)]
    {
        let scn = if overlap {
            let layout = batchrep::batching::overlapping(12, 12, 3).unwrap();
            let assignment = batchrep::assignment::balanced(12, 12).unwrap();
            Scenario::new(layout, assignment, BatchService::paper(spec.clone())).unwrap()
        } else {
            Scenario::paper_balanced(12, b, BatchService::paper(spec.clone())).unwrap()
        };
        let mut rng = Rng::new(7);
        suite.bench(&format!("sample_completion {name}"), 1, || {
            black_box(montecarlo::sample_completion(&scn, &mut rng));
        });
    }
    suite.finish();
}
