//! The lint gate as an integration test: the shipped tree must produce
//! zero non-baselined findings, and the analyzer's own artifact and
//! baseline plumbing must round-trip through the public surface exactly
//! the way `ci.sh` drives it.

use batchrep::lint::{self, baseline::Baseline, LintConfig};

/// The acceptance bar from the issue: `batchrep lint` exits zero on the
/// shipped tree. Runs the identical configuration the CLI defaults to
/// (scan `src/`, absorb `lint/baseline.json`) and renders any findings
/// so a regression names its exact file:line:col and fix hint.
#[test]
fn shipped_tree_is_lint_clean() {
    let report = lint::run(&LintConfig::default()).expect("lint scan runs");
    assert!(report.files_scanned > 30, "scan saw {} files — wrong root?", report.files_scanned);
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.findings.is_empty(),
        "lint found {} violation(s) in the shipped tree:\n{}",
        report.findings.len(),
        rendered.join("\n")
    );
}

/// The checked-in baseline stays empty: new violations must be fixed or
/// carry a reasoned inline suppression, not be grandfathered silently.
#[test]
fn checked_in_baseline_is_empty() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("lint/baseline.json");
    let bl = Baseline::load(&path).expect("baseline parses");
    assert!(bl.entries.is_empty(), "baseline has {} grandfathered entr(ies)", bl.entries.len());
}

/// The LINT.json artifact written by `--json` validates against its own
/// schema — the same check ci.sh applies to the artifact it keeps.
#[test]
fn artifact_round_trips_schema_validation() {
    let report = lint::run(&LintConfig::default()).expect("lint scan runs");
    let j = lint::report_json(&report);
    lint::validate_json(&j).expect("artifact validates");
    let reparsed = batchrep::util::json::Json::parse(&j.to_string()).expect("reparses");
    lint::validate_json(&reparsed).expect("serialized artifact validates");
}

/// Baseline round-trip over real findings: a seeded violation is
/// absorbed by a baseline built from it, and the same baseline does NOT
/// absorb a second instance of the same violation class.
#[test]
fn baseline_absorbs_exactly_the_recorded_count() {
    let fixture =
        "fn rank(xs: &[f64]) -> f64 {\n    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)\n}\n";
    let files = vec![lint::SourceFile::parse("fix.rs", fixture)];
    let found = lint::apply_suppressions(&files, lint::analyze(&files));
    assert!(!found.is_empty(), "fixture should violate D1");
    let bl = Baseline::from_findings(&found);
    let (kept, absorbed) = bl.apply(found.clone());
    assert!(kept.is_empty());
    assert_eq!(absorbed, found.len());

    // Two instances against a one-instance baseline: one leaks through.
    let mut doubled = found.clone();
    doubled.extend(found.iter().cloned().map(|mut f| {
        f.line += 100;
        f
    }));
    let (kept, absorbed) = bl.apply(doubled);
    assert_eq!(absorbed, found.len());
    assert_eq!(kept.len(), found.len(), "the extra instance must not be absorbed");
}
