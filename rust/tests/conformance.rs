//! Integration tests of the conformance subsystem: the thread-count
//! bit-determinism property of the sharded backends, and a small
//! end-to-end matrix run including the live k-of-B cells the
//! acceptance criteria name.

use batchrep::conformance::{self, MatrixOptions};
use batchrep::des::engine::Redundancy;
use batchrep::evaluator::{DesEvaluator, Evaluator, MonteCarloEvaluator};
use batchrep::testkit;

#[test]
fn prop_mc_and_des_are_bit_deterministic_across_thread_counts() {
    // The satellite property: for a fixed seed, `MonteCarloEvaluator`
    // and `DesEvaluator` produce *identical* CompletionStats across
    // threads ∈ {1, 2, 4, 8} on generated scenarios — the logical-shard
    // plan makes the thread count a pure wall-clock knob.
    testkit::check("conformance-thread-determinism", 25, |g| {
        let case = conformance::gen_case(g);
        let scn = &case.scenario;
        let assert_same = |a: &batchrep::evaluator::CompletionStats,
                           b: &batchrep::evaluator::CompletionStats,
                           what: &str| {
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{what} mean");
            assert_eq!(a.variance.to_bits(), b.variance.to_bits(), "{what} variance");
            assert_eq!(a.sem.to_bits(), b.sem.to_bits(), "{what} sem");
            assert_eq!(a.quantiles, b.quantiles, "{what} quantiles");
            assert_eq!(a.samples, b.samples, "{what} samples");
            match (&a.cost, &b.cost) {
                (None, None) => {}
                (Some(ca), Some(cb)) => {
                    assert_eq!(ca.busy.to_bits(), cb.busy.to_bits(), "{what} busy");
                    assert_eq!(ca.wasted.to_bits(), cb.wasted.to_bits(), "{what} wasted");
                }
                _ => panic!("{what}: cost presence differs across thread counts"),
            }
        };
        if scn.redundancy == Redundancy::Upfront {
            let base = MonteCarloEvaluator { trials: 4_000, threads: 1 }
                .evaluate(scn)
                .unwrap();
            for threads in [2usize, 4, 8] {
                let run = MonteCarloEvaluator { trials: 4_000, threads }
                    .evaluate(scn)
                    .unwrap();
                assert_same(&base, &run, &format!("mc threads={threads}"));
            }
        }
        let des = |threads: usize| {
            DesEvaluator {
                trials: 2_000,
                threads,
                fail_prob: case.fail_prob,
                ..DesEvaluator::default()
            }
            .evaluate(scn)
            .unwrap()
        };
        let base = des(1);
        for threads in [2usize, 4, 8] {
            assert_same(&base, &des(threads), &format!("des threads={threads}"));
        }
    });
}

#[test]
fn matrix_with_live_cells_covers_the_required_corners() {
    // End-to-end: anchors + a few generated scenarios, live cells on.
    // The report must show at least one heterogeneous-speed analytic
    // cell and at least one live k-of-B DES↔Live cell — the two corners
    // the acceptance criteria name explicitly.
    let opts = MatrixOptions {
        scenarios: 5,
        mc_trials: 8_000,
        des_trials: 4_000,
        live_rounds: 40,
        threads: 2,
        include_live: true,
        seed: Some(11),
        z: 5.5,
        rel_floor: 0.01,
        live_floor: 0.15,
    };
    let report = conformance::run_matrix(&opts).unwrap();
    assert!(report.scenarios >= 16, "{report:?}");
    assert!(report.hetero_analytic_cells >= 2, "{report:?}");
    assert!(report.des_live >= 3, "live anchors must run: {report:?}");
    assert!(report.live_k_of_b_cells >= 1, "{report:?}");
    assert!(report.worst_gap_over_tol <= 1.0, "{report:?}");
}

#[test]
fn matrix_failure_reports_a_replay_seed() {
    // Sabotage: an impossibly tight tolerance must make some cell fail,
    // and the error must carry the deterministic replay instructions
    // (anchor context or a BATCHREP_PROP_SEED line).
    let opts = MatrixOptions {
        scenarios: 3,
        mc_trials: 2_000,
        des_trials: 1_000,
        live_rounds: 1,
        threads: 2,
        include_live: false,
        seed: Some(3),
        z: 0.0,
        rel_floor: 0.0,
        live_floor: 0.0,
    };
    let err = conformance::run_matrix(&opts).unwrap_err().to_string();
    assert!(err.contains("conformance"), "{err}");
    assert!(
        err.contains("scenario:"),
        "failure must describe the offending scenario: {err}"
    );
}
