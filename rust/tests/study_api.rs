//! Public-surface tests of the declarative Study API: spec → plan →
//! shared-pool execution → report/artifact, exercised exactly the way
//! downstream consumers (experiments, CLI, conformance) use it.

use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::evaluator::{cross_check_stats, AnalyticEvaluator, Evaluator};
use batchrep::study::{
    execute, validate_json, BackendSel, BatchAxis, KTarget, SpeedAxis, StudySpec,
};

fn paper_services(delta_mus: &[f64]) -> Vec<BatchService> {
    delta_mus
        .iter()
        .map(|&dm| BatchService::paper(ServiceSpec::shifted_exp(1.0, dm)))
        .collect()
}

#[test]
fn fig2_style_study_cross_checks_and_dedups() {
    // A miniature Fig. 2: a ∆µ axis × feasible batch counts × the
    // {analytic, montecarlo} backend pair, with one ∆µ listed twice —
    // the duplicate service axis entry must not cost a second
    // evaluation, and every grid point must cross-check.
    let spec = StudySpec {
        n_workers: vec![12],
        services: paper_services(&[0.2, 2.0, 0.2]),
        backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo],
        mc_trials: 30_000,
        seed: 17,
        ..StudySpec::base("fig2-mini")
    };
    let plan = spec.compile().unwrap();
    let n_b = batchrep::assignment::feasible_batch_counts(12).len();
    assert_eq!(plan.axis_points(), 3 * n_b * 2);
    assert_eq!(plan.cells.len(), 2 * n_b * 2, "duplicate delta_mu planned once");
    assert_eq!(plan.deduped_points(), n_b * 2);

    let report = execute(&plan, 4, &mut |_, _, _, _| {}).unwrap();
    assert_eq!(report.refused_cells, 0);
    for si in 0..2 {
        for &b in &batchrep::assignment::feasible_batch_counts(12) {
            let an = report
                .stats_where(&|c| {
                    c.service_idx == si && c.b == b && c.backend == BackendSel::Analytic
                })
                .unwrap()
                .clone();
            let mc = report
                .stats_where(&|c| {
                    c.service_idx == si && c.b == b && c.backend == BackendSel::MonteCarlo
                })
                .unwrap()
                .clone();
            cross_check_stats("analytic", "montecarlo", an, mc).unwrap();
        }
    }
    // The duplicate axis entry resolves to the same cell as its twin.
    let first = report.point_where(&|c| c.service_idx == 0 && c.b == 2).unwrap().cell;
    let twin = report.point_where(&|c| c.service_idx == 2 && c.b == 2).unwrap().cell;
    assert_eq!(first, twin);
}

#[test]
fn study_report_identical_across_thread_counts() {
    // Acceptance property, public surface: the whole report — artifact
    // serialization included — is bit-identical for threads ∈ {1,2,4,8}.
    let spec = StudySpec {
        n_workers: vec![8],
        batches: BatchAxis::Explicit(vec![2, 4, 8]),
        services: paper_services(&[0.3]),
        k_targets: vec![KTarget::Full, KTarget::Fraction(0.5)],
        speeds: vec![SpeedAxis::Homogeneous, SpeedAxis::Ramp { lo: 0.7, hi: 1.6 }],
        backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des],
        mc_trials: 8_000,
        des_trials: 2_000,
        seed: 23,
        ..StudySpec::base("threads-property")
    };
    let plan = spec.compile().unwrap();
    let baseline = execute(&plan, 1, &mut |_, _, _, _| {}).unwrap();
    let baseline_json = baseline.to_json().to_string();
    validate_json(&baseline.to_json()).unwrap();
    for threads in [2usize, 4, 8] {
        let run = execute(&plan, threads, &mut |_, _, _, _| {}).unwrap();
        assert_eq!(
            run.to_json().to_string(),
            baseline_json,
            "study artifact diverged at {threads} threads"
        );
        assert_eq!(run.to_csv(), baseline.to_csv());
    }
}

#[test]
fn analytic_cells_match_the_evaluator_and_hetero_cells_refuse_correctly() {
    // Analytic study cells are the evaluator's own numbers; the
    // hetero × partial-aggregation combination is refused with the
    // evaluator's field-naming message rather than silently dropped.
    let spec = StudySpec {
        n_workers: vec![8],
        batches: BatchAxis::Explicit(vec![4]),
        services: paper_services(&[0.2]),
        k_targets: vec![KTarget::Exact(2)],
        speeds: vec![SpeedAxis::Ramp { lo: 0.5, hi: 1.5 }],
        backends: vec![BackendSel::Analytic],
        seed: 3,
        ..StudySpec::base("hetero-k-refusal")
    };
    let plan = spec.compile().unwrap();
    let report = execute(&plan, 2, &mut |_, _, _, _| {}).unwrap();
    assert_eq!(report.refused_cells, 1);
    let err = report.stats_where(&|c| c.backend == BackendSel::Analytic).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("Scenario::worker_speeds"), "{msg}");
    assert!(msg.contains("Scenario::k_of_b"), "{msg}");

    // Same grid without the k axis: served, and equal to the direct
    // evaluator call on the planned scenario.
    let spec = StudySpec { k_targets: vec![KTarget::Full], ..spec };
    let plan = spec.compile().unwrap();
    let report = execute(&plan, 2, &mut |_, _, _, _| {}).unwrap();
    let got = report.stats_where(&|c| c.backend == BackendSel::Analytic).unwrap();
    let want = AnalyticEvaluator.evaluate(&plan.cells[0].scenario).unwrap();
    assert_eq!(got.mean.to_bits(), want.mean.to_bits());
    assert_eq!(got.sem.to_bits(), want.sem.to_bits());
}

#[test]
fn spec_files_round_trip_through_the_planner() {
    // A spec written to disk loads, compiles, and names its study; an
    // unknown file errors with the preset list.
    let dir = std::env::temp_dir().join("batchrep_study_api_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spec.json");
    std::fs::write(
        &path,
        r#"{"name": "disk-spec", "n_workers": [8], "batches": [2, 4],
            "services": ["sexp:1.0,0.2"], "backends": ["analytic", "montecarlo"],
            "mc_trials": 2000, "seed": 9}"#,
    )
    .unwrap();
    let spec = StudySpec::load(path.to_str().unwrap()).unwrap();
    assert_eq!(spec.name, "disk-spec");
    let plan = spec.compile().unwrap();
    assert_eq!(plan.cells.len(), 4);
    let report = execute(&plan, 2, &mut |_, _, _, _| {}).unwrap();
    let out = dir.join("STUDY_disk-spec.json");
    report.write(&out).unwrap();
    batchrep::study::validate_file(&out).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let err = StudySpec::load("no-such-study").unwrap_err().to_string();
    assert!(err.contains("smoke") && err.contains("spec file"), "{err}");
}

#[test]
fn smoke_preset_runs_fast_end_to_end() {
    // The ci.sh gate in miniature: the smoke preset under --fast
    // budgets compiles, executes with dedup, streams every cell, and
    // validates its artifact.
    let spec = StudySpec::preset("smoke").unwrap().fast();
    let plan = spec.compile().unwrap();
    let mut streamed = 0usize;
    let report = execute(&plan, 4, &mut |_, _, done, total| {
        assert!(done <= total);
        streamed += 1;
    })
    .unwrap();
    assert_eq!(streamed, plan.cells.len());
    assert!(report.deduped_points > 0, "smoke preset always exercises dedup");
    assert_eq!(report.refused_cells, 0, "smoke grid is fully in-scope");
    validate_json(&report.to_json()).unwrap();
}
