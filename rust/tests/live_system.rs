//! End-to-end integration: the full live System1 (coordinator + worker
//! threads + PJRT artifacts + injected stragglers + cancellation).
//!
//! Artifact-dependent tests skip with a notice if `make artifacts` has
//! not run. The mock-backend tests always run.

use batchrep::assignment::Policy;
use batchrep::config::SystemConfig;
use batchrep::coordinator::{Backend, Coordinator};
use batchrep::dist::ServiceSpec;

fn have_artifacts() -> bool {
    let ok = batchrep::runtime::default_artifact_dir()
        .join("manifest.json")
        .exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn pjrt_cfg(n: usize, b: usize) -> SystemConfig {
    SystemConfig {
        n_workers: n,
        n_batches: b,
        policy: Policy::BalancedDisjoint,
        service: ServiceSpec::shifted_exp(50.0, 0.02), // fast: ~ms delays
        time_scale: 0.01,
        n_samples: 512,
        dim: 4,
        seed: 77,
        artifacts_dir: batchrep::runtime::default_artifact_dir()
            .to_string_lossy()
            .to_string(),
        ..SystemConfig::default()
    }
}

#[test]
fn pjrt_training_converges_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut coord = Coordinator::new(pjrt_cfg(4, 2), Backend::Pjrt).unwrap();
    let report = coord.run_training(80, 0.5).unwrap();
    coord.shutdown();
    assert_eq!(report.loss_curve.len(), 80);
    assert!(
        report.loss_curve[79] < report.loss_curve[0] / 20.0,
        "loss curve did not drop 20x: first={}, last={}",
        report.loss_curve[0],
        report.loss_curve[79]
    );
    assert!(report.dist_to_w_star < 0.15, "‖w−w*‖ = {}", report.dist_to_w_star);
}

#[test]
fn pjrt_and_mock_backends_agree() {
    if !have_artifacts() {
        return;
    }
    // Same config/seed: the aggregated gradients must match numerically,
    // so both training runs land on (nearly) the same weights.
    let mut a = Coordinator::new(pjrt_cfg(4, 4), Backend::Pjrt).unwrap();
    let ra = a.run_training(20, 0.5).unwrap();
    a.shutdown();
    let mut b = Coordinator::new(pjrt_cfg(4, 4), Backend::Mock).unwrap();
    let rb = b.run_training(20, 0.5).unwrap();
    b.shutdown();
    for (x, y) in ra.final_w.iter().zip(&rb.final_w) {
        assert!((x - y).abs() < 1e-3, "backends diverged: {x} vs {y}");
    }
}

#[test]
fn pjrt_mapsum_round() {
    if !have_artifacts() {
        return;
    }
    let mut coord = Coordinator::new(pjrt_cfg(4, 4), Backend::Pjrt).unwrap();
    let total = coord.run_mapsum(vec![0.1; 4], vec![0.2; 4]).unwrap();
    coord.shutdown();
    assert!(total.is_finite());
    assert!(total.abs() < 512.0, "tanh scores bound the sum by n_samples");
}

#[test]
fn replication_reduces_completion_vs_parallelism_mock() {
    // Behavioral check of the paper's core claim on the *live* system
    // (mock backend: no artifacts needed, pure scheduling semantics):
    // with heavy straggling, B=1 (full diversity) completes rounds
    // faster on average than B=N (full parallelism).
    let rounds = 25;
    let mean_wall = |b: usize| -> f64 {
        let mut cfg = pjrt_cfg(8, b);
        // Heavy-tailed-ish: big randomness relative to shift.
        cfg.service = ServiceSpec::shifted_exp(10.0, 0.01);
        cfg.n_samples = 64;
        let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
        c.run_training(rounds, 0.1).unwrap();
        let m = c.metrics.mean_injected();
        c.shutdown();
        m
    };
    let diversity = mean_wall(1);
    let parallelism = mean_wall(8);
    assert!(
        diversity < parallelism,
        "full diversity {diversity} should beat full parallelism {parallelism} \
         under exponential-dominated service"
    );
}

#[test]
fn cancellation_flag_controls_cancelled_counts() {
    let mut cfg = pjrt_cfg(6, 2);
    cfg.cancellation = false;
    cfg.n_samples = 60;
    let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
    c.run_training(10, 0.1).unwrap();
    let (_, redundant, cancelled) = c.metrics.totals();
    c.shutdown();
    // Without cancellation every non-winning replica still finishes and
    // arrives late: all redundancy shows up as redundant, none cancelled.
    assert_eq!(cancelled, 0);
    assert_eq!(redundant, 10 * (6 - 2));
}
