//! End-to-end integration: the full live System1 (coordinator + worker
//! threads + PJRT artifacts + injected stragglers + cancellation).
//!
//! Artifact-dependent tests skip with a notice if `make artifacts` has
//! not run. The mock-backend tests always run.

use batchrep::assignment::Policy;
use batchrep::config::SystemConfig;
use batchrep::coordinator::{Backend, Coordinator};
use batchrep::dist::ServiceSpec;
use batchrep::fault::{FaultEvent, FaultPlan};
use batchrep::metrics::FaultTotals;

fn have_artifacts() -> bool {
    let ok = batchrep::runtime::default_artifact_dir()
        .join("manifest.json")
        .exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn pjrt_cfg(n: usize, b: usize) -> SystemConfig {
    SystemConfig {
        n_workers: n,
        n_batches: b,
        policy: Policy::BalancedDisjoint,
        service: ServiceSpec::shifted_exp(50.0, 0.02), // fast: ~ms delays
        time_scale: 0.01,
        n_samples: 512,
        dim: 4,
        seed: 77,
        artifacts_dir: batchrep::runtime::default_artifact_dir()
            .to_string_lossy()
            .to_string(),
        ..SystemConfig::default()
    }
}

#[test]
fn pjrt_training_converges_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut coord = Coordinator::new(pjrt_cfg(4, 2), Backend::Pjrt).unwrap();
    let report = coord.run_training(80, 0.5).unwrap();
    coord.shutdown();
    assert_eq!(report.loss_curve.len(), 80);
    assert!(
        report.loss_curve[79] < report.loss_curve[0] / 20.0,
        "loss curve did not drop 20x: first={}, last={}",
        report.loss_curve[0],
        report.loss_curve[79]
    );
    assert!(report.dist_to_w_star < 0.15, "‖w−w*‖ = {}", report.dist_to_w_star);
}

#[test]
fn pjrt_and_mock_backends_agree() {
    if !have_artifacts() {
        return;
    }
    // Same config/seed: the aggregated gradients must match numerically,
    // so both training runs land on (nearly) the same weights.
    let mut a = Coordinator::new(pjrt_cfg(4, 4), Backend::Pjrt).unwrap();
    let ra = a.run_training(20, 0.5).unwrap();
    a.shutdown();
    let mut b = Coordinator::new(pjrt_cfg(4, 4), Backend::Mock).unwrap();
    let rb = b.run_training(20, 0.5).unwrap();
    b.shutdown();
    for (x, y) in ra.final_w.iter().zip(&rb.final_w) {
        assert!((x - y).abs() < 1e-3, "backends diverged: {x} vs {y}");
    }
}

#[test]
fn pjrt_mapsum_round() {
    if !have_artifacts() {
        return;
    }
    let mut coord = Coordinator::new(pjrt_cfg(4, 4), Backend::Pjrt).unwrap();
    let total = coord.run_mapsum(vec![0.1; 4], vec![0.2; 4]).unwrap();
    coord.shutdown();
    assert!(total.is_finite());
    assert!(total.abs() < 512.0, "tanh scores bound the sum by n_samples");
}

#[test]
fn replication_reduces_completion_vs_parallelism_mock() {
    // Behavioral check of the paper's core claim on the *live* system
    // (mock backend: no artifacts needed, pure scheduling semantics):
    // with heavy straggling, B=1 (full diversity) completes rounds
    // faster on average than B=N (full parallelism).
    let rounds = 25;
    let mean_wall = |b: usize| -> f64 {
        let mut cfg = pjrt_cfg(8, b);
        // Heavy-tailed-ish: big randomness relative to shift.
        cfg.service = ServiceSpec::shifted_exp(10.0, 0.01);
        cfg.n_samples = 64;
        let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
        c.run_training(rounds, 0.1).unwrap();
        let m = c.metrics.mean_injected();
        c.shutdown();
        m
    };
    let diversity = mean_wall(1);
    let parallelism = mean_wall(8);
    assert!(
        diversity < parallelism,
        "full diversity {diversity} should beat full parallelism {parallelism} \
         under exponential-dominated service"
    );
}

#[test]
fn cancellation_flag_controls_cancelled_counts() {
    let mut cfg = pjrt_cfg(6, 2);
    cfg.cancellation = false;
    cfg.n_samples = 60;
    let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
    c.run_training(10, 0.1).unwrap();
    let (_, redundant, cancelled) = c.metrics.totals();
    c.shutdown();
    // Without cancellation every non-winning replica still finishes and
    // arrives late: all redundancy shows up as redundant, none cancelled.
    assert_eq!(cancelled, 0);
    assert_eq!(redundant, 10 * (6 - 2));
}

/// Run `rounds` training rounds with a fault plan installed; return the
/// fault totals plus the end-of-run live count and batch count.
fn run_with_plan(
    mut cfg: SystemConfig,
    plan: &FaultPlan,
    rounds: u64,
) -> (FaultTotals, usize, usize) {
    cfg.n_samples = 60;
    let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
    c.install_fault_plan(plan).unwrap();
    let report = c.run_training(rounds, 0.1).unwrap();
    assert_eq!(report.loss_curve.len(), rounds as usize, "a round was lost to a fault");
    let totals = c.metrics.fault_totals();
    let live = c.live_workers();
    let b = c.assignment().n_batches;
    c.shutdown();
    (totals, live, b)
}

#[test]
fn fault_schedule_is_deterministic_per_seed() {
    // The plan's crash/respawn/drop schedule is seeded, not wall-clock
    // driven: two runs with the same config + plan must observe the
    // identical schedule. (Relaunches are excluded — they fire on real
    // deadline timeouts, which may differ across runs at the margin.)
    let plan = FaultPlan::preset("respawn").unwrap();
    let (a, live_a, _) = run_with_plan(pjrt_cfg(8, 4), &plan, 12);
    let (b, live_b, _) = run_with_plan(pjrt_cfg(8, 4), &plan, 12);
    assert_eq!(
        (a.crashes, a.respawns, a.degradations, a.dropped),
        (b.crashes, b.respawns, b.degradations, b.dropped),
        "fault schedule diverged across identically-seeded runs"
    );
    // The preset crashes workers 0 (round 2, back after 2) and 1
    // (round 6, back after 3): both transients fire and both heal
    // within 12 rounds.
    assert_eq!(a.crashes, 2);
    assert_eq!(a.respawns, 2);
    assert_eq!(live_a, 8);
    assert_eq!(live_b, 8);
}

#[test]
fn deadline_relaunch_keeps_winner_accounting_exact() {
    // Drop-heavy plan: every worker drops 90% of its tasks before
    // dispatch, so batches routinely lose all replicas and only the
    // speculative deadline relaunch can complete the round. Whatever
    // the relaunch count, per-round accounting must stay exact: every
    // dispatched replica is the winner, redundant, or cancelled —
    // dropped tasks were never dispatched and relaunches are ordinary
    // dispatches.
    let mut cfg = pjrt_cfg(6, 2);
    cfg.n_samples = 60;
    let plan = FaultPlan {
        name: "drop-heavy".into(),
        seed: 11,
        events: (0..6).map(|w| (w, FaultEvent::TaskDrop { prob: 0.9 })).collect(),
    };
    let mut c = Coordinator::new(cfg, Backend::Mock).unwrap();
    c.install_fault_plan(&plan).unwrap();
    c.run_training(10, 0.1).unwrap();
    let totals = c.metrics.fault_totals();
    for r in c.metrics.records() {
        assert_eq!(
            r.dispatched,
            2 + r.redundant + r.cancelled,
            "round {}: dispatched ≠ winners + redundant + cancelled",
            r.job_id
        );
    }
    c.shutdown();
    assert!(totals.dropped > 0, "the drop plan never fired");
    assert!(
        totals.relaunches > 0,
        "90% drops on every replica of every batch must force at least one relaunch"
    );
}

#[test]
fn permanent_crash_degrades_onto_survivors() {
    // N = B = 4 (no replication): a permanent crash leaves one batch
    // with zero live replicas, so the coordinator must re-plan onto the
    // 3 survivors. degraded_batch_count(4, 3, 4) = 2 — the largest
    // feasible divisor of the unit count.
    let plan = FaultPlan {
        name: "perma".into(),
        seed: 5,
        events: vec![(0, FaultEvent::PermanentCrash { round: 2, fraction: 0.5 })],
    };
    let (totals, live, b) = run_with_plan(pjrt_cfg(4, 4), &plan, 8);
    assert_eq!(totals.crashes, 1);
    assert_eq!(totals.respawns, 0, "a permanent crash must never respawn");
    assert!(totals.degradations >= 1, "no degraded re-plan was recorded");
    assert_eq!(live, 3);
    assert_eq!(b, 2, "expected a re-plan to the largest feasible batch count");
}

#[test]
fn fig2_scale_transient_crashes_complete_every_round() {
    // The acceptance scenario: fig2 scale (N=24, B=6) under the
    // respawn preset — every round completes, both transients heal,
    // and the cluster ends fully live.
    let plan = FaultPlan::preset("respawn").unwrap();
    let (totals, live, b) = run_with_plan(pjrt_cfg(24, 6), &plan, 12);
    assert_eq!(totals.crashes, 2);
    assert_eq!(totals.respawns, 2);
    assert_eq!(live, 24);
    assert_eq!(b, 6, "transient crashes must not trigger a degraded re-plan here");
}
