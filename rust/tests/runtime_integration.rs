//! Integration tests over the PJRT runtime: load the AOT artifacts,
//! execute them, and check numerics against the pure-Rust oracle.
//!
//! These tests need `make artifacts`; when the manifest is missing they
//! skip (with a notice) rather than fail, so `cargo test` stays green on
//! a fresh checkout.

use batchrep::runtime::{default_artifact_dir, Engine};
use batchrep::worker::{Compute, JobOut, JobSpec, MockCompute, PjrtCompute, Shard};
use std::sync::Arc;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} — run `make artifacts`", dir.display());
        None
    }
}

fn rand_shard(rows: usize, dim: usize, seed: u64) -> Shard {
    let mut rng = batchrep::util::rng::Rng::new(seed);
    Shard {
        rows,
        dim,
        x: (0..rows * dim).map(|_| rng.normal() as f32).collect(),
        y: (0..rows).map(|_| rng.normal() as f32).collect(),
    }
}

#[test]
fn grad_artifact_matches_rust_oracle() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let shard = rand_shard(8, 4, 1);
    let w: Vec<f32> = vec![0.3, -0.7, 1.1, 0.05];
    let out = engine.grad(8, 4, &shard.x, &shard.y, &w).unwrap();

    let mut mock = MockCompute;
    let expect = match mock.run(&shard, &JobSpec::Grad { w: Arc::new(w) }).unwrap() {
        JobOut::Grad(g) => g,
        _ => unreachable!(),
    };
    for (a, e) in out.grad.iter().zip(&expect.grad) {
        assert!((a - e).abs() < 1e-3 * e.abs().max(1.0), "{a} vs {e}");
    }
    assert!((out.loss - expect.loss).abs() < 1e-3 * expect.loss.max(1.0));
}

#[test]
fn mapsum_artifact_matches_rust_oracle() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let shard = rand_shard(8, 4, 2);
    let a = vec![0.2f32, -0.1, 0.3, 0.0];
    let b = vec![1.0f32, 0.5, -0.5, 0.25];
    let got = engine.mapsum(8, 4, &shard.x, &a, &b).unwrap();

    let mut mock = MockCompute;
    let expect = match mock
        .run(&shard, &JobSpec::MapSum { a: Arc::new(a), b: Arc::new(b) })
        .unwrap()
    {
        JobOut::MapSum(v) => v,
        _ => unreachable!(),
    };
    assert!((got - expect).abs() < 1e-4 * expect.abs().max(1.0), "{got} vs {expect}");
}

#[test]
fn pjrt_compute_pads_to_variant() {
    let Some(dir) = artifacts() else { return };
    // 5 rows: no artifact variant — must pad to rows=8 exactly.
    let shard = rand_shard(5, 4, 3);
    let w: Vec<f32> = vec![1.0, 0.0, -1.0, 0.5];
    let mut pjrt = PjrtCompute::new(&dir).unwrap();
    let got = match pjrt.run(&shard, &JobSpec::Grad { w: Arc::new(w.clone()) }).unwrap() {
        JobOut::Grad(g) => g,
        _ => unreachable!(),
    };
    let mut mock = MockCompute;
    let expect = match mock.run(&shard, &JobSpec::Grad { w: Arc::new(w) }).unwrap() {
        JobOut::Grad(g) => g,
        _ => unreachable!(),
    };
    for (a, e) in got.grad.iter().zip(&expect.grad) {
        assert!((a - e).abs() < 1e-3 * e.abs().max(1.0), "padding broke grad: {a} vs {e}");
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    engine.prepare("grad", 8, 4).unwrap();
    let shard = rand_shard(8, 4, 4);
    let w = vec![0.1f32; 4];
    // Repeated executions on the cached executable must agree exactly.
    let o1 = engine.grad(8, 4, &shard.x, &shard.y, &w).unwrap();
    let o2 = engine.grad(8, 4, &shard.x, &shard.y, &w).unwrap();
    assert_eq!(o1, o2);
}

#[test]
fn larger_variant_executes() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let rows = 512;
    let dim = 64;
    if engine.manifest().find("grad", rows, dim).is_err() {
        eprintln!("SKIP: no grad r{rows} d{dim} artifact");
        return;
    }
    let shard = rand_shard(rows, dim, 5);
    let w = vec![0.01f32; dim];
    let out = engine.grad(rows, dim, &shard.x, &shard.y, &w).unwrap();
    assert_eq!(out.grad.len(), dim);
    assert!(out.loss.is_finite() && out.loss > 0.0);
}

#[test]
fn input_shape_validation() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    assert!(engine.grad(8, 4, &[0.0; 31], &[0.0; 8], &[0.0; 4]).is_err());
    assert!(engine.grad(8, 4, &[0.0; 32], &[0.0; 7], &[0.0; 4]).is_err());
    assert!(engine.mapsum(8, 4, &[0.0; 32], &[0.0; 3], &[0.0; 4]).is_err());
}
