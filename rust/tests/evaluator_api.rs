//! Integration tests of the unified `Scenario → Evaluator` API: the
//! same scenario value must be accepted by all four backends, and the
//! analytic and Monte-Carlo backends must cross-check on the paper's
//! Fig. 2 validation matrix.

use batchrep::des::engine::Redundancy;
use batchrep::des::Scenario;
use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::evaluator::{
    cross_check, sweep, AnalyticEvaluator, DesEvaluator, Evaluator, LiveEvaluator,
    MonteCarloEvaluator, ReplicationPolicy,
};

fn paper_scn(n: usize, b: usize, spec: ServiceSpec, seed: u64) -> Scenario {
    Scenario::from_policy(
        ReplicationPolicy::BalancedDisjoint,
        n,
        b,
        BatchService::paper(spec),
        seed,
    )
    .unwrap()
}

#[test]
fn acceptance_cross_check_matrix() {
    // Acceptance criterion: cross_check(analytic, montecarlo, scenario)
    // passes within tolerance for N=24, B ∈ {1, 2, 4, 8, 24} under
    // Shifted-Exponential service.
    let mc = MonteCarloEvaluator { trials: 100_000, threads: 1 };
    for b in [1usize, 2, 4, 8, 24] {
        let scn = paper_scn(24, b, ServiceSpec::shifted_exp(1.0, 0.2), 42 + b as u64);
        let ck = cross_check(&AnalyticEvaluator, &mc, &scn)
            .unwrap_or_else(|e| panic!("B={b}: {e}"));
        assert!(ck.mean_diff <= ck.tolerance, "B={b}");
        // Quantiles must agree too (p50 within 2%).
        let (pa, pm) = (ck.a.quantile(0.5).unwrap(), ck.b.quantile(0.5).unwrap());
        assert!((pa - pm).abs() / pa < 0.02, "B={b}: p50 analytic {pa} vs mc {pm}");
    }
}

#[test]
fn one_scenario_value_fits_every_backend() {
    // Fast service so the live backend's injected sleeps stay small.
    let scn = paper_scn(6, 3, ServiceSpec::shifted_exp(20.0, 0.05), 7);

    let analytic = AnalyticEvaluator.evaluate(&scn).unwrap();
    let mc = MonteCarloEvaluator { trials: 40_000, threads: 2 }.evaluate(&scn).unwrap();
    let des = DesEvaluator { trials: 10_000, ..DesEvaluator::default() }
        .evaluate(&scn)
        .unwrap();
    let live = LiveEvaluator { rounds: 10, time_scale: 0.001, ..LiveEvaluator::default() }
        .evaluate(&scn)
        .unwrap();

    // All four speak the same currency.
    for (name, st) in
        [("analytic", &analytic), ("mc", &mc), ("des", &des), ("live", &live)]
    {
        assert!(st.mean.is_finite() && st.mean > 0.0, "{name}");
        assert!(st.variance >= 0.0, "{name}");
        assert!(st.quantile(0.5).is_some(), "{name}");
    }
    // Simulation backends agree tightly with the exact value.
    assert!((mc.mean - analytic.mean).abs() < 6.0 * mc.sem.max(1e-3));
    assert!((des.mean - analytic.mean).abs() < 6.0 * des.sem.max(1e-3));
    // The live system is noisy at 10 rounds but lands in the ballpark.
    assert!(
        (live.mean - analytic.mean).abs() < 0.6 * analytic.mean,
        "live {} vs analytic {}",
        live.mean,
        analytic.mean
    );
}

#[test]
fn seed_makes_evaluations_bit_reproducible() {
    let spec = ServiceSpec::shifted_exp(1.0, 0.2);
    let mc = MonteCarloEvaluator { trials: 20_000, threads: 1 };
    let a = mc.evaluate(&paper_scn(12, 4, spec.clone(), 99)).unwrap();
    let b = mc.evaluate(&paper_scn(12, 4, spec.clone(), 99)).unwrap();
    assert_eq!(a.mean, b.mean);
    assert_eq!(a.variance, b.variance);
    let c = mc.evaluate(&paper_scn(12, 4, spec, 100)).unwrap();
    assert_ne!(a.mean, c.mean);
}

#[test]
fn backends_swap_with_one_line() {
    // The generic sweep driver with two different backends — the shape
    // the experiments layer is built on.
    let service = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2));
    let bs = [1usize, 2, 4, 8];
    let make = |seed: u64| {
        let service = service.clone();
        move |b: usize| {
            Scenario::from_policy(
                ReplicationPolicy::BalancedDisjoint,
                24,
                b,
                service.clone(),
                seed + b as u64,
            )
        }
    };
    let exact = sweep(&bs, &AnalyticEvaluator, make(1)).unwrap();
    let sim =
        sweep(&bs, &MonteCarloEvaluator { trials: 30_000, threads: 1 }, make(1)).unwrap();
    for (e, s) in exact.iter().zip(&sim) {
        assert_eq!(e.b, s.b);
        assert!(
            (e.stats.mean - s.stats.mean).abs() < 0.02 * e.stats.mean,
            "B={}: {} vs {}",
            e.b,
            e.stats.mean,
            s.stats.mean
        );
    }
}

#[test]
fn k_of_b_is_a_first_class_scenario_field() {
    // Partial aggregation rides the scenario, not a bespoke sampler:
    // all four backends consume it — the live coordinator completes the
    // round at the k-th finished batch and cancels the rest.
    let scn = paper_scn(24, 6, ServiceSpec::shifted_exp(1.0, 0.2), 17)
        .with_k_of_b(3)
        .unwrap();
    let exact = AnalyticEvaluator.evaluate(&scn).unwrap();
    let mc = MonteCarloEvaluator { trials: 60_000, threads: 2 }.evaluate(&scn).unwrap();
    let des = DesEvaluator { trials: 30_000, ..DesEvaluator::default() }
        .evaluate(&scn)
        .unwrap();
    assert!((mc.mean - exact.mean).abs() < 6.0 * mc.sem.max(1e-3));
    assert!((des.mean - exact.mean).abs() < 6.0 * des.sem.max(1e-3));
    // Waiting for fewer batches is strictly faster than full completion.
    let full = AnalyticEvaluator
        .evaluate(&paper_scn(24, 6, ServiceSpec::shifted_exp(1.0, 0.2), 17))
        .unwrap();
    assert!(exact.mean < full.mean);
    // The live backend consumes k-of-B too (smaller cluster so the
    // injected sleeps stay short; generous tolerance for wall noise).
    let live_scn = paper_scn(6, 3, ServiceSpec::shifted_exp(2.0, 0.1), 17)
        .with_k_of_b(2)
        .unwrap();
    let live = LiveEvaluator { rounds: 25, time_scale: 0.01, ..LiveEvaluator::default() }
        .evaluate(&live_scn)
        .unwrap();
    let live_exact = AnalyticEvaluator.evaluate(&live_scn).unwrap();
    assert!(
        (live.mean - live_exact.mean).abs() < 0.5 * live_exact.mean,
        "live k-of-B {} vs analytic {}",
        live.mean,
        live_exact.mean
    );
}

#[test]
fn des_evaluator_is_deterministic_per_seed_and_threads() {
    let scn = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.2), 23);
    let ev = DesEvaluator { trials: 20_000, threads: 3, ..DesEvaluator::default() };
    let a = ev.evaluate(&scn).unwrap();
    let b = ev.evaluate(&scn).unwrap();
    assert_eq!(a.mean.to_bits(), b.mean.to_bits());
    assert_eq!(a.variance.to_bits(), b.variance.to_bits());
    assert_eq!(a.quantiles, b.quantiles);
}

#[test]
fn speculative_scenarios_route_to_capable_backends() {
    let scn = paper_scn(12, 3, ServiceSpec::shifted_exp(1.0, 0.2), 5)
        .with_redundancy(Redundancy::Speculative { deadline_factor: 1.5 });
    // The closed forms and the direct sampler do not model reactive
    // redundancy — they must refuse rather than silently mis-evaluate.
    assert!(AnalyticEvaluator.evaluate(&scn).is_err());
    assert!(MonteCarloEvaluator::default().evaluate(&scn).is_err());
    // The event engine models it.
    let st = DesEvaluator { trials: 5_000, ..DesEvaluator::default() }
        .evaluate(&scn)
        .unwrap();
    assert!(st.mean.is_finite() && st.cost.is_some());
}
