//! Public-surface tests of the unified observability layer: installing
//! the event sink around real runs (study pools, the live coordinator),
//! validating the emitted JSON-lines log, and — the acceptance bar —
//! proving the sink never perturbs results: stats with the sink
//! installed are bit-identical to stats without it at any thread count.

use std::sync::Mutex;

use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::evaluator::{Evaluator, LiveEvaluator, ReplicationPolicy};
use batchrep::study::{execute, BackendSel, BatchAxis, StudySpec};

/// The sink is process-wide state, so every test that installs one must
/// hold this lock for its whole body (install → run → uninstall).
static SINK: Mutex<()> = Mutex::new(());

fn sink_guard() -> std::sync::MutexGuard<'static, ()> {
    SINK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn small_spec() -> StudySpec {
    StudySpec {
        n_workers: vec![12],
        batches: BatchAxis::Explicit(vec![3, 4]),
        services: vec![BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2))],
        backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo, BackendSel::Des],
        mc_trials: 6_000,
        des_trials: 2_000,
        seed: 11,
        ..StudySpec::base("obs-test")
    }
}

#[test]
fn sink_does_not_perturb_study_results_at_any_thread_count() {
    // The acceptance property: the full study artifact is bit-identical
    // with and without an installed sink, for threads ∈ {1, 4}. The
    // sink must observe, never participate.
    let plan = small_spec().compile().unwrap();
    for threads in [1usize, 4] {
        let bare = execute(&plan, threads, &mut |_, _, _, _| {}).unwrap();
        let bare_json = bare.to_json().to_string();

        let guard = sink_guard();
        let mem = batchrep::obs::install_memory().unwrap();
        let observed = execute(&plan, threads, &mut |_, _, _, _| {}).unwrap();
        batchrep::obs::uninstall();
        drop(guard);

        assert_eq!(
            observed.to_json().to_string(),
            bare_json,
            "sink perturbed the study artifact at {threads} threads"
        );
        // And the run it watched actually produced events.
        let summary = batchrep::obs::summarize_str(&mem.contents()).unwrap();
        assert!(summary.lines > 0, "sink installed but nothing was recorded");
    }
}

#[test]
fn file_sink_captures_a_schema_valid_multi_subsystem_log() {
    // `--events` in miniature: run a pooled study into a file sink,
    // then push the file through the same validator `obs summarize`
    // uses. The log must carry events from the study executor, both
    // simulation pools, and the analysis cache, plus spans + counters.
    let dir = std::env::temp_dir().join("batchrep_obs_layer_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("events.jsonl");

    let guard = sink_guard();
    batchrep::obs::install_file(&path).unwrap();
    let plan = small_spec().compile().unwrap();
    execute(&plan, 4, &mut |_, _, _, _| {}).unwrap();
    batchrep::obs::uninstall();
    drop(guard);

    let s = batchrep::obs::validate_file(&path).unwrap();
    for sub in ["study", "mc", "des", "analysis", "obs"] {
        assert!(s.subsystems.contains(sub), "no '{sub}' events in {:?}", s.subsystems);
    }
    assert!(
        s.event_counts.get("study/cell").copied().unwrap_or(0) >= plan.cells.len() as u64,
        "missing per-cell events: {:?}",
        s.event_counts
    );
    assert!(!s.spans.is_empty(), "no spans recorded");
    assert!(s.spans.contains_key("study.execute"), "{:?}", s.spans.keys());
    assert!(!s.counters.is_empty(), "uninstall did not flush a counters snapshot");
    assert!(s.counters.contains_key("study.cells"), "{:?}", s.counters);
    assert!(s.duration_s() >= 0.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn live_coordinator_emits_round_events() {
    // The live runtime is the richest event source: every round must
    // land a coordinator/round record carrying the relaunch count the
    // summarizer's straggler histogram is built from.
    let scn = batchrep::des::Scenario::from_policy(
        ReplicationPolicy::BalancedDisjoint,
        6,
        2,
        BatchService::paper(ServiceSpec::shifted_exp(50.0, 0.02)),
        7,
    )
    .unwrap();
    let live = LiveEvaluator {
        rounds: 3,
        time_scale: 0.01,
        n_samples: 64,
        dim: 4,
        ..LiveEvaluator::default()
    };

    let guard = sink_guard();
    let mem = batchrep::obs::install_memory().unwrap();
    let stats = live.evaluate(&scn).unwrap();
    batchrep::obs::uninstall();
    drop(guard);

    assert!(stats.mean.is_finite());
    let s = batchrep::obs::summarize_str(&mem.contents()).unwrap();
    assert!(s.subsystems.contains("coordinator"), "{:?}", s.subsystems);
    assert!(
        s.event_counts.get("coordinator/round").copied().unwrap_or(0) >= 3,
        "expected ≥3 round events: {:?}",
        s.event_counts
    );
    assert!(s.live_rounds >= 3, "summary live_rounds = {}", s.live_rounds);
    // Every round bins into the relaunch histogram (0 relaunches is a bin).
    let binned: u64 = s.relaunch_hist.values().sum();
    assert!(binned >= 3, "relaunch histogram covers {binned} rounds");
}

#[test]
fn counters_accumulate_without_a_sink() {
    // Counters are always-on (one relaxed atomic each) and must track
    // work even when no sink is installed — and still never perturb it.
    let before = batchrep::obs::snapshot();
    let plan = small_spec().compile().unwrap();
    execute(&plan, 2, &mut |_, _, _, _| {}).unwrap();
    let delta = batchrep::obs::snapshot().delta(&before);
    assert!(
        delta.get(batchrep::obs::Counter::StudyCells) >= plan.cells.len() as u64,
        "study cell counter did not advance"
    );
    assert!(delta.get(batchrep::obs::Counter::McTrials) >= 1);
    assert!(delta.get(batchrep::obs::Counter::DesTrials) >= 1);
}
