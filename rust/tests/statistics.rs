//! Statistical integration tests: the three independent implementations
//! of System1's completion time — closed-form analysis, Monte-Carlo
//! sampler, and the discrete-event engine — must agree pairwise across
//! a matrix of (N, B, distribution) configurations; and the live
//! coordinator's injected completion must match all three.

use batchrep::analysis;
use batchrep::des::engine::{simulate_many, EngineConfig};
use batchrep::des::{montecarlo, Scenario};
use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::evaluator::CompletionStats;
use batchrep::testkit;
use batchrep::util::stats::{Samples, Welford};

const TRIALS: u64 = 60_000;

fn scn(n: usize, b: usize, spec: &ServiceSpec) -> Scenario {
    Scenario::paper_balanced(n, b, BatchService::paper(spec.clone())).unwrap()
}

#[test]
fn three_way_agreement_matrix() {
    let specs = [
        ServiceSpec::exp(0.5),
        ServiceSpec::exp(2.0),
        ServiceSpec::shifted_exp(1.0, 0.1),
        ServiceSpec::shifted_exp(2.0, 1.0),
    ];
    for spec in &specs {
        for (n, b) in [(6usize, 2usize), (12, 4), (24, 8)] {
            let cf = analysis::completion_time_stats(n as u64, b as u64, spec).unwrap();
            let s = scn(n, b, spec);
            let mc = montecarlo::run_trials(&s, TRIALS, 101);
            let en = simulate_many(&s, &EngineConfig::default(), TRIALS / 3, 202);

            let tol = 4.0 * mc.ci95().max(1e-3);
            assert!(
                (mc.mean() - cf.mean).abs() < tol,
                "{} N={n} B={b}: mc {} vs cf {}",
                spec.name(),
                mc.mean(),
                cf.mean
            );
            assert!(
                (en.completion.mean() - cf.mean).abs() < 2.0 * tol,
                "{} N={n} B={b}: engine {} vs cf {}",
                spec.name(),
                en.completion.mean(),
                cf.mean
            );
            let var_rel = (mc.variance() - cf.var).abs() / cf.var;
            assert!(var_rel < 0.08, "{} N={n} B={b}: var {}", spec.name(), var_rel);
        }
    }
}

#[test]
fn mc_k_of_b_matches_partial_closed_form_under_z_test() {
    // Satellite acceptance: the MC k-of-B sampler vs
    // `analysis::partial_completion_stats` on Shifted-Exponential with
    // a tolerance that is *derived from the trial count* (a z-bound on
    // the estimator's standard error — no hard-coded epsilon), at the
    // (k, B) corners including k = 1 and k = B.
    let z = 4.5;
    let spec = ServiceSpec::shifted_exp(1.0, 0.3);
    for (n, b) in [(12u64, 4u64), (24, 6)] {
        for k in [1u64, b.div_ceil(2), b] {
            let s = scn(n as usize, b as usize, &spec)
                .with_k_of_b(k as usize)
                .unwrap();
            let mc = montecarlo::run_trials(&s, TRIALS, 77 + k);
            let cf = analysis::partial_completion_stats(n, b, k, &spec).unwrap();
            // SE of the mean straight from the sampled variance and the
            // trial count: tol shrinks as 1/√TRIALS.
            let sem = (mc.variance() / TRIALS as f64).sqrt();
            assert!(
                (mc.mean() - cf.mean).abs() <= z * sem,
                "N={n} B={b} k={k}: mc {} vs cf {} exceeds {z}σ = {}",
                mc.mean(),
                cf.mean,
                z * sem
            );
        }
    }
}

#[test]
fn empirical_cdf_matches_closed_form() {
    let spec = ServiceSpec::shifted_exp(1.5, 0.4);
    let (n, b) = (12u64, 3u64);
    let s = scn(n as usize, b as usize, &spec);
    let mc = montecarlo::run_trials(&s, 150_000, 7);
    let raw = mc.samples.raw();
    for q_t in [2.0, 2.5, 3.0, 4.0] {
        let theory = analysis::completion_time_cdf(n, b, &spec, q_t).unwrap();
        let emp = raw.iter().filter(|&&x| x <= q_t).count() as f64 / raw.len() as f64;
        assert!(
            (theory - emp).abs() < 0.01,
            "t={q_t}: cdf theory {theory} vs empirical {emp}"
        );
    }
}

#[test]
fn prop_mean_dominance_of_balanced_holds_in_simulation() {
    // Theorem 1, statistical form across random configs: balanced
    // disjoint E[T] ≤ skewed E[T] (with MC slack) for exp-family.
    testkit::check("thm1-sim", 12, |g| {
        let choices = [(8usize, 2usize), (8, 4), (12, 3), (12, 4), (16, 8)];
        let (n, b) = *g.pick(&choices);
        let delta = g.f64_in(0.0, 1.0);
        let spec = ServiceSpec::shifted_exp(1.0, delta);
        let seed = g.u64_in(0, 1 << 40);

        let bal = scn(n, b, &spec);
        let layout = batchrep::batching::disjoint(n, b).unwrap();
        let skw = Scenario::new(
            layout,
            batchrep::assignment::skewed(n, b).unwrap(),
            BatchService::paper(spec.clone()),
        )
        .unwrap();
        let m_bal = montecarlo::run_trials(&bal, 30_000, seed);
        let m_skw = montecarlo::run_trials(&skw, 30_000, seed ^ 1);
        assert!(
            m_bal.mean() <= m_skw.mean() + 3.0 * (m_bal.ci95() + m_skw.ci95()),
            "N={n} B={b} delta={delta}: balanced {} > skewed {}",
            m_bal.mean(),
            m_skw.mean()
        );
    });
}

#[test]
fn completion_stats_quantile_edge_cases() {
    // The reported-quantile lookup: an exact backend with no retained
    // samples reports an empty quantile list, and every lookup is None
    // rather than a panic or a fabricated number.
    let empty = CompletionStats {
        mean: 1.0,
        variance: 0.5,
        quantiles: Vec::new(),
        cost: None,
        sem: 0.0,
        samples: 0,
        overhead: None,
    };
    for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
        assert_eq!(empty.quantile(q), None, "q={q}");
    }
    // Populated lists match within the lookup's epsilon and miss
    // cleanly outside it.
    let st = CompletionStats {
        quantiles: vec![(0.5, 2.0), (0.9, 3.0), (0.99, 4.0)],
        ..empty.clone()
    };
    assert_eq!(st.quantile(0.5), Some(2.0));
    assert_eq!(st.quantile(0.5 + 1e-12), Some(2.0), "lookup tolerates fp wobble");
    assert_eq!(st.quantile(0.75), None);
    assert_eq!(st.quantile(1.0), None);

    // The sample-set quantile under the same edge cases: a single
    // sample answers every q; q = 0 / q = 1 are the extreme order
    // statistics; ties and unsorted input are fine (total_cmp order).
    let mut none = Samples::new();
    assert_eq!(none.quantile(0.5), None, "empty sample set has no quantiles");
    let mut one = Samples::new();
    one.push(7.5);
    for q in [0.0, 0.3, 1.0] {
        assert_eq!(one.quantile(q), Some(7.5));
    }
    let mut s = Samples::new();
    for x in [3.0f64, 1.0, 2.0, 2.0, 0.0, -1.0] {
        s.push(x);
    }
    assert_eq!(s.quantile(0.0), Some(-1.0));
    assert_eq!(s.quantile(1.0), Some(3.0));
    let p50 = s.quantile(0.5).unwrap();
    assert!((0.0..=3.0).contains(&p50), "median {p50} inside the sample range");
    // NaN-free ordering: zeros and negative zeros don't wedge the
    // total_cmp sort, and quantiles stay monotone in q.
    let mut z = Samples::new();
    for x in [0.0f64, -0.0, 1.0, -1.0, 0.5] {
        z.push(x);
    }
    let mut prev = f64::NEG_INFINITY;
    for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let v = z.quantile(q).unwrap();
        assert!(v >= prev, "quantiles must be monotone: q={q} v={v} prev={prev}");
        prev = v;
    }
}

#[test]
fn welford_merge_is_associative_across_arbitrary_shard_splits() {
    // The study pool and both sharded runners rely on Welford merges
    // being split-invariant: any partition of the trial stream into
    // shards, merged in any grouping, must agree with the sequential
    // accumulator to fp accuracy (count exactly).
    let mut rng = batchrep::util::rng::Rng::new(99);
    let xs: Vec<f64> = (0..5_000).map(|_| rng.f64() * 10.0 - 3.0).collect();
    let mut sequential = Welford::new();
    for &x in &xs {
        sequential.push(x);
    }
    let splits: Vec<Vec<usize>> = vec![
        vec![5_000],
        vec![1, 4_999],
        vec![2_500, 2_500],
        vec![1, 1, 1, 4_997],
        vec![64; 5_000 / 64]
            .into_iter()
            .chain(std::iter::once(5_000 % 64))
            .collect(),
    ];
    for split in &splits {
        // Build the shard accumulators.
        let mut shards: Vec<Welford> = Vec::new();
        let mut i = 0usize;
        for &len in split {
            let mut w = Welford::new();
            for &x in &xs[i..i + len] {
                w.push(x);
            }
            i += len;
            shards.push(w);
        }
        assert_eq!(i, xs.len());
        // Left fold.
        let mut left = Welford::new();
        for sh in &shards {
            left.merge(sh);
        }
        // Pairwise tree fold (a different association).
        let mut level = shards.clone();
        while level.len() > 1 {
            let mut next = Vec::new();
            for pair in level.chunks(2) {
                let mut m = pair[0];
                if let Some(b) = pair.get(1) {
                    m.merge(b);
                }
                next.push(m);
            }
            level = next;
        }
        let tree = level[0];
        for (name, merged) in [("left", &left), ("tree", &tree)] {
            assert_eq!(merged.count(), sequential.count(), "{name} {split:?}");
            assert!(
                (merged.mean() - sequential.mean()).abs() < 1e-10,
                "{name} {split:?}: mean {} vs {}",
                merged.mean(),
                sequential.mean()
            );
            assert!(
                (merged.variance() - sequential.variance()).abs() < 1e-8,
                "{name} {split:?}: var {} vs {}",
                merged.variance(),
                sequential.variance()
            );
            assert_eq!(merged.min(), sequential.min(), "{name} {split:?}");
            assert_eq!(merged.max(), sequential.max(), "{name} {split:?}");
        }
        // Merging an empty accumulator from either side is the identity.
        let mut with_empty = left;
        with_empty.merge(&Welford::new());
        assert_eq!(with_empty.count(), left.count());
        let mut from_empty = Welford::new();
        from_empty.merge(&left);
        assert!((from_empty.mean() - left.mean()).abs() < 1e-12);
    }
}

#[test]
fn variance_reduction_of_diversity_is_monotone_sexp() {
    // Theorem 4 in simulation: Var[T] nonincreasing as B decreases.
    let spec = ServiceSpec::shifted_exp(1.0, 0.5);
    let divisors = [1usize, 2, 3, 4, 6, 12];
    let mut prev = f64::NEG_INFINITY;
    for &b in &divisors {
        let s = scn(12, b, &spec);
        let mc = montecarlo::run_trials(&s, 150_000, 55);
        assert!(
            mc.variance() >= prev * 0.93,
            "variance not increasing in B: B={b} var={} prev={prev}",
            mc.variance()
        );
        prev = mc.variance();
    }
}
