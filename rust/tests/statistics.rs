//! Statistical integration tests: the three independent implementations
//! of System1's completion time — closed-form analysis, Monte-Carlo
//! sampler, and the discrete-event engine — must agree pairwise across
//! a matrix of (N, B, distribution) configurations; and the live
//! coordinator's injected completion must match all three.

use batchrep::analysis;
use batchrep::des::engine::{simulate_many, EngineConfig};
use batchrep::des::{montecarlo, Scenario};
use batchrep::dist::{BatchService, ServiceSpec};
use batchrep::testkit;

const TRIALS: u64 = 60_000;

fn scn(n: usize, b: usize, spec: &ServiceSpec) -> Scenario {
    Scenario::paper_balanced(n, b, BatchService::paper(spec.clone())).unwrap()
}

#[test]
fn three_way_agreement_matrix() {
    let specs = [
        ServiceSpec::exp(0.5),
        ServiceSpec::exp(2.0),
        ServiceSpec::shifted_exp(1.0, 0.1),
        ServiceSpec::shifted_exp(2.0, 1.0),
    ];
    for spec in &specs {
        for (n, b) in [(6usize, 2usize), (12, 4), (24, 8)] {
            let cf = analysis::completion_time_stats(n as u64, b as u64, spec).unwrap();
            let s = scn(n, b, spec);
            let mc = montecarlo::run_trials(&s, TRIALS, 101);
            let en = simulate_many(&s, &EngineConfig::default(), TRIALS / 3, 202);

            let tol = 4.0 * mc.ci95().max(1e-3);
            assert!(
                (mc.mean() - cf.mean).abs() < tol,
                "{} N={n} B={b}: mc {} vs cf {}",
                spec.name(),
                mc.mean(),
                cf.mean
            );
            assert!(
                (en.completion.mean() - cf.mean).abs() < 2.0 * tol,
                "{} N={n} B={b}: engine {} vs cf {}",
                spec.name(),
                en.completion.mean(),
                cf.mean
            );
            let var_rel = (mc.variance() - cf.var).abs() / cf.var;
            assert!(var_rel < 0.08, "{} N={n} B={b}: var {}", spec.name(), var_rel);
        }
    }
}

#[test]
fn mc_k_of_b_matches_partial_closed_form_under_z_test() {
    // Satellite acceptance: the MC k-of-B sampler vs
    // `analysis::partial_completion_stats` on Shifted-Exponential with
    // a tolerance that is *derived from the trial count* (a z-bound on
    // the estimator's standard error — no hard-coded epsilon), at the
    // (k, B) corners including k = 1 and k = B.
    let z = 4.5;
    let spec = ServiceSpec::shifted_exp(1.0, 0.3);
    for (n, b) in [(12u64, 4u64), (24, 6)] {
        for k in [1u64, b.div_ceil(2), b] {
            let s = scn(n as usize, b as usize, &spec)
                .with_k_of_b(k as usize)
                .unwrap();
            let mc = montecarlo::run_trials(&s, TRIALS, 77 + k);
            let cf = analysis::partial_completion_stats(n, b, k, &spec).unwrap();
            // SE of the mean straight from the sampled variance and the
            // trial count: tol shrinks as 1/√TRIALS.
            let sem = (mc.variance() / TRIALS as f64).sqrt();
            assert!(
                (mc.mean() - cf.mean).abs() <= z * sem,
                "N={n} B={b} k={k}: mc {} vs cf {} exceeds {z}σ = {}",
                mc.mean(),
                cf.mean,
                z * sem
            );
        }
    }
}

#[test]
fn empirical_cdf_matches_closed_form() {
    let spec = ServiceSpec::shifted_exp(1.5, 0.4);
    let (n, b) = (12u64, 3u64);
    let s = scn(n as usize, b as usize, &spec);
    let mc = montecarlo::run_trials(&s, 150_000, 7);
    let raw = mc.samples.raw();
    for q_t in [2.0, 2.5, 3.0, 4.0] {
        let theory = analysis::completion_time_cdf(n, b, &spec, q_t).unwrap();
        let emp = raw.iter().filter(|&&x| x <= q_t).count() as f64 / raw.len() as f64;
        assert!(
            (theory - emp).abs() < 0.01,
            "t={q_t}: cdf theory {theory} vs empirical {emp}"
        );
    }
}

#[test]
fn prop_mean_dominance_of_balanced_holds_in_simulation() {
    // Theorem 1, statistical form across random configs: balanced
    // disjoint E[T] ≤ skewed E[T] (with MC slack) for exp-family.
    testkit::check("thm1-sim", 12, |g| {
        let choices = [(8usize, 2usize), (8, 4), (12, 3), (12, 4), (16, 8)];
        let (n, b) = *g.pick(&choices);
        let delta = g.f64_in(0.0, 1.0);
        let spec = ServiceSpec::shifted_exp(1.0, delta);
        let seed = g.u64_in(0, 1 << 40);

        let bal = scn(n, b, &spec);
        let layout = batchrep::batching::disjoint(n, b).unwrap();
        let skw = Scenario::new(
            layout,
            batchrep::assignment::skewed(n, b).unwrap(),
            BatchService::paper(spec.clone()),
        )
        .unwrap();
        let m_bal = montecarlo::run_trials(&bal, 30_000, seed);
        let m_skw = montecarlo::run_trials(&skw, 30_000, seed ^ 1);
        assert!(
            m_bal.mean() <= m_skw.mean() + 3.0 * (m_bal.ci95() + m_skw.ci95()),
            "N={n} B={b} delta={delta}: balanced {} > skewed {}",
            m_bal.mean(),
            m_skw.mean()
        );
    });
}

#[test]
fn variance_reduction_of_diversity_is_monotone_sexp() {
    // Theorem 4 in simulation: Var[T] nonincreasing as B decreases.
    let spec = ServiceSpec::shifted_exp(1.0, 0.5);
    let divisors = [1usize, 2, 3, 4, 6, 12];
    let mut prev = f64::NEG_INFINITY;
    for &b in &divisors {
        let s = scn(12, b, &spec);
        let mc = montecarlo::run_trials(&s, 150_000, 55);
        assert!(
            mc.variance() >= prev * 0.93,
            "variance not increasing in B: B={b} var={} prev={prev}",
            mc.variance()
        );
        prev = mc.variance();
    }
}
