//! Micro-benchmark harness (a `criterion` stand-in for the offline
//! environment), used by every file in `rust/benches/` with
//! `harness = false`.
//!
//! Methodology: warm up until the clock stabilizes, then run timed
//! batches until a minimum measurement time is reached; report median,
//! mean, and MAD over per-iteration times, plus optional throughput.
//! Output is a Markdown table (stdout) and an optional CSV file so the
//! experiment harness can diff runs across optimization iterations.

pub mod des;
pub mod mc;

use crate::util::stats::Samples;
use crate::util::table::Table;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id.
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median absolute deviation, seconds.
    pub mad_s: f64,
    /// Iterations measured.
    pub iters: u64,
    /// Optional items/s given a per-iteration item count.
    pub throughput: Option<f64>,
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup time before measurement.
    pub warmup: Duration,
    /// Minimum total measured time.
    pub measure: Duration,
    /// Maximum recorded sample count (batches).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1200),
            max_samples: 200,
        }
    }
}

/// A suite of benchmarks producing one results table.
#[derive(Debug)]
pub struct Suite {
    title: String,
    cfg: BenchConfig,
    results: Vec<Measurement>,
}

impl Suite {
    /// New suite (title is the table heading).
    pub fn new(title: &str) -> Self {
        // Fast mode for CI smoke runs: BATCHREP_BENCH_FAST=1.
        let cfg = if std::env::var("BATCHREP_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(80),
                max_samples: 30,
            }
        } else {
            BenchConfig::default()
        };
        Self { title: title.to_string(), cfg, results: Vec::new() }
    }

    /// Benchmark a closure; `items_per_iter` (if nonzero) adds a
    /// throughput column.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items_per_iter: u64, mut f: F) {
        let m = run_bench(name, self.cfg, items_per_iter, &mut f);
        eprintln!(
            "  {:<42} median {:>12}  mean {:>12}  ±{:>10}{}",
            m.name,
            fmt_time(m.median_s),
            fmt_time(m.mean_s),
            fmt_time(m.mad_s),
            m.throughput
                .map(|t| format!("  {:.3e} items/s", t))
                .unwrap_or_default()
        );
        self.results.push(m);
    }

    /// Render the results table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &self.title,
            &["benchmark", "median", "mean", "mad", "iters", "throughput/s"],
        );
        for m in &self.results {
            t.row(vec![
                m.name.clone(),
                fmt_time(m.median_s),
                fmt_time(m.mean_s),
                fmt_time(m.mad_s),
                m.iters.to_string(),
                m.throughput.map(|x| format!("{x:.3e}")).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// Print the table and persist CSV under `results/bench/`.
    pub fn finish(self) {
        let t = self.table();
        t.print();
        let stem: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let dir = std::path::Path::new("results/bench");
        if let Err(e) = t.write_to(dir, &stem) {
            eprintln!("warn: could not write bench csv: {e}");
        }
    }
}

#[allow(clippy::disallowed_methods)] // benchmarking is inherently wall-clock
fn run_bench<F: FnMut()>(
    name: &str,
    cfg: BenchConfig,
    items_per_iter: u64,
    f: &mut F,
) -> Measurement {
    // Warmup, and discover a batch size that runs ≥ ~50 µs so that timer
    // overhead is negligible.
    let mut batch = 1u64;
    let warm_end = Instant::now() + cfg.warmup;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        if Instant::now() >= warm_end && dt >= Duration::from_micros(20) {
            break;
        }
        if dt < Duration::from_micros(50) && batch < (1 << 30) {
            batch *= 2;
        }
    }

    let mut per_iter = Samples::new();
    let measure_end = Instant::now() + cfg.measure;
    let mut total_iters = 0u64;
    while Instant::now() < measure_end && per_iter.len() < cfg.max_samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        per_iter.push(dt / batch as f64);
        total_iters += batch;
    }

    // lint:allow(D4): the warmup loop above guarantees at least one measured iteration
    let median = per_iter.median().expect("bench measured at least one iteration");
    let mean = per_iter.mean();
    let mut devs = Samples::new();
    for &x in per_iter.raw() {
        devs.push((x - median).abs());
    }
    // lint:allow(D4): devs holds one deviation per (non-empty) measured sample
    let mad = devs.median().expect("deviations mirror the non-empty samples");
    Measurement {
        name: name.to_string(),
        median_s: median,
        mean_s: mean,
        mad_s: mad,
        iters: total_iters,
        throughput: (items_per_iter > 0).then(|| items_per_iter as f64 / median),
    }
}

/// Human time formatting (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("BATCHREP_BENCH_FAST", "1");
        let mut suite = Suite::new("selftest");
        let mut acc = 0u64;
        suite.bench("wrapping-mul", 1, || {
            acc = black_box(acc.wrapping_mul(6364136223846793005).wrapping_add(1));
        });
        let t = suite.table();
        assert_eq!(t.rows.len(), 1);
        let m = &suite.results[0];
        assert!(m.median_s > 0.0 && m.median_s < 1e-3);
        assert!(m.iters > 0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }
}
