//! Monte-Carlo throughput trajectory harness (the `batchrep bench-mc`
//! subcommand).
//!
//! Measures trials/sec of the three sampler paths — the retained scalar
//! reference ([`crate::des::montecarlo::run_trials_reference`]), the
//! block kernel, and auto-threaded sharding — on a **fixed fig2-scale
//! reference scenario**, and writes the result as `BENCH_mc.json` at
//! the repo root. The file gives this and every future perf PR a
//! measured baseline to diff against; PERF.md documents the schema and
//! how to rerun.

use crate::des::{montecarlo, Scenario};
use crate::dist::{BatchService, ServiceSpec};
use crate::evaluator::ReplicationPolicy;
use crate::util::json::Json;
use crate::util::Timer;
use std::path::Path;

/// Schema version of `BENCH_mc.json`.
pub const SCHEMA_VERSION: i64 = 1;

/// The fixed measurement scenario: the Fig. 2 scale (`N = 24`, `B = 4`,
/// SExp(1, 0.2), balanced disjoint, seed 42). Fixed so that numbers are
/// comparable across PRs.
pub fn reference_scenario() -> Scenario {
    Scenario::from_policy(
        ReplicationPolicy::BalancedDisjoint,
        24,
        4,
        BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2)),
        42,
    )
    // lint:allow(D4): fixed in-source reference scenario, covered by benchkit tests
    .expect("reference scenario is valid by construction")
}

/// One measured sampler path.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    /// Trials executed in the timed run.
    pub trials: u64,
    /// Wall-clock seconds of the timed run.
    pub elapsed_s: f64,
    /// `trials / elapsed_s`.
    pub trials_per_sec: f64,
}

/// Full harness result (serialized to `BENCH_mc.json`).
#[derive(Debug, Clone)]
pub struct McBenchReport {
    /// Trials per timed run.
    pub trials: u64,
    /// Threads used by the multi-threaded run.
    pub threads: usize,
    /// Pre-PR scalar per-draw sampler (the speedup baseline).
    pub reference_scalar: Throughput,
    /// Block kernel, single thread.
    pub single_thread: Throughput,
    /// Block kernel, `threads`-way sharding.
    pub multi_thread: Throughput,
    /// `single_thread / reference_scalar` throughput ratio.
    pub speedup_block_vs_reference: f64,
    /// `multi_thread / single_thread` throughput ratio.
    pub speedup_threads_vs_single: f64,
}

fn measure(trials: u64, mut f: impl FnMut() -> montecarlo::McSummary) -> (Throughput, f64) {
    let t = Timer::start();
    let sum = f();
    let elapsed_s = t.secs().max(1e-9);
    (
        Throughput { trials, elapsed_s, trials_per_sec: trials as f64 / elapsed_s },
        sum.mean(),
    )
}

/// Run the harness: one warmed, timed run per sampler path, plus an
/// agreement guard so a broken kernel can never report a "speedup".
pub fn run(trials: u64, threads: usize) -> McBenchReport {
    let trials = trials.max(1);
    let threads = threads.max(1);
    let scn = reference_scenario();
    // Warm caches and lazily-built tables before timing.
    let _ = montecarlo::run_trials(&scn, (trials / 10).max(1), 7);
    let (reference_scalar, m_ref) =
        measure(trials, || montecarlo::run_trials_reference(&scn, trials, scn.seed));
    let (single_thread, m_single) =
        measure(trials, || montecarlo::run_trials(&scn, trials, scn.seed));
    let (multi_thread, m_multi) = measure(trials, || {
        montecarlo::run_trials_parallel(&scn, trials, scn.seed, threads)
    });
    // The three paths must describe the same system: scalar and block
    // consume the same RNG stream (fast_ln rounding only); the threaded
    // run uses substreams, so it agrees statistically.
    assert!(
        (m_ref - m_single).abs() <= 1e-9 * m_ref.abs().max(1.0),
        "block kernel diverged from scalar reference: {m_single} vs {m_ref}"
    );
    assert!(
        (m_multi - m_ref).abs() <= 0.05 * m_ref.abs().max(1.0),
        "threaded sampler diverged from reference: {m_multi} vs {m_ref}"
    );
    McBenchReport {
        trials,
        threads,
        reference_scalar,
        single_thread,
        multi_thread,
        speedup_block_vs_reference: single_thread.trials_per_sec
            / reference_scalar.trials_per_sec,
        speedup_threads_vs_single: multi_thread.trials_per_sec
            / single_thread.trials_per_sec,
    }
}

/// `Throughput` → JSON object (shared with the `bench-des` harness).
pub(super) fn throughput_json(t: &Throughput) -> Json {
    Json::obj(vec![
        ("trials", (t.trials as i64).into()),
        ("elapsed_s", t.elapsed_s.into()),
        ("trials_per_sec", t.trials_per_sec.into()),
    ])
}

impl McBenchReport {
    /// Serialize to the `BENCH_mc.json` schema (see PERF.md).
    pub fn to_json(&self) -> Json {
        let scn = reference_scenario();
        Json::obj(vec![
            ("version", SCHEMA_VERSION.into()),
            (
                "scenario",
                Json::obj(vec![
                    ("n_workers", scn.n_workers().into()),
                    ("n_batches", scn.assignment.n_batches.into()),
                    ("service", scn.service.spec.name().into()),
                    ("policy", scn.policy.name().into()),
                    ("seed", (scn.seed as i64).into()),
                ]),
            ),
            ("trials", (self.trials as i64).into()),
            ("threads", (self.threads as i64).into()),
            ("reference_scalar", throughput_json(&self.reference_scalar)),
            ("single_thread", throughput_json(&self.single_thread)),
            ("multi_thread", throughput_json(&self.multi_thread)),
            ("speedup_block_vs_reference", self.speedup_block_vs_reference.into()),
            ("speedup_threads_vs_single", self.speedup_threads_vs_single.into()),
        ])
    }

    /// Write the report to `path` (pretty-printing is not needed — the
    /// file is machine-diffed).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Schema check of a `BENCH_mc.json` document: every required key
/// present, every throughput positive and finite. The `bench-mc`
/// subcommand re-reads and validates the file it wrote, so a malformed
/// artifact fails the CI gate.
pub fn validate_json(j: &Json) -> anyhow::Result<()> {
    anyhow::ensure!(
        j.get("version").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "missing or unexpected schema version"
    );
    for key in ["scenario", "trials", "threads"] {
        anyhow::ensure!(j.get(key).is_some(), "missing key '{key}'");
    }
    for key in ["reference_scalar", "single_thread", "multi_thread"] {
        let sec = j.get(key).ok_or_else(|| anyhow::anyhow!("missing section '{key}'"))?;
        let tps = sec
            .get("trials_per_sec")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("section '{key}' missing trials_per_sec"))?;
        anyhow::ensure!(
            tps.is_finite() && tps > 0.0,
            "section '{key}' has nonsensical trials_per_sec {tps}"
        );
    }
    for key in ["speedup_block_vs_reference", "speedup_threads_vs_single"] {
        let v = j
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))?;
        anyhow::ensure!(v.is_finite() && v > 0.0, "nonsensical '{key}' = {v}");
    }
    Ok(())
}

/// Read `path` and [`validate_json`] it.
pub fn validate_file(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        anyhow::anyhow!(
            "reading {}: {e} — regenerate with `batchrep bench-mc --out {}` \
             (baseline workflow in PERF.md)",
            path.display(),
            path.display()
        )
    })?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    validate_json(&j)?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_round_trips_and_validates() {
        let report = run(2_000, 2);
        assert!(report.reference_scalar.trials_per_sec > 0.0);
        assert!(report.single_thread.trials_per_sec > 0.0);
        assert!(report.multi_thread.trials_per_sec > 0.0);
        let j = report.to_json();
        validate_json(&j).unwrap();
        // File round trip.
        let path = std::env::temp_dir().join("batchrep_bench_mc_test.json");
        report.write(&path).unwrap();
        let parsed = validate_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(parsed.get("version").and_then(Json::as_i64), Some(SCHEMA_VERSION));
        assert_eq!(parsed.get("trials").and_then(Json::as_i64), Some(2_000));
    }

    #[test]
    fn validation_rejects_malformed_documents() {
        assert!(validate_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = run(500, 1).to_json();
        validate_json(&j).unwrap();
        if let Json::Object(m) = &mut j {
            m.remove("single_thread");
        }
        assert!(validate_json(&j).is_err());
        // Wrong version is malformed too.
        let bad = Json::parse("{\"version\": 999}").unwrap();
        assert!(validate_json(&bad).is_err());
    }

    #[test]
    fn reference_scenario_is_fig2_scale() {
        let scn = reference_scenario();
        assert_eq!(scn.n_workers(), 24);
        assert_eq!(scn.assignment.n_batches, 4);
        assert_eq!(scn.service.spec.name(), "sexp:1,0.2");
        assert_eq!(scn.seed, 42);
    }
}
