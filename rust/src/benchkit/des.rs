//! DES throughput trajectory harness (the `batchrep bench-des`
//! subcommand).
//!
//! Measures trials/sec of the three event-engine paths — the retained
//! heap + scalar-draw reference
//! ([`crate::des::engine::simulate_many_reference`]), the flat-queue +
//! block-kernel engine, and its multi-threaded sharding — on the same
//! **fixed fig2-scale reference scenario** the `bench-mc` harness uses,
//! under both redundancy activation modes (upfront and speculative
//! relaunch), and writes the result as `BENCH_des.json` at the repo
//! root. The file gives this and every future perf PR a measured
//! baseline to diff against; PERF.md documents the schema and how to
//! rerun.

use super::mc::{reference_scenario, throughput_json, Throughput};
use crate::des::engine::{
    simulate_many, simulate_many_parallel, simulate_many_reference, EngineConfig,
    EngineSummary, Redundancy,
};
use crate::des::Scenario;
use crate::util::json::Json;
use crate::util::Timer;
use std::path::Path;

/// Schema version of `BENCH_des.json`.
pub const SCHEMA_VERSION: i64 = 1;

/// Deadline factor of the speculative measurement config (fixed so the
/// numbers are comparable across PRs).
pub const SPECULATIVE_DEADLINE_FACTOR: f64 = 1.5;

/// The speculative variant of the fixed measurement scenario.
pub fn speculative_scenario() -> Scenario {
    reference_scenario().with_redundancy(Redundancy::Speculative {
        deadline_factor: SPECULATIVE_DEADLINE_FACTOR,
    })
}

/// One redundancy mode's measured engine paths.
#[derive(Debug, Clone, Copy)]
pub struct ModeThroughput {
    /// Retained heap + per-draw scalar engine (the speedup baseline).
    pub reference_scalar: Throughput,
    /// Flat queue + block kernel, single thread.
    pub single_thread: Throughput,
    /// Flat queue + block kernel, `threads`-way sharding.
    pub multi_thread: Throughput,
    /// `single_thread / reference_scalar` throughput ratio.
    pub speedup_flat_vs_reference: f64,
    /// `multi_thread / single_thread` throughput ratio.
    pub speedup_threads_vs_single: f64,
}

/// Full harness result (serialized to `BENCH_des.json`).
#[derive(Debug, Clone)]
pub struct DesBenchReport {
    /// Trials per timed run.
    pub trials: u64,
    /// Threads used by the multi-threaded runs.
    pub threads: usize,
    /// Upfront replication (the paper's model).
    pub upfront: ModeThroughput,
    /// Speculative relaunch (the reactive baseline).
    pub speculative: ModeThroughput,
}

fn measure(trials: u64, f: impl FnOnce() -> EngineSummary) -> (Throughput, f64) {
    let t = Timer::start();
    let sum = f();
    let elapsed_s = t.secs().max(1e-9);
    (
        Throughput { trials, elapsed_s, trials_per_sec: trials as f64 / elapsed_s },
        sum.completion.mean(),
    )
}

/// Measure one redundancy mode: one warmed, timed run per engine path,
/// plus an agreement guard so a broken engine can never report a
/// "speedup". The flat-queue engine is stream-equivalent to the
/// reference (same RNG draws, `fast_ln` rounding only), so their means
/// must agree to 1e-9 relative; the threaded run uses substreams, so it
/// agrees statistically.
fn run_mode(scn: &Scenario, cfg: &EngineConfig, trials: u64, threads: usize) -> ModeThroughput {
    // Warm caches, lazily-grown buffers, and the thread pool costs.
    let _ = simulate_many(scn, cfg, (trials / 10).max(1), 7);
    let (reference_scalar, m_ref) =
        measure(trials, || simulate_many_reference(scn, cfg, trials, scn.seed));
    let (single_thread, m_single) =
        measure(trials, || simulate_many(scn, cfg, trials, scn.seed));
    let (multi_thread, m_multi) =
        measure(trials, || simulate_many_parallel(scn, cfg, trials, scn.seed, threads));
    assert!(
        (m_single - m_ref).abs() <= 1e-9 * m_ref.abs().max(1.0),
        "flat-queue engine diverged from the reference: {m_single} vs {m_ref}"
    );
    assert!(
        (m_multi - m_ref).abs() <= 0.05 * m_ref.abs().max(1.0),
        "threaded engine diverged from the reference: {m_multi} vs {m_ref}"
    );
    ModeThroughput {
        reference_scalar,
        single_thread,
        multi_thread,
        speedup_flat_vs_reference: single_thread.trials_per_sec
            / reference_scalar.trials_per_sec,
        speedup_threads_vs_single: multi_thread.trials_per_sec
            / single_thread.trials_per_sec,
    }
}

/// Run the harness on both redundancy modes of the fixed fig2-scale
/// scenario.
pub fn run(trials: u64, threads: usize) -> DesBenchReport {
    let trials = trials.max(1);
    let threads = threads.max(1);
    let upfront_scn = reference_scenario();
    let upfront = run_mode(&upfront_scn, &EngineConfig::default(), trials, threads);
    let spec_scn = speculative_scenario();
    let spec_cfg = EngineConfig {
        redundancy: Redundancy::Speculative {
            deadline_factor: SPECULATIVE_DEADLINE_FACTOR,
        },
        ..EngineConfig::default()
    };
    let speculative = run_mode(&spec_scn, &spec_cfg, trials, threads);
    DesBenchReport { trials, threads, upfront, speculative }
}

fn mode_json(m: &ModeThroughput) -> Json {
    Json::obj(vec![
        ("reference_scalar", throughput_json(&m.reference_scalar)),
        ("single_thread", throughput_json(&m.single_thread)),
        ("multi_thread", throughput_json(&m.multi_thread)),
        ("speedup_flat_vs_reference", m.speedup_flat_vs_reference.into()),
        ("speedup_threads_vs_single", m.speedup_threads_vs_single.into()),
    ])
}

impl DesBenchReport {
    /// Serialize to the `BENCH_des.json` schema (see PERF.md).
    pub fn to_json(&self) -> Json {
        let scn = reference_scenario();
        Json::obj(vec![
            ("version", SCHEMA_VERSION.into()),
            (
                "scenario",
                Json::obj(vec![
                    ("n_workers", scn.n_workers().into()),
                    ("n_batches", scn.assignment.n_batches.into()),
                    ("service", scn.service.spec.name().into()),
                    ("policy", scn.policy.name().into()),
                    ("seed", (scn.seed as i64).into()),
                    (
                        "speculative_deadline_factor",
                        SPECULATIVE_DEADLINE_FACTOR.into(),
                    ),
                ]),
            ),
            ("trials", (self.trials as i64).into()),
            ("threads", (self.threads as i64).into()),
            ("upfront", mode_json(&self.upfront)),
            ("speculative", mode_json(&self.speculative)),
        ])
    }

    /// Write the report to `path` (machine-diffed, not pretty-printed).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Schema check of a `BENCH_des.json` document: every required key
/// present, every throughput and speedup positive and finite, for both
/// redundancy modes. The `bench-des` subcommand re-reads and validates
/// the file it wrote, so a malformed artifact fails the CI gate.
pub fn validate_json(j: &Json) -> anyhow::Result<()> {
    anyhow::ensure!(
        j.get("version").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "missing or unexpected schema version"
    );
    for key in ["scenario", "trials", "threads"] {
        anyhow::ensure!(j.get(key).is_some(), "missing key '{key}'");
    }
    for mode in ["upfront", "speculative"] {
        let m = j.get(mode).ok_or_else(|| anyhow::anyhow!("missing mode '{mode}'"))?;
        for key in ["reference_scalar", "single_thread", "multi_thread"] {
            let sec = m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("mode '{mode}' missing section '{key}'"))?;
            let tps = sec.get("trials_per_sec").and_then(Json::as_f64).ok_or_else(|| {
                anyhow::anyhow!("{mode}.{key} missing trials_per_sec")
            })?;
            anyhow::ensure!(
                tps.is_finite() && tps > 0.0,
                "{mode}.{key} has nonsensical trials_per_sec {tps}"
            );
        }
        for key in ["speedup_flat_vs_reference", "speedup_threads_vs_single"] {
            let v = m
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("mode '{mode}' missing key '{key}'"))?;
            anyhow::ensure!(v.is_finite() && v > 0.0, "nonsensical '{mode}.{key}' = {v}");
        }
    }
    Ok(())
}

/// Read `path` and [`validate_json`] it.
pub fn validate_file(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        anyhow::anyhow!(
            "reading {}: {e} — regenerate with `batchrep bench-des --out {}` \
             (baseline workflow in PERF.md)",
            path.display(),
            path.display()
        )
    })?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    validate_json(&j)?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_round_trips_and_validates() {
        let report = run(1_000, 2);
        for m in [&report.upfront, &report.speculative] {
            assert!(m.reference_scalar.trials_per_sec > 0.0);
            assert!(m.single_thread.trials_per_sec > 0.0);
            assert!(m.multi_thread.trials_per_sec > 0.0);
        }
        let j = report.to_json();
        validate_json(&j).unwrap();
        // File round trip.
        let path = std::env::temp_dir().join("batchrep_bench_des_test.json");
        report.write(&path).unwrap();
        let parsed = validate_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(parsed.get("version").and_then(Json::as_i64), Some(SCHEMA_VERSION));
        assert_eq!(parsed.get("trials").and_then(Json::as_i64), Some(1_000));
    }

    #[test]
    fn validation_rejects_malformed_documents() {
        assert!(validate_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = run(300, 1).to_json();
        validate_json(&j).unwrap();
        if let Json::Object(m) = &mut j {
            m.remove("speculative");
        }
        assert!(validate_json(&j).is_err());
        // A mode missing one engine path is malformed too.
        let mut j = run(300, 1).to_json();
        if let Json::Object(m) = &mut j {
            if let Some(Json::Object(up)) = m.get_mut("upfront") {
                up.remove("single_thread");
            }
        }
        assert!(validate_json(&j).is_err());
        let bad = Json::parse("{\"version\": 999}").unwrap();
        assert!(validate_json(&bad).is_err());
    }

    #[test]
    fn speculative_scenario_is_the_reference_with_relaunch() {
        let scn = speculative_scenario();
        assert_eq!(scn.n_workers(), 24);
        assert_eq!(scn.assignment.n_batches, 4);
        assert!(matches!(scn.redundancy, Redundancy::Speculative { .. }));
    }
}
