//! Service-time distributions and the size-dependent batch service
//! model (Gardner et al.) the paper builds on.
//!
//! A [`ServiceSpec`] is the per-unit service-time law τ; a
//! [`BatchService`] composes it into the service time of a batch of `s`
//! units under one of three [`BatchModel`]s. The paper's analysis uses
//! the **size-scaled** composition (`s·τ`), under which balanced
//! replication exactly cancels the size penalty — the identity at the
//! heart of Theorems 2–4. The other two models are ablation points.
//!
//! Specs have a compact string form (`exp:1.0`, `sexp:1.0,0.2`,
//! `pareto:0.5,2.2`, `weibull:0.6,1.0`, `det:0.5`, `trace:path.csv`)
//! used by the config system and the CLI.

use crate::util::math::fast_ln;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Per-unit service-time distribution.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceSpec {
    /// Exponential with rate `mu` (mean `1/mu`).
    Exp {
        /// Service rate µ.
        mu: f64,
    },
    /// Shifted-Exponential: `delta + Exp(mu)`.
    ShiftedExp {
        /// Rate of the exponential part.
        mu: f64,
        /// Deterministic shift ∆ ≥ 0.
        delta: f64,
    },
    /// Pareto with scale `xm` and tail index `alpha` (heavy-tailed
    /// robustness case; violates the paper's dec-convex hypothesis).
    Pareto {
        /// Scale (minimum value) x_m > 0.
        xm: f64,
        /// Tail index α > 0.
        alpha: f64,
    },
    /// Weibull with shape `k` and scale `lambda` (k < 1 is heavy-tailed).
    Weibull {
        /// Shape k > 0.
        shape: f64,
        /// Scale λ > 0.
        scale: f64,
    },
    /// Degenerate point mass (zero-randomness baseline and benchmarks).
    Deterministic {
        /// The constant service time.
        value: f64,
    },
    /// Empirical distribution replayed by i.i.d. resampling from a
    /// recorded trace (see [`crate::trace`]).
    Trace {
        /// Recorded per-unit service times.
        samples: Arc<Vec<f64>>,
    },
}

impl ServiceSpec {
    /// Exponential with rate `mu`.
    pub fn exp(mu: f64) -> ServiceSpec {
        assert!(mu > 0.0, "exp rate must be positive");
        ServiceSpec::Exp { mu }
    }

    /// Shifted-Exponential `delta + Exp(mu)`.
    pub fn shifted_exp(mu: f64, delta: f64) -> ServiceSpec {
        assert!(mu > 0.0, "sexp rate must be positive");
        assert!(delta >= 0.0, "sexp shift must be nonnegative");
        ServiceSpec::ShiftedExp { mu, delta }
    }

    /// Pareto with scale `xm` and tail index `alpha`.
    pub fn pareto(xm: f64, alpha: f64) -> ServiceSpec {
        assert!(xm > 0.0 && alpha > 0.0, "pareto parameters must be positive");
        ServiceSpec::Pareto { xm, alpha }
    }

    /// Weibull with shape `shape` and scale `scale`.
    pub fn weibull(shape: f64, scale: f64) -> ServiceSpec {
        assert!(shape > 0.0 && scale > 0.0, "weibull parameters must be positive");
        ServiceSpec::Weibull { shape, scale }
    }

    /// Compact spec string (round-trips through [`ServiceSpec::parse`]
    /// for the parametric families).
    pub fn name(&self) -> String {
        match self {
            ServiceSpec::Exp { mu } => format!("exp:{mu}"),
            ServiceSpec::ShiftedExp { mu, delta } => format!("sexp:{mu},{delta}"),
            ServiceSpec::Pareto { xm, alpha } => format!("pareto:{xm},{alpha}"),
            ServiceSpec::Weibull { shape, scale } => format!("weibull:{shape},{scale}"),
            ServiceSpec::Deterministic { value } => format!("det:{value}"),
            ServiceSpec::Trace { samples } => format!("trace[{} samples]", samples.len()),
        }
    }

    /// Parse a compact spec string: `exp:MU`, `sexp:MU,DELTA`,
    /// `pareto:XM,ALPHA`, `weibull:SHAPE,SCALE`, `det:VALUE`, or
    /// `trace:PATH` (one value per line).
    pub fn parse(s: &str) -> anyhow::Result<ServiceSpec> {
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("service spec '{s}' missing ':' (e.g. sexp:1.0,0.2)"))?;
        let one = || -> anyhow::Result<f64> {
            rest.trim()
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad number in '{s}': {e}"))
        };
        let two = || -> anyhow::Result<(f64, f64)> {
            let (a, b) = rest
                .split_once(',')
                .ok_or_else(|| anyhow::anyhow!("spec '{s}' needs two comma-separated numbers"))?;
            Ok((
                a.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("bad number in '{s}': {e}"))?,
                b.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("bad number in '{s}': {e}"))?,
            ))
        };
        let spec = match kind {
            "exp" => {
                let mu = one()?;
                anyhow::ensure!(mu > 0.0, "exp rate must be positive");
                ServiceSpec::Exp { mu }
            }
            "sexp" => {
                let (mu, delta) = two()?;
                anyhow::ensure!(mu > 0.0 && delta >= 0.0, "need mu > 0, delta >= 0");
                ServiceSpec::ShiftedExp { mu, delta }
            }
            "pareto" => {
                let (xm, alpha) = two()?;
                anyhow::ensure!(xm > 0.0 && alpha > 0.0, "need xm > 0, alpha > 0");
                ServiceSpec::Pareto { xm, alpha }
            }
            "weibull" => {
                let (shape, scale) = two()?;
                anyhow::ensure!(shape > 0.0 && scale > 0.0, "need shape > 0, scale > 0");
                ServiceSpec::Weibull { shape, scale }
            }
            "det" => {
                let value = one()?;
                anyhow::ensure!(value >= 0.0, "deterministic value must be nonnegative");
                ServiceSpec::Deterministic { value }
            }
            "trace" => {
                let samples = crate::trace::load_trace(std::path::Path::new(rest.trim()))?;
                anyhow::ensure!(!samples.is_empty(), "trace file '{rest}' is empty");
                ServiceSpec::Trace { samples: Arc::new(samples) }
            }
            other => anyhow::bail!("unknown service spec kind '{other}'"),
        };
        Ok(spec)
    }

    /// Draw one per-unit service time.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            ServiceSpec::Exp { mu } => -rng.f64_open0().ln() / mu,
            ServiceSpec::ShiftedExp { mu, delta } => delta - rng.f64_open0().ln() / mu,
            ServiceSpec::Pareto { xm, alpha } => xm * rng.f64_open0().powf(-1.0 / alpha),
            ServiceSpec::Weibull { shape, scale } => {
                scale * (-rng.f64_open0().ln()).powf(1.0 / shape)
            }
            ServiceSpec::Deterministic { value } => *value,
            ServiceSpec::Trace { samples } => samples[rng.below(samples.len() as u64) as usize],
        }
    }

    /// Mean per-unit service time; `None` when infinite/undefined
    /// (Pareto with α ≤ 1).
    pub fn mean(&self) -> Option<f64> {
        match self {
            ServiceSpec::Exp { mu } => Some(1.0 / mu),
            ServiceSpec::ShiftedExp { mu, delta } => Some(delta + 1.0 / mu),
            ServiceSpec::Pareto { xm, alpha } => {
                (*alpha > 1.0).then(|| xm * alpha / (alpha - 1.0))
            }
            ServiceSpec::Weibull { shape, scale } => Some(scale * gamma(1.0 + 1.0 / shape)),
            ServiceSpec::Deterministic { value } => Some(*value),
            ServiceSpec::Trace { samples } => {
                Some(samples.iter().sum::<f64>() / samples.len() as f64)
            }
        }
    }

    /// Fill `out` with i.i.d. per-unit service draws — the block form of
    /// [`ServiceSpec::sample`].
    ///
    /// **Stream semantics:** consumes exactly the same RNG stream as
    /// `out.len()` successive [`ServiceSpec::sample`] calls (same number
    /// and order of raw draws), so scalar and block paths are seed-
    /// compatible. Values agree with the scalar path to ≤ 1e-14 relative
    /// (the log-based families apply the vectorizable
    /// [`crate::util::math::fast_ln`] instead of libm `ln`);
    /// `Deterministic` and `Trace` are bit-identical.
    ///
    /// The uniform draw and the transform run as separate passes over
    /// the slice so the transform loop is free of RNG state dependencies
    /// and can vectorize.
    pub fn fill_times(&self, out: &mut [f64], rng: &mut Rng) {
        match self {
            ServiceSpec::Exp { mu } => {
                rng.fill_f64_open0(out);
                for x in out.iter_mut() {
                    *x = -fast_ln(*x) / mu;
                }
            }
            ServiceSpec::ShiftedExp { mu, delta } => {
                rng.fill_f64_open0(out);
                for x in out.iter_mut() {
                    *x = delta - fast_ln(*x) / mu;
                }
            }
            ServiceSpec::Pareto { xm, alpha } => {
                rng.fill_f64_open0(out);
                let inv_alpha = -1.0 / alpha;
                for x in out.iter_mut() {
                    *x = xm * x.powf(inv_alpha);
                }
            }
            ServiceSpec::Weibull { shape, scale } => {
                rng.fill_f64_open0(out);
                let inv_shape = 1.0 / shape;
                for x in out.iter_mut() {
                    *x = scale * (-fast_ln(*x)).powf(inv_shape);
                }
            }
            ServiceSpec::Deterministic { value } => out.fill(*value),
            ServiceSpec::Trace { samples } => {
                for x in out.iter_mut() {
                    *x = samples[rng.below(samples.len() as u64) as usize];
                }
            }
        }
    }

    /// `(mu, delta)` when this spec is in the exponential family the
    /// paper's closed forms cover (∆ = 0 for plain Exponential).
    pub fn exp_family(&self) -> Option<(f64, f64)> {
        match self {
            ServiceSpec::Exp { mu } => Some((*mu, 0.0)),
            ServiceSpec::ShiftedExp { mu, delta } => Some((*mu, *delta)),
            _ => None,
        }
    }

    /// Infimum of the support (the deterministic part of the service).
    pub fn shift(&self) -> f64 {
        match self {
            ServiceSpec::ShiftedExp { delta, .. } => *delta,
            ServiceSpec::Pareto { xm, .. } => *xm,
            ServiceSpec::Deterministic { value } => *value,
            _ => 0.0,
        }
    }
}

/// How per-unit service composes into the service time of an `s`-unit
/// batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchModel {
    /// `T_batch = s·τ` — one slowdown draw scales the whole batch (the
    /// paper/Gardner model; the worker is slow or fast for the entire
    /// job).
    SizeScaled,
    /// `T_batch = (s−1)·shift + τ` — the data-proportional work is
    /// deterministic and the random contention tail is independent of
    /// batch size.
    DecoupledSlowdown,
    /// `T_batch = Σ_{i=1..s} τ_i` — independent per-sample draws
    /// (averaging weakens the diversity gain).
    PerSampleSum,
}

impl BatchModel {
    /// Table/config identifier.
    pub fn name(&self) -> &'static str {
        match self {
            BatchModel::SizeScaled => "size_scaled",
            BatchModel::DecoupledSlowdown => "decoupled_slowdown",
            BatchModel::PerSampleSum => "per_sample_sum",
        }
    }

    /// Parse from config string.
    pub fn parse(s: &str) -> anyhow::Result<BatchModel> {
        Ok(match s {
            "size_scaled" => BatchModel::SizeScaled,
            "decoupled_slowdown" => BatchModel::DecoupledSlowdown,
            "per_sample_sum" => BatchModel::PerSampleSum,
            _ => anyhow::bail!("unknown batch model '{s}'"),
        })
    }
}

/// A per-unit service law plus a composition model: the complete batch
/// service-time description a scenario carries.
#[derive(Debug, Clone)]
pub struct BatchService {
    /// Per-unit service-time distribution.
    pub spec: ServiceSpec,
    /// Composition model.
    pub model: BatchModel,
}

impl BatchService {
    /// The paper's model: size-scaled composition.
    pub fn paper(spec: ServiceSpec) -> BatchService {
        BatchService { spec, model: BatchModel::SizeScaled }
    }

    /// Draw the service time of one `s`-unit batch on one worker.
    #[inline]
    pub fn sample_batch(&self, s: u64, rng: &mut Rng) -> f64 {
        let sf = s as f64;
        match self.model {
            BatchModel::SizeScaled => sf * self.spec.sample(rng),
            BatchModel::DecoupledSlowdown => {
                (sf - 1.0).max(0.0) * self.spec.shift() + self.spec.sample(rng)
            }
            BatchModel::PerSampleSum => (0..s).map(|_| self.spec.sample(rng)).sum(),
        }
    }

    /// Fill `out` with i.i.d. `s`-unit batch service draws — the block
    /// form of [`BatchService::sample_batch`], and the kernel under the
    /// Monte-Carlo hot path.
    ///
    /// **Stream semantics:** consumes exactly the same RNG stream as
    /// `out.len()` successive `sample_batch` calls; values agree with
    /// the scalar path to ≤ 1e-14 relative (see
    /// [`ServiceSpec::fill_times`] for the `fast_ln` caveat).
    pub fn fill_batch_times(&self, s: u64, out: &mut [f64], rng: &mut Rng) {
        let sf = s as f64;
        match self.model {
            BatchModel::SizeScaled => {
                self.spec.fill_times(out, rng);
                for x in out.iter_mut() {
                    *x *= sf;
                }
            }
            BatchModel::DecoupledSlowdown => {
                self.spec.fill_times(out, rng);
                let base = (sf - 1.0).max(0.0) * self.spec.shift();
                for x in out.iter_mut() {
                    *x += base;
                }
            }
            BatchModel::PerSampleSum => {
                // Each output consumes `s` sequential per-unit draws, as
                // the scalar path does; no block transform applies.
                for x in out.iter_mut() {
                    *x = (0..s).map(|_| self.spec.sample(rng)).sum();
                }
            }
        }
    }

    /// Mean batch service time; `None` when the per-unit mean is
    /// infinite.
    pub fn batch_mean(&self, s: u64) -> Option<f64> {
        let m = self.spec.mean()?;
        let sf = s as f64;
        Some(match self.model {
            BatchModel::SizeScaled | BatchModel::PerSampleSum => sf * m,
            BatchModel::DecoupledSlowdown => (sf - 1.0).max(0.0) * self.spec.shift() + m,
        })
    }
}

/// Gamma function via the Lanczos approximation (g = 7, n = 9); used for
/// the Weibull mean. Accurate to ~1e-13 over the range we need (x > 0).
fn gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        return std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x));
    }
    let x = x - 1.0;
    let mut a = C[0];
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    let t = x + G + 0.5;
    (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_known_values() {
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
        assert!((gamma(1.0) - 1.0).abs() < 1e-12);
        assert!((gamma(5.0) - 24.0).abs() < 1e-9);
    }

    #[test]
    fn parse_round_trips() {
        for s in ["exp:1.5", "sexp:1,0.2", "pareto:0.5,2.2", "weibull:0.6,1", "det:0.25"] {
            let spec = ServiceSpec::parse(s).unwrap();
            let again = ServiceSpec::parse(&spec.name()).unwrap();
            assert_eq!(spec, again, "{s}");
        }
        assert!(ServiceSpec::parse("exp").is_err());
        assert!(ServiceSpec::parse("exp:-1").is_err());
        assert!(ServiceSpec::parse("sexp:1").is_err());
        assert!(ServiceSpec::parse("mystery:1").is_err());
    }

    #[test]
    fn sample_means_match_theory() {
        let mut rng = Rng::new(7);
        let specs = [
            ServiceSpec::exp(2.0),
            ServiceSpec::shifted_exp(1.0, 0.5),
            ServiceSpec::pareto(0.5, 2.5),
            ServiceSpec::weibull(1.5, 1.0),
            ServiceSpec::Deterministic { value: 0.75 },
        ];
        for spec in &specs {
            let n = 200_000;
            let mean: f64 = (0..n).map(|_| spec.sample(&mut rng)).sum::<f64>() / n as f64;
            let theory = spec.mean().unwrap();
            assert!(
                (mean - theory).abs() < 0.02 * theory.max(0.1),
                "{}: empirical {mean} vs theory {theory}",
                spec.name()
            );
        }
    }

    #[test]
    fn fill_times_means_and_variances_match_theory() {
        // Block-sampler statistical gate, in the style of
        // sample_means_match_theory: empirical mean within 2% and (for
        // the families with a simple second moment) variance within 5%.
        let mut rng = Rng::new(19);
        let n = 200_000usize;
        let mut buf = vec![0.0f64; n];
        // (spec, theoretical variance)
        let cases = [
            (ServiceSpec::exp(2.0), Some(0.25)),
            (ServiceSpec::shifted_exp(1.0, 0.5), Some(1.0)),
            (ServiceSpec::pareto(0.5, 2.5), None),
            (ServiceSpec::weibull(1.5, 1.0), None),
            (ServiceSpec::Deterministic { value: 0.75 }, Some(0.0)),
        ];
        for (spec, var_theory) in &cases {
            spec.fill_times(&mut buf, &mut rng);
            let mean = buf.iter().sum::<f64>() / n as f64;
            let theory = spec.mean().unwrap();
            assert!(
                (mean - theory).abs() < 0.02 * theory.max(0.1),
                "{}: empirical mean {mean} vs theory {theory}",
                spec.name()
            );
            if let Some(v) = var_theory {
                let var =
                    buf.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
                assert!(
                    (var - v).abs() < 0.05 * v.max(0.05),
                    "{}: empirical var {var} vs theory {v}",
                    spec.name()
                );
            }
        }
    }

    #[test]
    fn fill_times_matches_scalar_stream() {
        // The rustdoc contract: same RNG consumption as repeated scalar
        // sample() calls, values equal to ≤ 1e-14 relative.
        let specs = [
            ServiceSpec::exp(1.5),
            ServiceSpec::shifted_exp(2.0, 0.3),
            ServiceSpec::pareto(0.5, 2.2),
            ServiceSpec::weibull(0.6, 1.0),
            ServiceSpec::Deterministic { value: 0.25 },
            ServiceSpec::Trace { samples: Arc::new(vec![1.0, 2.0, 3.0]) },
        ];
        for spec in &specs {
            let mut block_rng = Rng::new(77);
            let mut scalar_rng = Rng::new(77);
            let mut block = vec![0.0f64; 503];
            spec.fill_times(&mut block, &mut block_rng);
            for (i, b) in block.iter().enumerate() {
                let s = spec.sample(&mut scalar_rng);
                assert!(
                    (b - s).abs() <= 1e-14 * s.abs().max(1e-14),
                    "{} draw {i}: block {b} vs scalar {s}",
                    spec.name()
                );
            }
            // Both generators consumed the same stream.
            assert_eq!(block_rng.next_u64(), scalar_rng.next_u64(), "{}", spec.name());
        }
    }

    #[test]
    fn fill_batch_times_matches_scalar_stream_across_models() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        for model in
            [BatchModel::SizeScaled, BatchModel::DecoupledSlowdown, BatchModel::PerSampleSum]
        {
            let svc = BatchService { spec: spec.clone(), model };
            let mut block_rng = Rng::new(31);
            let mut scalar_rng = Rng::new(31);
            let mut block = vec![0.0f64; 200];
            svc.fill_batch_times(4, &mut block, &mut block_rng);
            for (i, b) in block.iter().enumerate() {
                let s = svc.sample_batch(4, &mut scalar_rng);
                assert!(
                    (b - s).abs() <= 1e-13 * s.abs().max(1e-13),
                    "{} draw {i}: block {b} vs scalar {s}",
                    model.name()
                );
            }
            assert_eq!(block_rng.next_u64(), scalar_rng.next_u64(), "{}", model.name());
        }
    }

    #[test]
    fn fill_batch_times_mean_matches_batch_mean() {
        let mut rng = Rng::new(8);
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let mut buf = vec![0.0f64; 100_000];
        for model in
            [BatchModel::SizeScaled, BatchModel::DecoupledSlowdown, BatchModel::PerSampleSum]
        {
            let svc = BatchService { spec: spec.clone(), model };
            svc.fill_batch_times(4, &mut buf, &mut rng);
            let mean = buf.iter().sum::<f64>() / buf.len() as f64;
            let theory = svc.batch_mean(4).unwrap();
            assert!(
                (mean - theory).abs() < 0.03 * theory,
                "{}: {mean} vs {theory}",
                model.name()
            );
        }
    }

    #[test]
    fn samples_are_positive_and_shifted() {
        let mut rng = Rng::new(3);
        let sexp = ServiceSpec::shifted_exp(1.0, 0.4);
        let par = ServiceSpec::pareto(0.7, 2.0);
        for _ in 0..10_000 {
            assert!(sexp.sample(&mut rng) >= 0.4);
            assert!(par.sample(&mut rng) >= 0.7);
        }
    }

    #[test]
    fn pareto_heavy_tail_has_no_mean() {
        assert!(ServiceSpec::pareto(1.0, 0.9).mean().is_none());
        assert!(ServiceSpec::pareto(1.0, 1.1).mean().is_some());
    }

    #[test]
    fn exp_family_extraction() {
        assert_eq!(ServiceSpec::exp(2.0).exp_family(), Some((2.0, 0.0)));
        assert_eq!(ServiceSpec::shifted_exp(1.0, 0.3).exp_family(), Some((1.0, 0.3)));
        assert_eq!(ServiceSpec::pareto(1.0, 2.0).exp_family(), None);
    }

    #[test]
    fn trace_resamples_recorded_values() {
        let spec = ServiceSpec::Trace { samples: Arc::new(vec![1.0, 2.0, 3.0]) };
        let mut rng = Rng::new(11);
        for _ in 0..100 {
            let x = spec.sample(&mut rng);
            assert!(x == 1.0 || x == 2.0 || x == 3.0);
        }
        assert!((spec.mean().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn batch_models_compose() {
        let mut rng = Rng::new(5);
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let n = 100_000;
        for model in
            [BatchModel::SizeScaled, BatchModel::DecoupledSlowdown, BatchModel::PerSampleSum]
        {
            let svc = BatchService { spec: spec.clone(), model };
            let mean: f64 =
                (0..n).map(|_| svc.sample_batch(4, &mut rng)).sum::<f64>() / n as f64;
            let theory = svc.batch_mean(4).unwrap();
            assert!(
                (mean - theory).abs() < 0.03 * theory,
                "{}: {mean} vs {theory}",
                model.name()
            );
        }
        // Size-scaled and per-sample-sum share the mean but not the law.
        let paper = BatchService::paper(spec.clone());
        assert_eq!(paper.batch_mean(4), Some(4.0 * 1.2));
        let dec = BatchService { spec, model: BatchModel::DecoupledSlowdown };
        assert!((dec.batch_mean(4).unwrap() - (3.0 * 0.2 + 1.2)).abs() < 1e-12);
    }

    #[test]
    fn batch_mean_none_for_heavy_tails() {
        let svc = BatchService::paper(ServiceSpec::pareto(1.0, 0.8));
        assert!(svc.batch_mean(4).is_none());
    }
}
