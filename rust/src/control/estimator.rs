//! Censoring-aware online estimation of service-time parameters.
//!
//! Replicated execution with cancellation observes the service-time
//! distribution through a censoring lens: the **winner** of each batch
//! contributes an exact per-unit service sample, while every cancelled
//! sibling contributes only a *right-censored* observation ("its service
//! time exceeds the elapsed time at cancellation"). Throwing the
//! censored replicas away would bias the fit — the winner of `g`
//! replicas is the minimum of `g` draws, systematically faster than the
//! distribution it came from. The censored-MLE likelihood restores
//! exactly the information the cancellation destroyed: one exact sample
//! plus `g − 1` censored-at-the-winner samples is the same likelihood
//! as `g` i.i.d. draws observed through right censoring.
//!
//! The accumulator is streaming: it keeps only the exact/censored
//! counts, a compensated (Kahan) running sum of all observed times, and
//! the minimum exact observation — O(1) state, mergeable in spirit with
//! the crate's Welford accumulators. Closed forms:
//!
//! * **Exponential(µ)** — the classic censored-data MLE
//!   `µ̂ = d / Σtᵢ` (exact events over total time on test), valid for
//!   *any* right-censoring pattern.
//! * **Shifted-Exponential(µ, ∆)** — `∆̂` anchors on the minimum exact
//!   observation `m`; the rate is fit on the excess time
//!   `S = Σtᵢ − n·m` with the standard one-event bias correction
//!   `µ̂ = (d − 1)/S`, and `∆̂ = m − 1/(n·µ̂)` corrects the minimum's
//!   own upward bias (`E[m − ∆] ≈ 1/(n·µ)` because the per-unit
//!   censoring times never undercut `m` in this telemetry: a cancelled
//!   replica is censored at its batch winner's exact time, which is
//!   itself ≥ `m`).
//!
//! Confidence intervals come from the observed Fisher information of
//! the censored likelihood: `Var[ln µ̂] ≈ 1/d`, so the µ band is
//! `µ̂·e^{±z/√d}`; the ∆ band has half-width `z/(n·µ̂)` (the scale of
//! `m − ∆`).

use crate::dist::ServiceSpec;
use crate::util::stats::Kahan;

/// One per-unit service-time observation from a single replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    /// Observed per-unit time: the replica's full service time when
    /// `exact`, else the elapsed per-unit time at which it was
    /// cancelled (a lower bound on its service time).
    pub t: f64,
    /// `true` — the replica finished (exact sample); `false` — it was
    /// cancelled at `t` (right-censored).
    pub exact: bool,
}

impl Observation {
    /// An exact (uncensored) sample.
    pub fn exact(t: f64) -> Self {
        Self { t, exact: true }
    }

    /// A right-censored sample (service time exceeds `t`).
    pub fn censored(t: f64) -> Self {
        Self { t, exact: false }
    }
}

/// Which exponential-family shape the estimator fits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitKind {
    /// Exponential(µ) — ∆ pinned to 0.
    Exp,
    /// Shifted-Exponential(µ, ∆).
    ShiftedExp,
}

impl FitKind {
    /// Stable name (round-trips through [`FitKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            FitKind::Exp => "exp",
            FitKind::ShiftedExp => "sexp",
        }
    }

    /// Parse a [`FitKind::name`] string.
    pub fn parse(s: &str) -> anyhow::Result<FitKind> {
        match s {
            "exp" => Ok(FitKind::Exp),
            "sexp" => Ok(FitKind::ShiftedExp),
            other => anyhow::bail!("unknown fit kind '{other}' (expected exp|sexp)"),
        }
    }
}

/// Streaming sufficient statistics of the censored exponential-family
/// MLE. O(1) state; push order does not matter.
#[derive(Debug, Clone)]
pub struct CensoredAccumulator {
    n_exact: u64,
    n_censored: u64,
    sum_t: Kahan,
    min_exact: f64,
}

impl Default for CensoredAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl CensoredAccumulator {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n_exact: 0, n_censored: 0, sum_t: Kahan::new(), min_exact: f64::INFINITY }
    }

    /// Add one observation. Non-finite or negative times are ignored
    /// (they carry no likelihood information and would poison the sums).
    pub fn push(&mut self, obs: Observation) {
        if !obs.t.is_finite() || obs.t < 0.0 {
            return;
        }
        self.sum_t.add(obs.t);
        if obs.exact {
            self.n_exact += 1;
            self.min_exact = self.min_exact.min(obs.t);
        } else {
            self.n_censored += 1;
        }
    }

    /// Number of exact (uncensored) observations.
    pub fn n_exact(&self) -> u64 {
        self.n_exact
    }

    /// Number of right-censored observations.
    pub fn n_censored(&self) -> u64 {
        self.n_censored
    }

    /// Total observations of either kind.
    pub fn n_total(&self) -> u64 {
        self.n_exact + self.n_censored
    }

    /// Total observed time (exact + censored), the "time on test".
    pub fn observed_time(&self) -> f64 {
        self.sum_t.sum()
    }

    /// Fit the censored MLE at confidence multiplier `z` (e.g. 4.0).
    /// Returns `None` until at least two exact observations with
    /// positive excess time are available.
    pub fn fit(&self, kind: FitKind, z: f64) -> Option<FittedSpec> {
        let d = self.n_exact;
        if d < 2 {
            return None;
        }
        let total = self.sum_t.sum();
        let df = d as f64;
        match kind {
            FitKind::Exp => {
                if total <= 0.0 {
                    return None;
                }
                let mu = df / total;
                let band = (z / df.sqrt()).exp();
                Some(FittedSpec {
                    kind,
                    mu,
                    mu_lo: mu / band,
                    mu_hi: mu * band,
                    delta: 0.0,
                    delta_lo: 0.0,
                    delta_hi: 0.0,
                    n_exact: d,
                    n_censored: self.n_censored,
                })
            }
            FitKind::ShiftedExp => {
                let n_all = self.n_total() as f64;
                let m = self.min_exact;
                // Excess time on test beyond the anchored shift. Every
                // censoring time in this telemetry is a batch winner's
                // exact time, so the subtraction never goes negative up
                // to rounding; a non-positive excess means the data are
                // still degenerate (e.g. deterministic-looking).
                let excess = total - n_all * m;
                if excess <= 0.0 {
                    return None;
                }
                // One-event bias correction: the minimum observation
                // contributes zero excess by construction.
                let mu = (df - 1.0) / excess;
                let band = (z / (df - 1.0).sqrt()).exp();
                let half = z / (n_all * mu);
                let delta = (m - 1.0 / (n_all * mu)).max(0.0);
                Some(FittedSpec {
                    kind,
                    mu,
                    mu_lo: mu / band,
                    mu_hi: mu * band,
                    delta,
                    delta_lo: (delta - half).max(0.0),
                    delta_hi: delta + half,
                    n_exact: d,
                    n_censored: self.n_censored,
                })
            }
        }
    }
}

/// A fitted exponential-family spec with confidence bands.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FittedSpec {
    /// Shape that was fit.
    pub kind: FitKind,
    /// Rate point estimate.
    pub mu: f64,
    /// Lower end of the µ confidence band.
    pub mu_lo: f64,
    /// Upper end of the µ confidence band.
    pub mu_hi: f64,
    /// Shift point estimate (0 for [`FitKind::Exp`]).
    pub delta: f64,
    /// Lower end of the ∆ confidence band.
    pub delta_lo: f64,
    /// Upper end of the ∆ confidence band.
    pub delta_hi: f64,
    /// Exact observations behind the fit.
    pub n_exact: u64,
    /// Censored observations behind the fit.
    pub n_censored: u64,
}

impl FittedSpec {
    /// Wrap a known (prior) spec as a zero-width "fit" — the controller
    /// seeds its plan with this before any telemetry arrives. `None`
    /// when the spec is not in the exponential family.
    pub fn from_prior(kind: FitKind, spec: &ServiceSpec) -> Option<FittedSpec> {
        let (mu, delta) = spec.exp_family()?;
        let delta = match kind {
            FitKind::Exp => 0.0,
            FitKind::ShiftedExp => delta,
        };
        Some(FittedSpec {
            kind,
            mu,
            mu_lo: mu,
            mu_hi: mu,
            delta,
            delta_lo: delta,
            delta_hi: delta,
            n_exact: 0,
            n_censored: 0,
        })
    }

    /// The point-estimate service spec.
    pub fn spec(&self) -> ServiceSpec {
        match self.kind {
            FitKind::Exp => ServiceSpec::exp(self.mu),
            FitKind::ShiftedExp => {
                if self.delta > 0.0 {
                    ServiceSpec::shifted_exp(self.mu, self.delta)
                } else {
                    ServiceSpec::exp(self.mu)
                }
            }
        }
    }

    /// Mean of the fitted per-unit service time.
    pub fn mean(&self) -> f64 {
        self.delta + 1.0 / self.mu
    }

    /// Does this fit's confidence band contain the point `(µ, ∆)`?
    pub fn covers(&self, mu: f64, delta: f64) -> bool {
        let mu_in = (self.mu_lo..=self.mu_hi).contains(&mu);
        let delta_in = delta >= self.delta_lo - 1e-12 && delta <= self.delta_hi + 1e-12;
        mu_in && delta_in
    }

    /// Two fits disagree when **neither** band covers the other's point
    /// estimate — the symmetric exit-the-confidence-band test the
    /// controller uses as its replan trigger. (One-sided containment is
    /// treated as agreement so a tightening band does not spuriously
    /// reject the plan it was built from.)
    pub fn disagrees(&self, other: &FittedSpec) -> bool {
        !self.covers(other.mu, other.delta) && !other.covers(self.mu, self.delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Simulate the telemetry of `batches` replicated batches with `g`
    /// replicas each: per batch the winner is exact, siblings are
    /// censored at the winner's time — censoring fraction (g−1)/g.
    fn feed_replicated(
        acc: &mut CensoredAccumulator,
        spec: &ServiceSpec,
        g: usize,
        batches: usize,
        rng: &mut Rng,
    ) {
        for _ in 0..batches {
            let mut win = f64::INFINITY;
            for _ in 0..g {
                win = win.min(spec.sample(rng));
            }
            acc.push(Observation::exact(win));
            for _ in 1..g {
                acc.push(Observation::censored(win));
            }
        }
    }

    #[test]
    fn exp_mle_recovers_planted_mu_at_several_censoring_fractions() {
        let mu = 2.3;
        let spec = ServiceSpec::exp(mu);
        for (i, g) in [1usize, 2, 4, 8].into_iter().enumerate() {
            let mut rng = Rng::new(100 + i as u64);
            let mut acc = CensoredAccumulator::new();
            feed_replicated(&mut acc, &spec, g, 4000, &mut rng);
            let fit = acc.fit(FitKind::Exp, 4.0).expect("fit");
            assert_eq!(fit.n_exact, 4000);
            assert_eq!(fit.n_censored, 4000 * (g as u64 - 1));
            let rel = (fit.mu - mu).abs() / mu;
            assert!(rel < 0.08, "g={g} mu_hat={} rel={rel}", fit.mu);
            assert!(
                fit.covers(mu, 0.0),
                "g={g} band [{}, {}] misses planted mu={mu}",
                fit.mu_lo,
                fit.mu_hi
            );
        }
    }

    #[test]
    fn exp_mle_handles_fixed_deadline_censoring() {
        // The time-on-test estimator is valid for any right-censoring
        // pattern, not just winner-censoring: censor at a fixed
        // deadline chosen for ~50% censoring.
        let mu = 1.4;
        let spec = ServiceSpec::exp(mu);
        let deadline = std::f64::consts::LN_2 / mu; // median
        let mut rng = Rng::new(7);
        let mut acc = CensoredAccumulator::new();
        for _ in 0..8000 {
            let t = spec.sample(&mut rng);
            if t <= deadline {
                acc.push(Observation::exact(t));
            } else {
                acc.push(Observation::censored(deadline));
            }
        }
        let fit = acc.fit(FitKind::Exp, 4.0).expect("fit");
        let rel = (fit.mu - mu).abs() / mu;
        assert!(rel < 0.06, "mu_hat={} rel={rel}", fit.mu);
        assert!(fit.covers(mu, 0.0));
    }

    #[test]
    fn sexp_mle_recovers_planted_mu_and_delta() {
        let (mu, delta) = (1.7, 0.4);
        let spec = ServiceSpec::shifted_exp(mu, delta);
        for (i, g) in [1usize, 2, 4].into_iter().enumerate() {
            let mut rng = Rng::new(200 + i as u64);
            let mut acc = CensoredAccumulator::new();
            feed_replicated(&mut acc, &spec, g, 6000, &mut rng);
            let fit = acc.fit(FitKind::ShiftedExp, 4.0).expect("fit");
            let rel_mu = (fit.mu - mu).abs() / mu;
            let rel_delta = (fit.delta - delta).abs() / delta;
            assert!(rel_mu < 0.08, "g={g} mu_hat={} rel={rel_mu}", fit.mu);
            assert!(rel_delta < 0.02, "g={g} delta_hat={} rel={rel_delta}", fit.delta);
            assert!(
                fit.covers(mu, delta),
                "g={g} bands mu=[{}, {}] delta=[{}, {}] miss ({mu}, {delta})",
                fit.mu_lo,
                fit.mu_hi,
                fit.delta_lo,
                fit.delta_hi
            );
        }
    }

    #[test]
    fn sexp_consistency_band_shrinks_with_data() {
        let spec = ServiceSpec::shifted_exp(2.0, 0.25);
        let mut rng = Rng::new(9);
        let mut acc = CensoredAccumulator::new();
        feed_replicated(&mut acc, &spec, 2, 500, &mut rng);
        let narrow_at_500 = acc.fit(FitKind::ShiftedExp, 4.0).expect("fit").mu_hi;
        feed_replicated(&mut acc, &spec, 2, 7500, &mut rng);
        let fit = acc.fit(FitKind::ShiftedExp, 4.0).expect("fit");
        assert!(fit.mu_hi - fit.mu_lo < narrow_at_500 - fit.mu_lo);
        assert!(fit.covers(2.0, 0.25));
    }

    #[test]
    fn fit_degenerate_inputs_return_none() {
        let mut acc = CensoredAccumulator::new();
        assert!(acc.fit(FitKind::Exp, 4.0).is_none());
        acc.push(Observation::exact(1.0));
        assert!(acc.fit(FitKind::Exp, 4.0).is_none(), "one exact obs is not enough");
        acc.push(Observation::exact(1.0));
        // Two identical exact observations: zero excess, SExp undefined.
        assert!(acc.fit(FitKind::ShiftedExp, 4.0).is_none());
        assert!(acc.fit(FitKind::Exp, 4.0).is_some());
        // Garbage observations are ignored, not accumulated.
        acc.push(Observation::exact(f64::NAN));
        acc.push(Observation::censored(-1.0));
        assert_eq!(acc.n_total(), 2);
    }

    #[test]
    fn prior_wrapping_and_disagreement() {
        let prior =
            FittedSpec::from_prior(FitKind::ShiftedExp, &ServiceSpec::shifted_exp(4.0, 0.8))
                .expect("exp-family prior");
        assert_eq!(prior.mu, 4.0);
        assert!(prior.covers(4.0, 0.8));
        assert!(!prior.covers(1.0, 0.2));
        assert!(FittedSpec::from_prior(FitKind::Exp, &ServiceSpec::pareto(1.0, 2.5)).is_none());

        // A tight fit far from the prior disagrees; near the prior it
        // does not.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let mut rng = Rng::new(3);
        let mut acc = CensoredAccumulator::new();
        for _ in 0..4000 {
            acc.push(Observation::exact(spec.sample(&mut rng)));
        }
        let fit = acc.fit(FitKind::ShiftedExp, 4.0).expect("fit");
        assert!(fit.disagrees(&prior));
        let near =
            FittedSpec::from_prior(FitKind::ShiftedExp, &ServiceSpec::shifted_exp(fit.mu, fit.delta))
                .expect("exp-family");
        assert!(!fit.disagrees(&near));
    }
}
