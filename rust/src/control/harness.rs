//! Closed-loop harness: run the controller against a hidden (and
//! optionally time-varying) true service spec and measure regret
//! against the oracle plan.
//!
//! Each **replicate** simulates `epochs × rounds_per_epoch` rounds of
//! replicated execution at the replica level, with exactly the DES
//! upfront-cancellation semantics: per batch the `g = N/B` replicas
//! draw i.i.d. per-unit service times from the *true* spec, the
//! earliest replica wins (exact observation), the siblings are
//! cancelled at the winner's time (right-censored observations), and
//! the round completes at the slowest batch winner (size-scaled,
//! `s·τ`). The controller sees only the telemetry — never the true
//! spec — and closes each epoch with a [`Controller::step`].
//!
//! **Regret** is scored analytically: at every epoch the objective
//! score of the batch count the controller actually ran, evaluated
//! under the *true* spec via the `analysis` closed forms, minus the
//! oracle score (the best feasible batch count under the same true
//! spec). Relative regret divides by the oracle score.
//!
//! Replicates fan out over the crate's fixed 64-shard plan
//! ([`crate::des::montecarlo`]): shard RNG substreams and per-shard
//! replicate counts depend only on `(replicates, seed)`, and results
//! merge in shard-index order, so a report is **bit-identical for any
//! thread count** — pinned by a test below, mirroring the study
//! engine's cross-thread equality test.

use super::controller::{Action, ControlDecision, Controller, ControllerConfig};
use super::estimator::Observation;
use super::report::{ControlReport, EpochAgg};
use super::ControlSpec;
use crate::des::montecarlo::{execute_shard_plan, shard_plan};
use crate::dist::ServiceSpec;
use crate::util::rng::Rng;
use crate::util::stats::Welford;

/// One stationary segment of the hidden truth.
#[derive(Debug, Clone, PartialEq)]
pub struct ServicePhase {
    /// First epoch (inclusive) this spec is in force.
    pub start_epoch: u64,
    /// True per-unit service spec during the phase.
    pub spec: ServiceSpec,
}

/// Piecewise-stationary hidden truth: the spec in force at an epoch is
/// the last phase starting at or before it.
#[derive(Debug, Clone, PartialEq)]
pub struct TrueService {
    phases: Vec<ServicePhase>,
}

impl TrueService {
    /// A single stationary phase.
    pub fn stationary(spec: ServiceSpec) -> anyhow::Result<TrueService> {
        TrueService::piecewise(vec![ServicePhase { start_epoch: 0, spec }])
    }

    /// Validate and wrap a phase list. Phases must start at epoch 0,
    /// be strictly increasing, and be exp-family (the oracle scores
    /// them through the closed forms).
    pub fn piecewise(phases: Vec<ServicePhase>) -> anyhow::Result<TrueService> {
        anyhow::ensure!(!phases.is_empty(), "need at least one service phase");
        anyhow::ensure!(phases[0].start_epoch == 0, "first phase must start at epoch 0");
        for w in phases.windows(2) {
            anyhow::ensure!(
                w[0].start_epoch < w[1].start_epoch,
                "phase starts must be strictly increasing"
            );
        }
        for p in &phases {
            anyhow::ensure!(
                p.spec.exp_family().is_some(),
                "true service must be exp/sexp (oracle uses closed forms), got {}",
                p.spec.name()
            );
        }
        Ok(TrueService { phases })
    }

    /// The spec in force at `epoch`.
    pub fn at(&self, epoch: u64) -> &ServiceSpec {
        let mut cur = &self.phases[0].spec;
        for p in &self.phases {
            if p.start_epoch <= epoch {
                cur = &p.spec;
            }
        }
        cur
    }

    /// The phase list.
    pub fn phases(&self) -> &[ServicePhase] {
        &self.phases
    }
}

/// Per-epoch record of one replicate.
struct EpochRec {
    /// Batch count actually run during the epoch.
    b: usize,
    /// Oracle batch count under the true spec.
    oracle_b: usize,
    /// Objective score gap vs the oracle (≥ 0 up to rounding).
    regret: f64,
    /// Regret divided by the oracle score.
    rel_regret: f64,
    /// Mean realized completion time over the epoch's rounds.
    realized_mean: f64,
    /// The decision that closed the epoch.
    action: Action,
}

/// One replicate's full trajectory.
struct ReplicateRun {
    epochs: Vec<EpochRec>,
    decisions: Vec<ControlDecision>,
}

/// One round of replicated execution at the replica level: feeds the
/// controller winner/censored telemetry and returns the realized
/// completion time (size-scaled max of batch winners).
fn run_round(truth: &ServiceSpec, c: &mut Controller, n: usize, rng: &mut Rng) -> f64 {
    let b = c.current_b();
    let g = n / b;
    let s = (n / b) as f64; // balanced: batch size == replication degree
    let mut slowest = 0.0f64;
    for _ in 0..b {
        let mut win = f64::INFINITY;
        for _ in 0..g {
            win = win.min(truth.sample(rng));
        }
        slowest = slowest.max(s * win);
        c.observe(Observation::exact(win));
        for _ in 1..g {
            c.observe(Observation::censored(win));
        }
    }
    slowest
}

/// Run one closed-loop replicate: the controller starts from the
/// (possibly mis-specified) prior and adapts to the hidden truth.
fn run_replicate(
    spec: &ControlSpec,
    truth: &TrueService,
    rng: &mut Rng,
) -> anyhow::Result<ReplicateRun> {
    let n = spec.n_workers;
    let cfg = ControllerConfig::new(
        n,
        spec.kind,
        spec.objective.clone(),
        spec.prior.clone(),
    );
    let mut c = Controller::new(cfg)?;
    let mut epochs = Vec::with_capacity(spec.epochs as usize);
    for epoch in 0..spec.epochs {
        let true_spec = truth.at(epoch);
        let b = c.current_b();
        let mut realized = Welford::new();
        for _ in 0..spec.rounds_per_epoch {
            realized.push(run_round(true_spec, &mut c, n, rng));
        }
        let oracle = super::controller::plan(n, true_spec, &spec.objective)?;
        let score = spec.objective.score(n as u64, b as u64, true_spec)?;
        let decision = c.step(epoch)?;
        epochs.push(EpochRec {
            b,
            oracle_b: oracle.b,
            regret: score - oracle.score,
            rel_regret: (score - oracle.score) / oracle.score,
            realized_mean: realized.mean(),
            action: decision.action,
        });
    }
    Ok(ReplicateRun { epochs, decisions: c.decisions().to_vec() })
}

/// Run the full closed-loop study: `spec.replicates` independent
/// replicates over the fixed shard plan, aggregated per epoch.
/// Bit-deterministic per seed for any `threads`.
pub fn run_loop(spec: &ControlSpec, threads: usize) -> anyhow::Result<ControlReport> {
    spec.validate()?;
    let truth = TrueService::piecewise(spec.phases.clone())?;
    let shards = shard_plan(spec.replicates, spec.seed);
    let per_shard: Vec<anyhow::Result<Vec<ReplicateRun>>> = execute_shard_plan(
        shards,
        threads,
        || (),
        |_, count, mut rng| (0..count).map(|_| run_replicate(spec, &truth, &mut rng)).collect(),
    );
    let mut runs: Vec<ReplicateRun> = Vec::with_capacity(spec.replicates as usize);
    for shard in per_shard {
        runs.extend(shard?);
    }
    anyhow::ensure!(!runs.is_empty(), "control loop needs at least one replicate");

    let mut epochs = Vec::with_capacity(spec.epochs as usize);
    for e in 0..spec.epochs as usize {
        let mut regret = Welford::new();
        let mut rel = Welford::new();
        let mut realized = Welford::new();
        let mut b_mean = Welford::new();
        let (mut hits, mut replans, mut drift_replans) = (0u64, 0u64, 0u64);
        for run in &runs {
            let r = &run.epochs[e];
            regret.push(r.regret);
            rel.push(r.rel_regret);
            realized.push(r.realized_mean);
            b_mean.push(r.b as f64);
            hits += u64::from(r.b == r.oracle_b);
            match r.action {
                Action::Hold => {}
                Action::Replan => replans += 1,
                Action::DriftReplan => drift_replans += 1,
            }
        }
        epochs.push(EpochAgg {
            epoch: e as u64,
            oracle_b: runs[0].epochs[e].oracle_b,
            mean_b: b_mean.mean(),
            frac_oracle: hits as f64 / runs.len() as f64,
            mean_regret: regret.mean(),
            sem_regret: regret.sem(),
            mean_rel_regret: rel.mean(),
            mean_realized: realized.mean(),
            replans,
            drift_replans,
        });
    }
    let (final_frac_oracle, final_mean_rel_regret) =
        epochs.last().map(|a| (a.frac_oracle, a.mean_rel_regret)).unwrap_or((0.0, 0.0));
    Ok(ControlReport {
        name: spec.name.clone(),
        seed: spec.seed,
        n_workers: spec.n_workers,
        objective: spec.objective.name(),
        kind: spec.kind.name().to_string(),
        prior: spec.prior.name(),
        phases: truth
            .phases()
            .iter()
            .map(|p| (p.start_epoch, p.spec.name()))
            .collect(),
        replicates: spec.replicates,
        rounds_per_epoch: spec.rounds_per_epoch,
        epochs,
        decisions: runs[0].decisions.clone(),
        final_frac_oracle,
        final_mean_rel_regret,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::optimum_b;

    #[test]
    fn true_service_phase_lookup_and_validation() {
        let ts = TrueService::piecewise(vec![
            ServicePhase { start_epoch: 0, spec: ServiceSpec::exp(1.0) },
            ServicePhase { start_epoch: 5, spec: ServiceSpec::exp(2.0) },
        ])
        .expect("valid");
        assert_eq!(ts.at(0).name(), "exp:1");
        assert_eq!(ts.at(4).name(), "exp:1");
        assert_eq!(ts.at(5).name(), "exp:2");
        assert_eq!(ts.at(99).name(), "exp:2");
        assert!(TrueService::piecewise(vec![]).is_err());
        assert!(TrueService::piecewise(vec![ServicePhase {
            start_epoch: 1,
            spec: ServiceSpec::exp(1.0)
        }])
        .is_err());
        assert!(TrueService::stationary(ServiceSpec::pareto(1.0, 2.5)).is_err());
    }

    #[test]
    fn smoke_loop_converges_to_oracle_plan() {
        let spec = ControlSpec::smoke();
        let report = run_loop(&spec, 2).expect("run");
        let truth = spec.phases[0].spec.clone();
        let oracle = optimum_b(spec.n_workers as u64, &truth).unwrap() as usize;
        let last = report.epochs.last().expect("epochs");
        assert_eq!(last.oracle_b, oracle);
        assert!(
            last.frac_oracle >= 0.75,
            "final frac_oracle = {} (oracle B = {oracle})",
            last.frac_oracle
        );
        assert!(
            last.mean_rel_regret < 0.05,
            "final mean relative regret = {}",
            last.mean_rel_regret
        );
        // The mis-specified prior causes real regret in epoch 0.
        assert!(report.epochs[0].mean_regret > 10.0 * last.mean_regret.max(1e-9));
        super::report::validate_json(&report.to_json()).expect("self-validates");
    }

    #[test]
    fn drift_loop_reconverges_after_shift() {
        let spec = ControlSpec::drift().fast();
        let report = run_loop(&spec, 2).expect("run");
        let shift = spec.phases[1].start_epoch as usize;
        let pre = &report.epochs[shift - 1];
        let at = &report.epochs[shift];
        let last = report.epochs.last().expect("epochs");
        // Converged before the shift, regret spikes at the shift epoch
        // (the plan in force was tuned to the old truth), and the
        // controller re-converges by the end.
        assert!(pre.frac_oracle >= 0.75, "pre-shift frac={}", pre.frac_oracle);
        assert!(at.mean_regret > 5.0 * pre.mean_regret.max(1e-9));
        assert!(last.frac_oracle >= 0.75, "final frac={}", last.frac_oracle);
        assert!(last.mean_rel_regret < 0.05, "final rel regret={}", last.mean_rel_regret);
        let drift_replans: u64 = report.epochs.iter().map(|a| a.drift_replans).sum();
        assert!(drift_replans >= report.replicates / 2, "drift replans={drift_replans}");
    }

    #[test]
    fn report_is_bit_deterministic_for_any_thread_count() {
        let spec = ControlSpec::smoke().fast();
        let reference = run_loop(&spec, 1).expect("run").to_json().to_string();
        for threads in [2usize, 4] {
            let got = run_loop(&spec, threads).expect("run").to_json().to_string();
            assert_eq!(got, reference, "threads={threads} diverged");
        }
    }

    #[test]
    fn different_seeds_differ_but_same_seed_repeats() {
        let spec = ControlSpec::smoke().fast();
        let a = run_loop(&spec, 2).expect("run").to_json().to_string();
        let b = run_loop(&spec, 2).expect("run").to_json().to_string();
        assert_eq!(a, b);
        let mut other = spec.clone();
        other.seed ^= 1;
        let c = run_loop(&other, 2).expect("run").to_json().to_string();
        assert_ne!(a, c);
    }
}
