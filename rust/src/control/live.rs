//! Live closed-loop glue: run the adaptive controller against the
//! **real thread-backed coordinator** instead of the simulated round
//! loop in [`super::harness`].
//!
//! Each epoch runs `rounds_per_epoch` live rounds on a Mock-backend
//! [`Coordinator`], drains the per-replica winner/censored telemetry
//! with [`Coordinator::take_round_observations`], normalizes it by the
//! batch size (live draws are size-scaled; the controller fits the
//! per-unit law), and closes the epoch with a [`Controller::step`].
//! When the controller re-plans — or a hidden-truth phase boundary
//! changes the service law — the cluster is rebuilt at the new batch
//! count, exactly what a deployed System1 would do.
//!
//! A [`FaultPlan`] can be installed on the live cluster (the CLI's
//! `control --live --fault <plan>`): a scheduled slowdown then shifts
//! the *observed* law mid-run, exercising the CUSUM drift detector on
//! telemetry from an actually-drifting live system rather than a
//! synthetic sampler. Rebuilds restart the plan's round clock (a fresh
//! cluster starts at round 0) and resurrect every worker.
//!
//! One replicate only — the run drives real OS threads, so this is the
//! `--live` spot-check behind the bit-deterministic simulated study,
//! not a Monte-Carlo harness. Regret is scored analytically against
//! the oracle plan, same as [`super::run_loop`].

use super::controller::{plan, Action, Controller, ControllerConfig};
use super::estimator::Observation;
use super::harness::TrueService;
use super::report::{ControlReport, EpochAgg};
use super::ControlSpec;
use crate::config::SystemConfig;
use crate::coordinator::{Backend, Coordinator};
use crate::dist::ServiceSpec;
use crate::fault::FaultPlan;
use crate::util::rng::splitmix64;
use crate::worker::JobSpec;
use std::sync::Arc;

/// Injected-seconds-per-unit scale: small enough that live control
/// runs finish in seconds, large enough that sleeps dominate thread
/// scheduling jitter (same clamp the conformance live cells use).
fn live_time_scale(service: &ServiceSpec) -> f64 {
    (0.004 / service.mean()).clamp(0.0008, 0.02)
}

/// Build a fresh live cluster for one control segment: `b` batches of
/// the hidden-truth service law, with the fault plan (if any)
/// reinstalled so its schedule restarts with the new cluster.
fn build_cluster(
    spec: &ControlSpec,
    service: &ServiceSpec,
    b: usize,
    rebuilds: u64,
    fault: Option<&FaultPlan>,
) -> anyhow::Result<Coordinator> {
    let cfg = SystemConfig {
        n_workers: spec.n_workers,
        n_batches: b,
        service: service.clone(),
        seed: spec.seed ^ splitmix64(rebuilds),
        time_scale: live_time_scale(service),
        n_samples: 64,
        dim: 4,
        ..SystemConfig::default()
    };
    let mut coord = Coordinator::new(cfg, Backend::Mock)?;
    if let Some(p) = fault {
        coord.install_fault_plan(p)?;
    }
    Ok(coord)
}

/// Run the closed loop against the live coordinator (see module docs).
/// Returns the same [`ControlReport`] artifact as the simulated study,
/// with `replicates = 1`.
pub fn run_live(spec: &ControlSpec, fault: Option<&FaultPlan>) -> anyhow::Result<ControlReport> {
    spec.validate()?;
    if let Some(p) = fault {
        p.validate(spec.n_workers)?;
    }
    let truth = TrueService::piecewise(spec.phases.clone())?;
    let n = spec.n_workers;
    let mut c = Controller::new(ControllerConfig::new(
        n,
        spec.kind,
        spec.objective.clone(),
        spec.prior.clone(),
    ))?;

    let mut cur_spec = truth.at(0).clone();
    let mut cur_b = c.current_b();
    let mut rebuilds = 0u64;
    let mut coord = build_cluster(spec, &cur_spec, cur_b, rebuilds, fault)?;
    let mut epochs = Vec::with_capacity(spec.epochs as usize);
    for epoch in 0..spec.epochs {
        let true_spec = truth.at(epoch);
        if *true_spec != cur_spec || c.current_b() != cur_b {
            cur_spec = true_spec.clone();
            cur_b = c.current_b();
            rebuilds += 1;
            coord.shutdown();
            coord = build_cluster(spec, &cur_spec, cur_b, rebuilds, fault)?;
        }
        let b = cur_b;
        let time_scale = live_time_scale(&cur_spec);
        let rec_base = coord.metrics.len();
        for _ in 0..spec.rounds_per_epoch {
            coord.run_round(JobSpec::Grad { w: Arc::new(vec![0f32; 4]) })?;
            // Live draws are size-scaled (`s` units per batch); the
            // controller fits the per-unit law. A degraded re-plan can
            // change the batch size mid-epoch, so recompute per round.
            let s = (n / coord.assignment().n_batches) as f64;
            c.observe_all(
                coord
                    .take_round_observations()
                    .into_iter()
                    .map(|o| Observation { t: o.t / s, exact: o.exact }),
            );
        }
        let realized_mean = coord.metrics.records()[rec_base..]
            .iter()
            .map(|r| r.injected_s / time_scale)
            .sum::<f64>()
            / spec.rounds_per_epoch as f64;
        let oracle = plan(n, true_spec, &spec.objective)?;
        let score = spec.objective.score(n as u64, b as u64, true_spec)?;
        let decision = c.step(epoch)?;
        let (mut replans, mut drift_replans) = (0u64, 0u64);
        match decision.action {
            Action::Hold => {}
            Action::Replan => replans = 1,
            Action::DriftReplan => drift_replans = 1,
        }
        epochs.push(EpochAgg {
            epoch,
            oracle_b: oracle.b,
            mean_b: b as f64,
            frac_oracle: f64::from(u8::from(b == oracle.b)),
            mean_regret: score - oracle.score,
            sem_regret: 0.0,
            mean_rel_regret: (score - oracle.score) / oracle.score,
            mean_realized: realized_mean,
            replans,
            drift_replans,
        });
    }
    coord.shutdown();

    let (final_frac_oracle, final_mean_rel_regret) =
        epochs.last().map(|a| (a.frac_oracle, a.mean_rel_regret)).unwrap_or((0.0, 0.0));
    Ok(ControlReport {
        name: spec.name.clone(),
        seed: spec.seed,
        n_workers: spec.n_workers,
        objective: spec.objective.name(),
        kind: spec.kind.name().to_string(),
        prior: spec.prior.name(),
        phases: truth.phases().iter().map(|p| (p.start_epoch, p.spec.name())).collect(),
        replicates: 1,
        rounds_per_epoch: spec.rounds_per_epoch,
        epochs,
        decisions: c.decisions().to_vec(),
        final_frac_oracle,
        final_mean_rel_regret,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultEvent;
    use crate::trace::MarkovTraceParams;

    fn tiny_spec() -> ControlSpec {
        let mut spec = ControlSpec::smoke();
        spec.n_workers = 6;
        spec.epochs = 3;
        spec.rounds_per_epoch = 6;
        spec.replicates = 1;
        spec
    }

    #[test]
    fn live_loop_produces_a_valid_control_artifact() {
        let report = run_live(&tiny_spec(), None).expect("run");
        assert_eq!(report.epochs.len(), 3);
        assert_eq!(report.replicates, 1);
        assert!(!report.decisions.is_empty());
        super::super::report::validate_json(&report.to_json()).expect("schema-valid");
    }

    #[test]
    fn installed_slowdown_shifts_the_observed_live_law() {
        let spec = tiny_spec();
        // Every worker congested from round 0: the live telemetry —
        // and therefore the realized completions — must reflect the
        // injected drift, not the nominal service law.
        let slow = FaultPlan {
            name: "all-slow".into(),
            seed: 7,
            events: (0..spec.n_workers)
                .map(|w| {
                    (
                        w,
                        FaultEvent::Slowdown {
                            from_round: 0,
                            rounds: 10_000,
                            params: MarkovTraceParams {
                                p_enter: 1.0,
                                p_exit: 1e-9,
                                ..MarkovTraceParams::default()
                            },
                        },
                    )
                })
                .collect(),
        };
        let base = run_live(&spec, None).expect("base run");
        let slowed = run_live(&spec, Some(&slow)).expect("slowed run");
        let m_base = base.epochs[0].mean_realized;
        let m_slow = slowed.epochs[0].mean_realized;
        assert!(
            m_slow > 2.0 * m_base,
            "slowdown did not shift the live law: {m_slow} vs {m_base}"
        );
        super::super::report::validate_json(&slowed.to_json()).expect("schema-valid");
    }
}
