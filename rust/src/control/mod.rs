//! Adaptive redundancy control: online service-time estimation and
//! closed-loop re-planning.
//!
//! The rest of the crate answers "given (µ, ∆), what is the optimal
//! replication level?" — this module closes the loop for the practical
//! question "what if the parameters are unknown, or change under your
//! feet?". It ties three pieces together:
//!
//! * [`estimator`] — censoring-aware streaming MLE over per-replica
//!   telemetry (winners are exact samples, cancelled replicas are
//!   right-censored), with confidence bands;
//! * [`controller`] — a declarative [`Objective`] (mean / variance /
//!   λ-blend / quantile) optimized over the `analysis` closed forms,
//!   a two-sided CUSUM drift detector, and the replan policy emitting
//!   a structured [`ControlDecision`] log;
//! * [`harness`] — the closed-loop study: the controller runs against
//!   a hidden, optionally time-varying true spec, fanned over the
//!   crate's fixed 64-shard plan so results are bit-deterministic per
//!   seed for any thread count, measuring **regret** vs the oracle
//!   plan; results land in the versioned `CONTROL_*.json` artifact
//!   ([`report`]).
//!
//! Entry points: [`ControlSpec::load`] (preset name or spec JSON) and
//! [`ControlSpec::run`]; the CLI wraps them as `batchrep control`.

pub mod controller;
pub mod estimator;
pub mod harness;
pub mod live;
pub mod report;

pub use controller::{plan, Action, ControlDecision, Controller, ControllerConfig, Objective, Plan};
pub use estimator::{CensoredAccumulator, FitKind, FittedSpec, Observation};
pub use harness::{run_loop, ServicePhase, TrueService};
pub use live::run_live;
pub use report::{validate_file, validate_json, ControlReport, EpochAgg, SCHEMA_VERSION};

use crate::dist::ServiceSpec;
use crate::util::json::Json;

/// Declarative description of one closed-loop control run.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlSpec {
    /// Name (artifact stem).
    pub name: String,
    /// Cluster size `N`.
    pub n_workers: usize,
    /// Which exponential-family shape the controller fits.
    pub kind: FitKind,
    /// What the controller minimizes.
    pub objective: Objective,
    /// The controller's prior spec — deliberately allowed to be wrong.
    pub prior: ServiceSpec,
    /// Hidden-truth phases (epoch-indexed, first must start at 0).
    pub phases: Vec<ServicePhase>,
    /// Control epochs per replicate.
    pub epochs: u64,
    /// Rounds simulated per epoch.
    pub rounds_per_epoch: u64,
    /// Independent replicates (fanned over the 64-shard plan).
    pub replicates: u64,
    /// Root seed.
    pub seed: u64,
}

impl ControlSpec {
    /// Names accepted by [`ControlSpec::preset`].
    pub fn preset_names() -> &'static [&'static str] {
        &["smoke", "drift"]
    }

    /// Look up a built-in preset.
    pub fn preset(name: &str) -> Option<ControlSpec> {
        match name {
            "smoke" => Some(ControlSpec::smoke()),
            "drift" => Some(ControlSpec::drift()),
            _ => None,
        }
    }

    /// Stationary convergence preset: the prior (µ=4, ∆=0.8, ∆µ=3.2)
    /// plans full parallelism, the truth (µ=1, ∆=0.2) wants B*=3 of
    /// N=12 — the controller must walk the plan across the paper's
    /// ∆µ crossover from telemetry alone.
    pub fn smoke() -> ControlSpec {
        ControlSpec {
            name: "smoke".into(),
            n_workers: 12,
            kind: FitKind::ShiftedExp,
            objective: Objective::Mean,
            prior: ServiceSpec::shifted_exp(4.0, 0.8),
            phases: vec![ServicePhase {
                start_epoch: 0,
                spec: ServiceSpec::shifted_exp(1.0, 0.2),
            }],
            epochs: 10,
            rounds_per_epoch: 30,
            replicates: 16,
            seed: 42,
        }
    }

    /// Drift preset: truth starts at ∆µ=1.0 (oracle: full parallelism,
    /// B*=N) and shifts mid-run to ∆µ=0.02 (oracle: full replication,
    /// B*=1) — the two ends of the diversity–parallelism spectrum. The
    /// CUSUM must catch the shift and re-plan from post-change data.
    pub fn drift() -> ControlSpec {
        ControlSpec {
            name: "drift".into(),
            n_workers: 24,
            kind: FitKind::ShiftedExp,
            objective: Objective::Mean,
            prior: ServiceSpec::shifted_exp(2.0, 0.1),
            phases: vec![
                ServicePhase { start_epoch: 0, spec: ServiceSpec::shifted_exp(1.0, 1.0) },
                ServicePhase { start_epoch: 12, spec: ServiceSpec::shifted_exp(1.0, 0.02) },
            ],
            epochs: 24,
            rounds_per_epoch: 40,
            replicates: 32,
            seed: 42,
        }
    }

    /// Shrink budgets for smoke-test/CI latency (epochs are kept so
    /// phase structure — e.g. the drift shift — survives).
    pub fn fast(mut self) -> ControlSpec {
        self.replicates = self.replicates.min(8);
        self.rounds_per_epoch = self.rounds_per_epoch.min(16);
        self
    }

    /// Resolve a CLI argument: a preset name, else a path to a spec
    /// JSON file (see [`ControlSpec::from_json`] for the format).
    pub fn load(which: &str) -> anyhow::Result<ControlSpec> {
        if let Some(spec) = ControlSpec::preset(which) {
            return Ok(spec);
        }
        let text = std::fs::read_to_string(which).map_err(|e| {
            anyhow::anyhow!(
                "'{which}' is not a preset ({}) and not a readable file: {e}",
                ControlSpec::preset_names().join("|")
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {which}: {e}"))?;
        let mut spec = ControlSpec::from_json(&j)?;
        if spec.name.is_empty() {
            spec.name = std::path::Path::new(which)
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("custom")
                .to_string();
        }
        Ok(spec)
    }

    /// Parse a spec object:
    ///
    /// ```json
    /// {
    ///   "name": "custom",
    ///   "n_workers": 12,
    ///   "kind": "sexp",
    ///   "objective": "mean",
    ///   "prior": "sexp:4,0.8",
    ///   "phases": [{"start_epoch": 0, "spec": "sexp:1,0.2"}],
    ///   "epochs": 10,
    ///   "rounds_per_epoch": 30,
    ///   "replicates": 16,
    ///   "seed": 42
    /// }
    /// ```
    ///
    /// `name` and `seed` are optional (default: file stem, 42).
    pub fn from_json(j: &Json) -> anyhow::Result<ControlSpec> {
        let int = |key: &str| -> anyhow::Result<u64> {
            j.get(key)
                .and_then(Json::as_i64)
                .filter(|v| *v >= 1)
                .map(|v| v as u64)
                .ok_or_else(|| anyhow::anyhow!("control spec needs positive integer '{key}'"))
        };
        let text = |key: &str| -> anyhow::Result<&str> {
            j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("control spec needs string '{key}'"))
        };
        let phases_j = j
            .get("phases")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow::anyhow!("control spec needs array 'phases'"))?;
        let mut phases = Vec::with_capacity(phases_j.len());
        for (i, p) in phases_j.iter().enumerate() {
            let start = p
                .get("start_epoch")
                .and_then(Json::as_i64)
                .filter(|v| *v >= 0)
                .ok_or_else(|| anyhow::anyhow!("phase {i} needs integer 'start_epoch'"))?;
            let spec_name = p
                .get("spec")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("phase {i} needs string 'spec'"))?;
            phases.push(ServicePhase {
                start_epoch: start as u64,
                spec: ServiceSpec::parse(spec_name)?,
            });
        }
        let spec = ControlSpec {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            n_workers: int("n_workers")? as usize,
            kind: FitKind::parse(text("kind")?)?,
            objective: Objective::parse(text("objective")?)?,
            prior: ServiceSpec::parse(text("prior")?)?,
            phases,
            epochs: int("epochs")?,
            rounds_per_epoch: int("rounds_per_epoch")?,
            replicates: int("replicates")?,
            seed: j.get("seed").and_then(Json::as_i64).map(|s| s as u64).unwrap_or(42),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural validation (also run by [`run_loop`]).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "need at least one worker");
        anyhow::ensure!(self.epochs >= 1, "need at least one epoch");
        anyhow::ensure!(self.rounds_per_epoch >= 1, "need at least one round per epoch");
        anyhow::ensure!(self.replicates >= 1, "need at least one replicate");
        anyhow::ensure!(
            self.prior.exp_family().is_some(),
            "controller prior must be exp/sexp, got {}",
            self.prior.name()
        );
        let truth = TrueService::piecewise(self.phases.clone())?;
        for p in truth.phases() {
            anyhow::ensure!(
                p.start_epoch < self.epochs,
                "phase starting at epoch {} is beyond the {}-epoch run",
                p.start_epoch,
                self.epochs
            );
        }
        Ok(())
    }

    /// Run the closed loop; see [`run_loop`].
    pub fn run(&self, threads: usize) -> anyhow::Result<ControlReport> {
        run_loop(self, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_validate() {
        for name in ControlSpec::preset_names() {
            let spec = ControlSpec::preset(name).expect("preset");
            assert_eq!(&spec.name, name);
            spec.validate().expect("valid");
            spec.fast().validate().expect("fast stays valid");
        }
        assert!(ControlSpec::preset("nope").is_none());
        assert!(ControlSpec::load("nope").is_err());
    }

    #[test]
    fn spec_json_round_trip() {
        let j = Json::parse(
            r#"{
                "name": "custom", "n_workers": 12, "kind": "sexp",
                "objective": "blend:0.5", "prior": "sexp:4,0.8",
                "phases": [
                    {"start_epoch": 0, "spec": "sexp:1,0.2"},
                    {"start_epoch": 4, "spec": "exp:2"}
                ],
                "epochs": 8, "rounds_per_epoch": 10, "replicates": 4, "seed": 7
            }"#,
        )
        .expect("json");
        let spec = ControlSpec::from_json(&j).expect("spec");
        assert_eq!(spec.name, "custom");
        assert_eq!(spec.objective, Objective::Blend { lambda: 0.5 });
        assert_eq!(spec.phases.len(), 2);
        assert_eq!(spec.phases[1].spec.name(), "exp:2");
        assert_eq!(spec.seed, 7);
    }

    #[test]
    fn spec_json_rejects_malformed() {
        let base = r#"{
            "n_workers": 12, "kind": "sexp", "objective": "mean",
            "prior": "sexp:4,0.8",
            "phases": [{"start_epoch": 0, "spec": "sexp:1,0.2"}],
            "epochs": 8, "rounds_per_epoch": 10, "replicates": 4
        }"#;
        // The base parses (name/seed optional).
        let spec = ControlSpec::from_json(&Json::parse(base).expect("json")).expect("spec");
        assert_eq!(spec.seed, 42);
        for broken in [
            base.replace("\"kind\": \"sexp\"", "\"kind\": \"pareto\""),
            base.replace("\"objective\": \"mean\"", "\"objective\": \"p99\""),
            base.replace("\"prior\": \"sexp:4,0.8\"", "\"prior\": \"pareto:1,2.5\""),
            base.replace("\"start_epoch\": 0", "\"start_epoch\": 3"),
            base.replace("\"epochs\": 8", "\"epochs\": 0"),
        ] {
            let j = Json::parse(&broken).expect("json");
            assert!(ControlSpec::from_json(&j).is_err(), "accepted: {broken}");
        }
        // A phase starting beyond the run is rejected by validate().
        let late = base.replace("\"epochs\": 8", "\"epochs\": 8, \"extra\": 0").replace(
            "{\"start_epoch\": 0, \"spec\": \"sexp:1,0.2\"}",
            "{\"start_epoch\": 0, \"spec\": \"sexp:1,0.2\"}, {\"start_epoch\": 9, \"spec\": \"exp:1\"}",
        );
        let j = Json::parse(&late).expect("json");
        assert!(ControlSpec::from_json(&j).is_err());
    }
}
