//! The `CONTROL_*.json` artifact: a versioned, schema-validated record
//! of one closed-loop control run — per-epoch regret aggregates across
//! replicates plus the first replicate's full decision log.
//!
//! Follows the crate's artifact idiom (`study::report`): an explicit
//! `version` field, a [`validate_json`] that checks structure *and*
//! internal consistency (counters vs arrays, finite stats), and a
//! [`validate_file`] the CLI runs on the artifact it just wrote — a
//! malformed artifact is an error, not a warning.

use super::controller::{Action, ControlDecision};
use crate::util::json::Json;
use std::path::Path;

/// Artifact schema version.
pub const SCHEMA_VERSION: i64 = 1;

/// Per-epoch aggregate across replicates.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochAgg {
    /// Epoch index.
    pub epoch: u64,
    /// Oracle batch count under the true spec in force.
    pub oracle_b: usize,
    /// Mean batch count the replicates actually ran.
    pub mean_b: f64,
    /// Fraction of replicates running exactly the oracle batch count.
    pub frac_oracle: f64,
    /// Mean objective regret vs the oracle.
    pub mean_regret: f64,
    /// Standard error of the regret mean.
    pub sem_regret: f64,
    /// Mean relative regret (regret / oracle score).
    pub mean_rel_regret: f64,
    /// Mean realized completion time over the epoch's rounds.
    pub mean_realized: f64,
    /// Replicates that replanned (band exit / argmin move) this epoch.
    pub replans: u64,
    /// Replicates that drift-replanned this epoch.
    pub drift_replans: u64,
}

impl EpochAgg {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", (self.epoch as i64).into()),
            ("oracle_b", self.oracle_b.into()),
            ("mean_b", self.mean_b.into()),
            ("frac_oracle", self.frac_oracle.into()),
            ("mean_regret", self.mean_regret.into()),
            ("sem_regret", self.sem_regret.into()),
            ("mean_rel_regret", self.mean_rel_regret.into()),
            ("mean_realized", self.mean_realized.into()),
            ("replans", (self.replans as i64).into()),
            ("drift_replans", (self.drift_replans as i64).into()),
        ])
    }
}

/// Result of one closed-loop control run (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ControlReport {
    /// Spec name (preset or file stem).
    pub name: String,
    /// Root seed of the shard plan.
    pub seed: u64,
    /// Cluster size `N`.
    pub n_workers: usize,
    /// Objective name ([`super::Objective::name`]).
    pub objective: String,
    /// Fit kind name (`exp` | `sexp`).
    pub kind: String,
    /// The controller's (mis-specified) prior spec name.
    pub prior: String,
    /// Hidden-truth phases as `(start_epoch, spec_name)`.
    pub phases: Vec<(u64, String)>,
    /// Replicates run.
    pub replicates: u64,
    /// Rounds simulated per epoch.
    pub rounds_per_epoch: u64,
    /// Per-epoch aggregates, one per epoch in order.
    pub epochs: Vec<EpochAgg>,
    /// Decision log of the first replicate (shard 0, replicate 0).
    pub decisions: Vec<ControlDecision>,
    /// `frac_oracle` of the final epoch.
    pub final_frac_oracle: f64,
    /// `mean_rel_regret` of the final epoch.
    pub final_mean_rel_regret: f64,
}

impl ControlReport {
    /// Serialize to the versioned artifact schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", SCHEMA_VERSION.into()),
            ("name", self.name.as_str().into()),
            ("seed", (self.seed as i64).into()),
            ("n_workers", self.n_workers.into()),
            ("objective", self.objective.as_str().into()),
            ("kind", self.kind.as_str().into()),
            ("prior", self.prior.as_str().into()),
            (
                "phases",
                Json::Array(
                    self.phases
                        .iter()
                        .map(|(start, spec)| {
                            Json::obj(vec![
                                ("start_epoch", (*start as i64).into()),
                                ("spec", spec.as_str().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("replicates", (self.replicates as i64).into()),
            ("rounds_per_epoch", (self.rounds_per_epoch as i64).into()),
            ("epochs", Json::Array(self.epochs.iter().map(EpochAgg::to_json).collect())),
            ("decisions", Json::Array(self.decisions.iter().map(ControlDecision::to_json).collect())),
            ("final_frac_oracle", self.final_frac_oracle.into()),
            ("final_mean_rel_regret", self.final_mean_rel_regret.into()),
        ])
    }

    /// Write the artifact (newline-terminated canonical JSON).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Validate a control artifact: schema version, required keys, finite
/// per-epoch stats, well-formed decision log, and summary fields
/// consistent with the final epoch entry.
pub fn validate_json(j: &Json) -> anyhow::Result<()> {
    anyhow::ensure!(
        j.get("version").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "missing or unexpected control schema version"
    );
    for key in ["name", "seed", "objective", "kind", "prior", "replicates", "rounds_per_epoch"] {
        anyhow::ensure!(j.get(key).is_some(), "missing key '{key}'");
    }
    let n_workers = j
        .get("n_workers")
        .and_then(Json::as_i64)
        .ok_or_else(|| anyhow::anyhow!("missing 'n_workers'"))?;
    anyhow::ensure!(n_workers >= 1, "n_workers must be >= 1");
    let phases = j
        .get("phases")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'phases'"))?;
    anyhow::ensure!(!phases.is_empty(), "artifact has no service phases");
    for (i, p) in phases.iter().enumerate() {
        anyhow::ensure!(
            p.get("start_epoch").and_then(Json::as_i64).is_some_and(|s| s >= 0),
            "phase {i} missing 'start_epoch'"
        );
        anyhow::ensure!(p.get("spec").and_then(Json::as_str).is_some(), "phase {i} missing 'spec'");
    }
    let epochs = j
        .get("epochs")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'epochs'"))?;
    anyhow::ensure!(!epochs.is_empty(), "artifact has no epochs");
    for (i, e) in epochs.iter().enumerate() {
        anyhow::ensure!(
            e.get("epoch").and_then(Json::as_i64) == Some(i as i64),
            "epoch entry {i} out of order"
        );
        anyhow::ensure!(
            e.get("oracle_b").and_then(Json::as_i64).is_some_and(|b| b >= 1),
            "epoch {i} missing 'oracle_b'"
        );
        for stat in
            ["mean_b", "frac_oracle", "mean_regret", "sem_regret", "mean_rel_regret", "mean_realized"]
        {
            let v = e
                .get(stat)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("epoch {i} missing '{stat}'"))?;
            anyhow::ensure!(v.is_finite(), "epoch {i} has non-finite '{stat}' = {v}");
        }
        let frac = e.get("frac_oracle").and_then(Json::as_f64).unwrap_or(f64::NAN);
        anyhow::ensure!((0.0..=1.0).contains(&frac), "epoch {i} frac_oracle out of [0,1]");
        for counter in ["replans", "drift_replans"] {
            anyhow::ensure!(
                e.get(counter).and_then(Json::as_i64).is_some_and(|c| c >= 0),
                "epoch {i} missing '{counter}'"
            );
        }
    }
    let decisions = j
        .get("decisions")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'decisions'"))?;
    anyhow::ensure!(
        decisions.len() == epochs.len(),
        "decision log has {} entries for {} epochs",
        decisions.len(),
        epochs.len()
    );
    for (i, d) in decisions.iter().enumerate() {
        let action = d
            .get("action")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("decision {i} missing 'action'"))?;
        Action::parse(action).map_err(|e| anyhow::anyhow!("decision {i}: {e}"))?;
        let b = d
            .get("b")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("decision {i} missing 'b'"))?;
        anyhow::ensure!(b >= 1 && b <= n_workers, "decision {i} has B={b} outside [1, N]");
        for stat in ["mu", "delta", "score"] {
            let v = d
                .get(stat)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("decision {i} missing '{stat}'"))?;
            anyhow::ensure!(v.is_finite(), "decision {i} has non-finite '{stat}'");
        }
    }
    let last = epochs
        .last()
        .ok_or_else(|| anyhow::anyhow!("'epochs' is empty"))?;
    let consistent = |summary: &str, per_epoch: &str| -> anyhow::Result<()> {
        let a = j.get(summary).and_then(Json::as_f64);
        let b = last.get(per_epoch).and_then(Json::as_f64);
        anyhow::ensure!(
            a.is_some() && a == b,
            "'{summary}' does not match the final epoch's '{per_epoch}'"
        );
        Ok(())
    };
    consistent("final_frac_oracle", "frac_oracle")?;
    consistent("final_mean_rel_regret", "mean_rel_regret")?;
    Ok(())
}

/// Read, parse, and validate an artifact file; returns the parsed JSON.
pub fn validate_file(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    validate_json(&j).map_err(|e| anyhow::anyhow!("validating {}: {e}", path.display()))?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::ControlSpec;

    fn sample_report() -> ControlReport {
        crate::control::run_loop(&ControlSpec::smoke().fast(), 1).expect("run")
    }

    #[test]
    fn artifact_round_trips_and_validates() {
        let report = sample_report();
        let j = report.to_json();
        validate_json(&j).expect("valid");
        let reparsed = Json::parse(&j.to_string()).expect("parse");
        assert_eq!(reparsed, j);
        validate_json(&reparsed).expect("still valid");
    }

    #[test]
    fn write_then_validate_file() {
        let report = sample_report();
        let dir = std::env::temp_dir().join("batchrep-control-report-test");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("CONTROL_roundtrip.json");
        report.write(&path).expect("write");
        let j = validate_file(&path).expect("validate");
        assert_eq!(j.get("name").and_then(Json::as_str), Some("smoke"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_rejects_malformed_artifacts() {
        let good = sample_report().to_json();
        let mutate = |f: &dyn Fn(&mut std::collections::BTreeMap<String, Json>)| {
            let mut m = good.as_object().expect("obj").clone();
            f(&mut m);
            Json::Object(m)
        };
        // Wrong version.
        let bad = mutate(&|m| {
            m.insert("version".into(), Json::Num(99.0));
        });
        assert!(validate_json(&bad).is_err());
        // Missing epochs.
        let bad = mutate(&|m| {
            m.remove("epochs");
        });
        assert!(validate_json(&bad).is_err());
        // Decision log length mismatch.
        let bad = mutate(&|m| {
            m.insert("decisions".into(), Json::Array(vec![]));
        });
        assert!(validate_json(&bad).is_err());
        // Unknown action in the decision log.
        let bad = mutate(&|m| {
            let mut ds = m.get("decisions").and_then(Json::as_array).expect("ds").to_vec();
            if let Json::Object(d0) = &mut ds[0] {
                d0.insert("action".into(), "panic".into());
            }
            m.insert("decisions".into(), Json::Array(ds));
        });
        assert!(validate_json(&bad).is_err());
        // Summary field out of sync with the final epoch.
        let bad = mutate(&|m| {
            m.insert("final_frac_oracle".into(), Json::Num(0.123_456));
        });
        assert!(validate_json(&bad).is_err());
    }
}
