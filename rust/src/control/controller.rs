//! The closed-loop redundancy controller: objective-driven planning
//! over the `analysis` closed forms, CUSUM drift detection, and the
//! replan policy tying them together.
//!
//! The controller holds a **planned** parameter fit (initially the
//! caller's prior, wrapped as a zero-width [`FittedSpec`]) and the
//! batch count `B` that optimizes the declared [`Objective`] under it.
//! Each [`Controller::step`] refits the censored MLE and replans only
//! when one of two triggers fires:
//!
//! 1. **Confidence-band exit** — the new fit and the planned fit
//!    [`FittedSpec::disagrees`]: neither confidence band covers the
//!    other's point estimate. This is the ISSUE's primary trigger and
//!    what moves the controller off a mis-specified prior.
//! 2. **Plan-consistency** — the argmin under the current fit differs
//!    from the held plan *and* switching improves the fitted objective
//!    score by more than [`ControllerConfig::replan_margin`]. Without
//!    this, a plan chosen from an early noisy fit could survive forever
//!    because later (correct) fits stay inside its parameter band; the
//!    margin stops near-tie divisors from flapping.
//!
//! Drift is watched continuously by a two-sided CUSUM on the exact
//! (winner) observations, standardized against the *planned* winner
//! law: under the plan a batch winner is the minimum of `g` replicas,
//! i.e. `∆ + Exp(g·µ)`. When the CUSUM crosses its threshold the
//! history is stale by definition, so the accumulator is rebuilt from a
//! ring buffer of the most recent observations and the next step
//! replans from post-change data only ([`Action::DriftReplan`]).

use super::estimator::{CensoredAccumulator, FitKind, FittedSpec, Observation};
use crate::analysis::{completion_time_quantile, completion_time_stats};
use crate::assignment::feasible_batch_counts;
use crate::dist::ServiceSpec;
use crate::util::json::Json;
use std::collections::VecDeque;

/// What the optimizer minimizes, over the paper's closed forms.
#[derive(Debug, Clone, PartialEq)]
pub enum Objective {
    /// Expected completion time `E[T]` (paper Eq. 4).
    Mean,
    /// Completion-time variance `Var[T]`.
    Variance,
    /// `(1−λ)·E[T] + λ·σ[T]` — the paper's mean/variance trade-off as a
    /// single dial, `λ ∈ [0, 1]`.
    Blend {
        /// Weight on the standard deviation.
        lambda: f64,
    },
    /// The q-quantile of the completion time (performance guarantee).
    Quantile {
        /// Probability level, `q ∈ (0, 1)`.
        q: f64,
    },
}

impl Objective {
    /// Stable name (round-trips through [`Objective::parse`]).
    pub fn name(&self) -> String {
        match self {
            Objective::Mean => "mean".into(),
            Objective::Variance => "variance".into(),
            Objective::Blend { lambda } => format!("blend:{lambda}"),
            Objective::Quantile { q } => format!("quantile:{q}"),
        }
    }

    /// Parse `mean | variance | blend:<λ> | quantile:<q>`.
    pub fn parse(s: &str) -> anyhow::Result<Objective> {
        if s == "mean" {
            return Ok(Objective::Mean);
        }
        if s == "variance" {
            return Ok(Objective::Variance);
        }
        if let Some(rest) = s.strip_prefix("blend:") {
            let lambda: f64 = rest.parse().map_err(|_| anyhow::anyhow!("bad blend '{s}'"))?;
            anyhow::ensure!((0.0..=1.0).contains(&lambda), "blend lambda must be in [0, 1]");
            return Ok(Objective::Blend { lambda });
        }
        if let Some(rest) = s.strip_prefix("quantile:") {
            let q: f64 = rest.parse().map_err(|_| anyhow::anyhow!("bad quantile '{s}'"))?;
            anyhow::ensure!(q > 0.0 && q < 1.0, "quantile q must be in (0, 1)");
            return Ok(Objective::Quantile { q });
        }
        anyhow::bail!("unknown objective '{s}' (expected mean|variance|blend:<l>|quantile:<q>)")
    }

    /// Score (lower is better) of running `n` workers with `b` batches
    /// under `spec`. Requires an exp-family spec.
    pub fn score(&self, n: u64, b: u64, spec: &ServiceSpec) -> anyhow::Result<f64> {
        anyhow::ensure!(
            spec.exp_family().is_some(),
            "objective scoring needs exp/sexp service, got {}",
            spec.name()
        );
        match self {
            Objective::Mean => Ok(completion_time_stats(n, b, spec)?.mean),
            Objective::Variance => Ok(completion_time_stats(n, b, spec)?.var),
            Objective::Blend { lambda } => {
                let st = completion_time_stats(n, b, spec)?;
                Ok((1.0 - lambda) * st.mean + lambda * st.stddev())
            }
            Objective::Quantile { q } => completion_time_quantile(n, b, spec, *q),
        }
    }
}

/// An optimized redundancy plan: the feasible batch count minimizing
/// the objective, with its score.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plan {
    /// Chosen batch count `B` (replication degree is `N/B`).
    pub b: usize,
    /// Objective score at `b`.
    pub score: f64,
}

/// Scan the feasible batch counts (divisors of `n`) and pick the
/// objective minimizer under `spec`.
pub fn plan(n: usize, spec: &ServiceSpec, objective: &Objective) -> anyhow::Result<Plan> {
    anyhow::ensure!(n >= 1, "need at least one worker");
    let mut best: Option<Plan> = None;
    for b in feasible_batch_counts(n) {
        let score = objective.score(n as u64, b as u64, spec)?;
        anyhow::ensure!(score.is_finite(), "non-finite objective score at B={b}");
        if best.map_or(true, |p| score < p.score) {
            best = Some(Plan { b, score });
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no feasible batch count for n={n}"))
}

/// Two-sided CUSUM detector on standardized residuals: fires when
/// either one-sided statistic exceeds `h`. `k` is the usual allowance
/// (insensitivity half-width) in standardized units.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    k: f64,
    h: f64,
    pos: f64,
    neg: f64,
}

impl DriftDetector {
    /// New detector with allowance `k` and threshold `h`.
    pub fn new(k: f64, h: f64) -> Self {
        assert!(k >= 0.0 && h > 0.0);
        Self { k, h, pos: 0.0, neg: 0.0 }
    }

    /// Feed one standardized residual; returns `true` when the
    /// cumulative sum crosses the threshold (the caller should
    /// [`DriftDetector::reset`] after handling the alarm).
    pub fn push(&mut self, z: f64) -> bool {
        self.pos = (self.pos + z - self.k).max(0.0);
        self.neg = (self.neg - z - self.k).max(0.0);
        self.pos > self.h || self.neg > self.h
    }

    /// Clear both one-sided statistics.
    pub fn reset(&mut self) {
        self.pos = 0.0;
        self.neg = 0.0;
    }
}

/// Tuning of a [`Controller`]. [`ControllerConfig::new`] fills the
/// knobs with defaults that hold the stationary false-alarm rate low
/// (see the FPR test) while detecting the E12 drift within a couple of
/// rounds.
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Cluster size `N` (batch counts are divisors of this).
    pub n_workers: usize,
    /// Which exponential-family shape to fit.
    pub kind: FitKind,
    /// What the plan minimizes.
    pub objective: Objective,
    /// Assumed service spec before any telemetry (may be wrong — that
    /// is the point). Must be exp-family.
    pub prior: ServiceSpec,
    /// Confidence multiplier for the estimator bands.
    pub z: f64,
    /// Exact observations required before the first data-driven replan.
    pub min_fit_obs: u64,
    /// CUSUM allowance `k` (standardized units).
    pub cusum_k: f64,
    /// CUSUM threshold `h`.
    pub cusum_h: f64,
    /// Ring-buffer size: observations kept for the post-drift rebuild.
    pub window: usize,
    /// Minimum relative score improvement before a plan-consistency
    /// replan (damps flapping between near-tie divisors).
    pub replan_margin: f64,
}

impl ControllerConfig {
    /// Config with default tuning.
    pub fn new(n_workers: usize, kind: FitKind, objective: Objective, prior: ServiceSpec) -> Self {
        Self {
            n_workers,
            kind,
            objective,
            prior,
            z: 4.0,
            min_fit_obs: 48,
            cusum_k: 0.5,
            cusum_h: 20.0,
            window: 512,
            replan_margin: 0.002,
        }
    }
}

/// Why a decision happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Plan kept (no trigger, or not enough data yet).
    Hold,
    /// Replanned: band exit or a margin-clearing argmin change.
    Replan,
    /// Replanned after a CUSUM alarm, from post-change data only.
    DriftReplan,
}

impl Action {
    /// Stable name (round-trips through [`Action::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Action::Hold => "hold",
            Action::Replan => "replan",
            Action::DriftReplan => "drift_replan",
        }
    }

    /// Parse an [`Action::name`] string.
    pub fn parse(s: &str) -> anyhow::Result<Action> {
        match s {
            "hold" => Ok(Action::Hold),
            "replan" => Ok(Action::Replan),
            "drift_replan" => Ok(Action::DriftReplan),
            other => anyhow::bail!("unknown action '{other}'"),
        }
    }
}

/// One structured entry of the controller's decision log.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlDecision {
    /// Control epoch the decision closed.
    pub epoch: u64,
    /// What happened.
    pub action: Action,
    /// Batch count in force after the decision.
    pub b: usize,
    /// Replication degree `N/B` after the decision.
    pub g: usize,
    /// Rate the plan is based on.
    pub mu: f64,
    /// Shift the plan is based on.
    pub delta: f64,
    /// Objective score of `b` under the planned parameters.
    pub score: f64,
    /// Exact observations accumulated when the decision was taken.
    pub n_exact: u64,
    /// Censored observations accumulated when the decision was taken.
    pub n_censored: u64,
}

impl ControlDecision {
    /// JSON object for the decision log artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", (self.epoch as i64).into()),
            ("action", self.action.name().into()),
            ("b", self.b.into()),
            ("g", self.g.into()),
            ("mu", self.mu.into()),
            ("delta", self.delta.into()),
            ("score", self.score.into()),
            ("n_exact", (self.n_exact as i64).into()),
            ("n_censored", (self.n_censored as i64).into()),
        ])
    }
}

/// The adaptive redundancy controller (see module docs).
#[derive(Debug, Clone)]
pub struct Controller {
    cfg: ControllerConfig,
    acc: CensoredAccumulator,
    recent: VecDeque<Observation>,
    detector: DriftDetector,
    planned: FittedSpec,
    b: usize,
    drift_pending: bool,
    decisions: Vec<ControlDecision>,
}

impl Controller {
    /// Build a controller and derive the initial plan from the prior.
    pub fn new(cfg: ControllerConfig) -> anyhow::Result<Controller> {
        let planned = FittedSpec::from_prior(cfg.kind, &cfg.prior).ok_or_else(|| {
            anyhow::anyhow!("controller prior must be exp/sexp, got {}", cfg.prior.name())
        })?;
        let initial = plan(cfg.n_workers, &planned.spec(), &cfg.objective)?;
        let detector = DriftDetector::new(cfg.cusum_k, cfg.cusum_h);
        Ok(Controller {
            acc: CensoredAccumulator::new(),
            recent: VecDeque::with_capacity(cfg.window),
            detector,
            planned,
            b: initial.b,
            drift_pending: false,
            decisions: Vec::new(),
            cfg,
        })
    }

    /// Batch count currently in force.
    pub fn current_b(&self) -> usize {
        self.b
    }

    /// Replication degree currently in force.
    pub fn replication(&self) -> usize {
        self.cfg.n_workers / self.b
    }

    /// Parameters the current plan is based on.
    pub fn planned(&self) -> &FittedSpec {
        &self.planned
    }

    /// The full decision log so far.
    pub fn decisions(&self) -> &[ControlDecision] {
        &self.decisions
    }

    /// Feed one replica observation. Exact observations additionally
    /// drive the CUSUM, standardized against the planned winner law
    /// `∆ + Exp(g·µ)`.
    pub fn observe(&mut self, obs: Observation) {
        self.acc.push(obs);
        if self.recent.len() == self.cfg.window {
            self.recent.pop_front();
        }
        self.recent.push_back(obs);
        if obs.exact && !self.drift_pending {
            let rate = self.replication() as f64 * self.planned.mu;
            let z = (obs.t - self.planned.delta) * rate - 1.0;
            if self.detector.push(z) {
                self.drift_pending = true;
            }
        }
    }

    /// Feed a batch of observations.
    pub fn observe_all(&mut self, obs: impl IntoIterator<Item = Observation>) {
        for o in obs {
            self.observe(o);
        }
    }

    /// Adopt a fit: replan under it and reset the drift watch.
    fn adopt(&mut self, fit: FittedSpec) -> anyhow::Result<()> {
        let p = plan(self.cfg.n_workers, &fit.spec(), &self.cfg.objective)?;
        self.planned = fit;
        self.b = p.b;
        self.detector.reset();
        Ok(())
    }

    /// Close a control epoch: refit, decide, log. Returns the decision.
    pub fn step(&mut self, epoch: u64) -> anyhow::Result<ControlDecision> {
        let action = if self.drift_pending {
            // History before the change point is stale: rebuild the
            // sufficient statistics from the recent window only.
            let mut acc = CensoredAccumulator::new();
            for &o in &self.recent {
                acc.push(o);
            }
            self.acc = acc;
            self.detector.reset();
            self.drift_pending = false;
            // Post-drift data are scarce by construction; accept a
            // quarter of the usual evidence before moving the plan.
            let enough = (self.cfg.min_fit_obs / 4).max(2);
            match self.acc.fit(self.cfg.kind, self.cfg.z) {
                Some(fit) if fit.n_exact >= enough => {
                    self.adopt(fit)?;
                    Action::DriftReplan
                }
                _ => Action::Hold,
            }
        } else {
            match self.acc.fit(self.cfg.kind, self.cfg.z) {
                Some(fit) if fit.n_exact >= self.cfg.min_fit_obs => {
                    if fit.disagrees(&self.planned) {
                        self.adopt(fit)?;
                        Action::Replan
                    } else {
                        // Plan-consistency trigger: same parameter
                        // neighborhood, but the argmin moved by more
                        // than the flap margin.
                        let n = self.cfg.n_workers;
                        let p = plan(n, &fit.spec(), &self.cfg.objective)?;
                        let held =
                            self.cfg.objective.score(n as u64, self.b as u64, &fit.spec())?;
                        if p.b != self.b && held - p.score > self.cfg.replan_margin * held.abs() {
                            self.adopt(fit)?;
                            Action::Replan
                        } else {
                            Action::Hold
                        }
                    }
                }
                _ => Action::Hold,
            }
        };
        let score = self.cfg.objective.score(
            self.cfg.n_workers as u64,
            self.b as u64,
            &self.planned.spec(),
        )?;
        let decision = ControlDecision {
            epoch,
            action,
            b: self.b,
            g: self.replication(),
            mu: self.planned.mu,
            delta: self.planned.delta,
            score,
            n_exact: self.acc.n_exact(),
            n_censored: self.acc.n_censored(),
        };
        self.decisions.push(decision.clone());
        match action {
            Action::Replan => crate::obs::bump(crate::obs::Counter::ControlReplans, 1),
            Action::DriftReplan => {
                crate::obs::bump(crate::obs::Counter::ControlDriftReplans, 1)
            }
            Action::Hold => {}
        }
        if action != Action::Hold && crate::obs::enabled() {
            crate::obs::emit(
                "control",
                action.name(),
                &[
                    ("epoch", epoch.into()),
                    ("b", decision.b.into()),
                    ("g", decision.g.into()),
                    ("mu", decision.mu.into()),
                    ("delta", decision.delta.into()),
                ],
            );
        }
        Ok(decision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::optimum_b;
    use crate::util::rng::Rng;

    #[test]
    fn objective_round_trips_and_scores() {
        for s in ["mean", "variance", "blend:0.5", "quantile:0.9"] {
            let o = Objective::parse(s).expect("parse");
            assert_eq!(o.name(), s);
        }
        assert!(Objective::parse("blend:1.5").is_err());
        assert!(Objective::parse("quantile:1").is_err());
        assert!(Objective::parse("median").is_err());
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let mean = Objective::Mean.score(12, 3, &spec).expect("score");
        // s∆ + H_3/µ = 4·0.2 + (1 + 1/2 + 1/3)
        assert!((mean - (0.8 + 11.0 / 6.0)).abs() < 1e-12);
        assert!(Objective::Mean.score(12, 3, &ServiceSpec::pareto(1.0, 2.5)).is_err());
    }

    #[test]
    fn plan_matches_analysis_optimum_for_mean() {
        for spec in [
            ServiceSpec::exp(1.3),
            ServiceSpec::shifted_exp(1.0, 0.2),
            ServiceSpec::shifted_exp(1.0, 1.0),
            ServiceSpec::shifted_exp(1.0, 0.02),
        ] {
            for n in [12usize, 24] {
                let p = plan(n, &spec, &Objective::Mean).expect("plan");
                assert_eq!(p.b as u64, optimum_b(n as u64, &spec).unwrap(), "spec={}", spec.name());
            }
        }
        // Variance is minimized at full replication for both shapes.
        let p = plan(24, &ServiceSpec::shifted_exp(1.0, 0.2), &Objective::Variance).expect("plan");
        assert_eq!(p.b, 1);
    }

    #[test]
    fn cusum_fires_on_shift_and_resets() {
        let mut d = DriftDetector::new(0.5, 20.0);
        // Standardized Exp(1)−1 residuals: no alarm on a short clean
        // stretch, alarm within ~60 observations of a +2σ shift.
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            assert!(!d.push(-rng.f64_open0().ln() - 1.0));
        }
        let mut fired_at = None;
        for i in 0..200 {
            if d.push(-rng.f64_open0().ln() + 1.0) {
                fired_at = Some(i);
                break;
            }
        }
        assert!(fired_at.is_some_and(|i| i < 100), "fired_at={fired_at:?}");
        d.reset();
        assert!(!d.push(0.0));
    }

    /// Feed `rounds` rounds of winner telemetry at the controller's
    /// current plan: per batch, the winner of `g` replicas is exact and
    /// the siblings are censored at the winner's time.
    fn feed_rounds(c: &mut Controller, truth: &ServiceSpec, rounds: usize, rng: &mut Rng) {
        for _ in 0..rounds {
            let b = c.current_b();
            let g = c.replication();
            for _ in 0..b {
                let mut win = f64::INFINITY;
                for _ in 0..g {
                    win = win.min(truth.sample(rng));
                }
                c.observe(Observation::exact(win));
                for _ in 1..g {
                    c.observe(Observation::censored(win));
                }
            }
        }
    }

    #[test]
    fn controller_converges_from_misspecified_prior() {
        let truth = ServiceSpec::shifted_exp(1.0, 0.2);
        let cfg = ControllerConfig::new(
            12,
            FitKind::ShiftedExp,
            Objective::Mean,
            ServiceSpec::shifted_exp(4.0, 0.8),
        );
        let mut c = Controller::new(cfg).expect("controller");
        // The mis-specified prior has ∆µ = 3.2 → full parallelism.
        assert_eq!(c.current_b(), 12);
        let mut rng = Rng::new(77);
        for epoch in 0..6 {
            feed_rounds(&mut c, &truth, 30, &mut rng);
            c.step(epoch).expect("step");
        }
        // Truth has ∆µ = 0.2 → oracle B = 3 for N = 12.
        assert_eq!(c.current_b() as u64, optimum_b(12, &truth).unwrap());
        let replans =
            c.decisions().iter().filter(|d| d.action != Action::Hold).count();
        assert!(replans >= 1 && replans <= 3, "replans={replans}");
    }

    #[test]
    fn drift_detector_false_positive_rate_is_low_when_stationary() {
        // Prior == truth, stationary service: across 10k+ exact
        // observations the CUSUM should essentially never fire.
        let truth = ServiceSpec::shifted_exp(1.5, 0.3);
        let cfg = ControllerConfig::new(
            12,
            FitKind::ShiftedExp,
            Objective::Mean,
            truth.clone(),
        );
        let mut c = Controller::new(cfg).expect("controller");
        let mut rng = Rng::new(4242);
        let mut drift_replans = 0usize;
        for epoch in 0..40 {
            feed_rounds(&mut c, &truth, 30, &mut rng);
            let d = c.step(epoch).expect("step");
            if d.action == Action::DriftReplan {
                drift_replans += 1;
            }
        }
        assert!(drift_replans <= 1, "stationary drift replans = {drift_replans}");
    }

    #[test]
    fn controller_detects_injected_shift_and_replans_from_fresh_data() {
        let pre = ServiceSpec::shifted_exp(1.0, 1.0);
        let post = ServiceSpec::shifted_exp(1.0, 0.02);
        let cfg = ControllerConfig::new(24, FitKind::ShiftedExp, Objective::Mean, pre.clone());
        let mut c = Controller::new(cfg).expect("controller");
        let mut rng = Rng::new(11);
        for epoch in 0..4 {
            feed_rounds(&mut c, &pre, 40, &mut rng);
            c.step(epoch).expect("step");
        }
        assert_eq!(c.current_b() as u64, optimum_b(24, &pre).unwrap());
        let mut saw_drift = false;
        for epoch in 4..8 {
            feed_rounds(&mut c, &post, 40, &mut rng);
            let d = c.step(epoch).expect("step");
            saw_drift |= d.action == Action::DriftReplan;
        }
        assert!(saw_drift, "no drift replan after the injected shift");
        assert_eq!(c.current_b() as u64, optimum_b(24, &post).unwrap());
    }

    #[test]
    fn decision_log_serializes() {
        let d = ControlDecision {
            epoch: 3,
            action: Action::Replan,
            b: 4,
            g: 6,
            mu: 1.5,
            delta: 0.2,
            score: 2.5,
            n_exact: 100,
            n_censored: 300,
        };
        let j = d.to_json();
        assert_eq!(j.get("action").and_then(|a| a.as_str()), Some("replan"));
        assert_eq!(j.get("b").and_then(|b| b.as_i64()), Some(4));
        assert_eq!(Action::parse("drift_replan").expect("parse"), Action::DriftReplan);
    }
}
