//! Unified observability layer: one structured event log, wall-clock
//! spans, and a typed counters registry shared by every runtime in the
//! crate.
//!
//! After eight PRs the repo emitted its operational signal in fragments
//! — [`crate::analysis::ct_cache_counters`], the coordinator's
//! [`crate::coordinator::RoundEvents`], [`crate::metrics::FaultTotals`],
//! study dedup counts, chaos/integrity report columns. This module is
//! the unified, machine-readable layer over all of them:
//!
//! * **Event sink** — a process-wide but *explicitly installed* JSON
//!   lines sink ([`install_file`] / [`install_memory`], torn down by
//!   [`uninstall`]). No-op by default: every emit site is gated on one
//!   relaxed atomic load ([`enabled`]), so hot paths stay zero-cost and
//!   — because events never touch an RNG or a result — simulation
//!   output is bit-identical with the sink on or off, for any thread
//!   count (pinned by the `obs_layer` integration tests).
//! * **Spans** — [`span("des.shard")`](span) returns a drop guard that
//!   emits a `kind: "span"` event with the measured `dur_s` when it
//!   falls out of scope; the subsystem label is the prefix before the
//!   first `.`.
//! * **Counters** — a typed, always-on registry of relaxed
//!   [`AtomicU64`]s ([`Counter`], [`bump`], [`snapshot`]) absorbing the
//!   crate's scattered ad-hoc counters behind one API. Counters are
//!   bumped at shard/round granularity, so the always-on cost is a few
//!   uncontended atomic adds per shard. [`uninstall`] writes the final
//!   nonzero snapshot into the log as an `obs/counters` event.
//!
//! ## Event schema (version [`SCHEMA_VERSION`])
//!
//! One JSON object per line. Reserved keys, present on every event:
//!
//! | key    | type   | meaning                                         |
//! |--------|--------|-------------------------------------------------|
//! | `v`    | int    | schema version (currently 1)                    |
//! | `ts`   | number | seconds since sink install, monotone per file   |
//! | `sub`  | string | subsystem (`study`, `mc`, `des`, `analysis`, `coordinator`, `control`, `fault`, `obs`) |
//! | `kind` | string | event kind within the subsystem                 |
//!
//! All other keys are event-specific payload. `kind: "span"` events
//! additionally carry `name` (the span name) and `dur_s`. The `ts` is
//! captured *under the writer lock*, so files are monotone by
//! construction and [`validate_file`] rejects any log that is not.
//!
//! The CLI surface is `--events <path>` on `evaluate`/`study`/
//! `control`/`chaos`/`integrity` plus `batchrep obs summarize
//! <events.jsonl>`; see README ("Observability") and PERF.md (schema +
//! measured sink overhead).

use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Schema version stamped into every event (`"v"`) and checked by
/// [`validate_file`].
pub const SCHEMA_VERSION: i64 = 1;

// ---------------------------------------------------------------------
// Typed counters registry
// ---------------------------------------------------------------------

macro_rules! define_counters {
    ($($variant:ident => $field:ident : $name:literal),* $(,)?) => {
        /// Typed handle into the process-wide counters registry. The
        /// dotted [`Counter::name`] is the stable external identifier
        /// (used in the `obs/counters` event and the summarize report).
        #[derive(Debug, Clone, Copy, PartialEq, Eq)]
        pub enum Counter {
            $(#[doc = $name] $variant,)*
        }

        impl Counter {
            /// Every counter, in declaration order.
            pub const ALL: &[Counter] = &[$(Counter::$variant,)*];

            /// Stable dotted name (`subsystem.metric`).
            pub fn name(self) -> &'static str {
                match self { $(Counter::$variant => $name,)* }
            }
        }

        struct Registry {
            $($field: AtomicU64,)*
        }

        static REGISTRY: Registry = Registry {
            $($field: AtomicU64::new(0),)*
        };

        /// Add `n` to one counter. Always on (no [`enabled`] gate):
        /// call sites sit at shard/round granularity, so the cost is an
        /// uncontended relaxed `fetch_add` — and the registry stays
        /// meaningful for in-process consumers even without a sink.
        #[inline]
        pub fn bump(c: Counter, n: u64) {
            match c {
                $(Counter::$variant => { REGISTRY.$field.fetch_add(n, Ordering::Relaxed); })*
            }
        }

        /// Point-in-time copy of every counter (relaxed loads; counters
        /// bumped mid-snapshot land in one side or the other).
        #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
        pub struct CounterSnapshot {
            $(#[doc = $name] pub $field: u64,)*
        }

        /// Snapshot the process-wide registry.
        pub fn snapshot() -> CounterSnapshot {
            CounterSnapshot {
                $($field: REGISTRY.$field.load(Ordering::Relaxed),)*
            }
        }

        impl CounterSnapshot {
            /// Value of one counter in this snapshot.
            pub fn get(&self, c: Counter) -> u64 {
                match c { $(Counter::$variant => self.$field,)* }
            }

            /// Accumulate another snapshot into this one (saturating),
            /// e.g. folding per-phase deltas into a run total.
            pub fn merge(&mut self, other: &CounterSnapshot) {
                $(self.$field = self.$field.saturating_add(other.$field);)*
            }

            /// Per-counter difference vs an `earlier` snapshot
            /// (saturating, so a registry reset cannot underflow).
            pub fn delta(&self, earlier: &CounterSnapshot) -> CounterSnapshot {
                CounterSnapshot {
                    $($field: self.$field.saturating_sub(earlier.$field),)*
                }
            }

            /// `(name, value)` of every nonzero counter, in declaration
            /// order — the payload of the `obs/counters` event.
            pub fn nonzero(&self) -> Vec<(&'static str, u64)> {
                let mut out = Vec::new();
                $(if self.$field > 0 { out.push(($name, self.$field)); })*
                out
            }
        }
    };
}

define_counters! {
    CtHit => ct_hit: "analysis.ct_cache.hit",
    CtMiss => ct_miss: "analysis.ct_cache.miss",
    McShards => mc_shards: "mc.shards",
    McTrials => mc_trials: "mc.trials",
    DesShards => des_shards: "des.shards",
    DesTrials => des_trials: "des.trials",
    StudyCells => study_cells: "study.cells",
    StudyDeduped => study_deduped: "study.deduped_points",
    StudyRefused => study_refused: "study.refused_cells",
    LiveRounds => live_rounds: "coordinator.rounds",
    LiveCrashes => live_crashes: "coordinator.crashes",
    LiveRespawns => live_respawns: "coordinator.respawns",
    LiveRelaunches => live_relaunches: "coordinator.relaunches",
    LiveDegradations => live_degradations: "coordinator.degradations",
    LiveDropped => live_dropped: "coordinator.dropped",
    LiveCorrupted => live_corrupted: "coordinator.corrupted",
    LiveFlagged => live_flagged: "coordinator.flagged",
    LiveQuarantined => live_quarantined: "coordinator.quarantined",
    ControlReplans => control_replans: "control.replans",
    ControlDriftReplans => control_drift_replans: "control.drift_replans",
    FaultChaosRuns => fault_chaos_runs: "fault.chaos_runs",
    FaultIntegrityRuns => fault_integrity_runs: "fault.integrity_runs",
    LintRuns => lint_runs: "lint.runs",
}

/// Every `(subsystem, kind)` event pair the crate emits with literal
/// arguments, i.e. the summarizer's vocabulary. The `lint` D6 rule checks
/// literal `emit("sub", "kind", …)` call sites against this table, so an
/// event added without registering it here fails the gate — which is the
/// point: the summarizer and any downstream consumer of `events.jsonl`
/// should never meet an unknown kind. Two families are intentionally
/// absent: the generic `"span"` kind (any subsystem, produced by
/// [`Span`]) and the `control` action kinds, which are derived from
/// `Action::name()` (`hold` / `replan` / `drift_replan`) and listed here
/// for documentation even though the call site is non-literal.
pub const KNOWN_KINDS: &[(&str, &str)] = &[
    ("obs", "installed"),
    ("obs", "counters"),
    ("analysis", "cache_miss"),
    ("mc", "shard"),
    ("des", "shard"),
    ("study", "plan"),
    ("study", "cell"),
    ("coordinator", "round"),
    ("coordinator", "crash"),
    ("coordinator", "respawn"),
    ("coordinator", "relaunch"),
    ("coordinator", "degrade"),
    ("coordinator", "timeout"),
    ("coordinator", "quarantine"),
    ("fault", "task_drop"),
    ("fault", "slowdown"),
    ("fault", "chaos_run"),
    ("fault", "integrity_run"),
    ("control", "hold"),
    ("control", "replan"),
    ("control", "drift_replan"),
    ("lint", "run"),
];

// ---------------------------------------------------------------------
// The event sink
// ---------------------------------------------------------------------

struct Active {
    start: Instant,
    out: Box<dyn Write + Send>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Active>> = Mutex::new(None);

fn lock_sink() -> MutexGuard<'static, Option<Active>> {
    // A panic while holding the writer lock must not wedge every later
    // emit (or the uninstall in a test harness) — the sink state itself
    // is a plain Option and stays coherent.
    SINK.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Whether an event sink is installed. One relaxed atomic load — the
/// gate every hot-path emit site checks before building any payload.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install a JSON-lines file sink at `path` (truncating). Fails if a
/// sink is already installed — the sink is process-wide, so nesting
/// would interleave two observers' expectations.
pub fn install_file(path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("creating event log {}: {e}", path.display()))?;
    install_writer(Box::new(std::io::BufWriter::new(f)))
}

/// Install an arbitrary writer as the sink (the file/memory installers
/// both land here). Emits the `obs/installed` marker event.
pub fn install_writer(out: Box<dyn Write + Send>) -> anyhow::Result<()> {
    {
        let mut g = lock_sink();
        anyhow::ensure!(
            g.is_none(),
            "an event sink is already installed — uninstall it first"
        );
        #[allow(clippy::disallowed_methods)] // obs owns the event-log clock
        let start = Instant::now();
        *g = Some(Active { start, out });
    }
    ENABLED.store(true, Ordering::Release);
    emit("obs", "installed", &[("schema", SCHEMA_VERSION.into())]);
    Ok(())
}

/// Shared in-memory sink buffer for tests ([`install_memory`]).
#[derive(Debug, Clone, Default)]
pub struct MemWriter(Arc<Mutex<Vec<u8>>>);

impl MemWriter {
    /// Everything written so far, as UTF-8 text.
    pub fn contents(&self) -> String {
        let buf = self.0.lock().unwrap_or_else(PoisonError::into_inner);
        String::from_utf8_lossy(&buf).into_owned()
    }
}

impl Write for MemWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Install an in-memory sink and return a handle to its buffer — the
/// test path (determinism pins, validator round trips) with no
/// filesystem involved.
pub fn install_memory() -> anyhow::Result<MemWriter> {
    let w = MemWriter::default();
    install_writer(Box::new(w.clone()))?;
    Ok(w)
}

/// Tear the sink down: emit the final `obs/counters` event (the nonzero
/// registry snapshot), flush, and drop the writer. Idempotent — a
/// second call with no sink installed is a no-op.
pub fn uninstall() {
    if enabled() {
        let fields: Vec<(&'static str, Json)> = snapshot()
            .nonzero()
            .into_iter()
            .map(|(name, v)| (name, Json::from(v)))
            .collect();
        emit("obs", "counters", &fields);
    }
    ENABLED.store(false, Ordering::Release);
    let mut g = lock_sink();
    if let Some(mut a) = g.take() {
        let _ = a.out.flush();
    }
}

/// Emit one structured event. Cheap no-op without a sink; with one, the
/// payload is assembled outside the writer lock and the timestamp is
/// read *under* it, so the log's `ts` sequence is monotone even with
/// many threads emitting. The reserved keys (`v`/`ts`/`sub`/`kind`)
/// always win over same-named payload fields.
pub fn emit(sub: &str, kind: &str, fields: &[(&str, Json)]) {
    if !enabled() {
        return;
    }
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    for (k, v) in fields {
        obj.insert((*k).to_string(), v.clone());
    }
    obj.insert("v".to_string(), Json::from(SCHEMA_VERSION));
    obj.insert("sub".to_string(), Json::from(sub));
    obj.insert("kind".to_string(), Json::from(kind));
    let mut g = lock_sink();
    let Some(a) = g.as_mut() else { return };
    obj.insert("ts".to_string(), Json::Num(a.start.elapsed().as_secs_f64()));
    let _ = writeln!(a.out, "{}", Json::Object(obj));
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

/// Drop guard of one wall-clock span (see [`span`]).
#[derive(Debug)]
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
}

/// Start a wall-clock span; the returned guard emits a `kind: "span"`
/// event with the measured `dur_s` when dropped. The subsystem label is
/// the prefix before the first `.` (`span("des.shard")` → `sub:
/// "des"`). Without a sink the guard holds no clock read at all.
#[must_use = "a span measures until the returned guard is dropped"]
#[allow(clippy::disallowed_methods)] // obs owns the span clock
pub fn span(name: &'static str) -> Span {
    Span { name, start: enabled().then(Instant::now) }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(start) = self.start.take() {
            let sub = self.name.split('.').next().unwrap_or(self.name);
            emit(
                sub,
                "span",
                &[
                    ("name", self.name.into()),
                    ("dur_s", start.elapsed().as_secs_f64().into()),
                ],
            );
        }
    }
}

// ---------------------------------------------------------------------
// Validation + summarization of an event log
// ---------------------------------------------------------------------

/// Aggregate of one span name across a log.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpanAgg {
    /// Spans recorded under this name.
    pub count: u64,
    /// Sum of their durations, seconds.
    pub total_s: f64,
    /// Longest single span, seconds.
    pub max_s: f64,
}

/// Validated aggregate of one event log — what `batchrep obs summarize`
/// renders and what [`validate_file`] returns.
#[derive(Debug, Clone, Default)]
pub struct ObsSummary {
    /// Events in the log.
    pub lines: u64,
    /// Distinct `sub` labels seen.
    pub subsystems: BTreeSet<String>,
    /// Event count per `"sub/kind"`.
    pub event_counts: BTreeMap<String, u64>,
    /// Span aggregates per span name.
    pub spans: BTreeMap<String, SpanAgg>,
    /// Histogram of relaunches per live coordinator round (the
    /// straggler/relaunch histogram; zero-relaunch rounds included).
    pub relaunch_hist: BTreeMap<u64, u64>,
    /// `coordinator/round` events seen.
    pub live_rounds: u64,
    /// Final registry snapshot from the last `counters` event.
    pub counters: BTreeMap<String, u64>,
    /// Timestamp of the first event.
    pub first_ts: f64,
    /// Timestamp of the last event.
    pub last_ts: f64,
}

impl ObsSummary {
    /// Wall-clock seconds the log spans.
    pub fn duration_s(&self) -> f64 {
        (self.last_ts - self.first_ts).max(0.0)
    }
}

/// Validate and aggregate an event log given as text. Checks, per line:
/// JSON object, schema version, finite monotone `ts`, non-empty
/// `sub`/`kind`, and span payloads (`name` + finite `dur_s`). An empty
/// log is an error — a run that produced no events at all is a wiring
/// bug, not a quiet success.
pub fn summarize_str(text: &str) -> anyhow::Result<ObsSummary> {
    let mut s = ObsSummary::default();
    let mut prev_ts = f64::NEG_INFINITY;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
        anyhow::ensure!(
            j.as_object().is_some(),
            "line {lineno}: event is not a JSON object"
        );
        let v = j.get("v").and_then(Json::as_i64);
        anyhow::ensure!(
            v == Some(SCHEMA_VERSION),
            "line {lineno}: missing or unsupported schema version {v:?} \
             (this validator understands v{SCHEMA_VERSION})"
        );
        let ts = j
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing numeric 'ts'"))?;
        anyhow::ensure!(ts.is_finite() && ts >= 0.0, "line {lineno}: nonsensical ts {ts}");
        anyhow::ensure!(
            ts >= prev_ts,
            "line {lineno}: timestamps must be monotone ({ts} after {prev_ts})"
        );
        prev_ts = ts;
        let sub = j
            .get("sub")
            .and_then(Json::as_str)
            .filter(|x| !x.is_empty())
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing 'sub'"))?;
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .filter(|x| !x.is_empty())
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing 'kind'"))?;
        if s.lines == 0 {
            s.first_ts = ts;
        }
        s.last_ts = ts;
        s.lines += 1;
        s.subsystems.insert(sub.to_string());
        *s.event_counts.entry(format!("{sub}/{kind}")).or_insert(0) += 1;
        if kind == "span" {
            let name = j
                .get("name")
                .and_then(Json::as_str)
                .filter(|x| !x.is_empty())
                .ok_or_else(|| anyhow::anyhow!("line {lineno}: span event missing 'name'"))?;
            let dur = j.get("dur_s").and_then(Json::as_f64).ok_or_else(|| {
                anyhow::anyhow!("line {lineno}: span event missing numeric 'dur_s'")
            })?;
            anyhow::ensure!(
                dur.is_finite() && dur >= 0.0,
                "line {lineno}: nonsensical span duration {dur}"
            );
            let agg = s.spans.entry(name.to_string()).or_default();
            agg.count += 1;
            agg.total_s += dur;
            agg.max_s = agg.max_s.max(dur);
        }
        if sub == "coordinator" && kind == "round" {
            s.live_rounds += 1;
            let rl = j.get("relaunches").and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
            *s.relaunch_hist.entry(rl).or_insert(0) += 1;
        }
        if kind == "counters" {
            if let Some(m) = j.as_object() {
                for (k, val) in m {
                    if matches!(k.as_str(), "v" | "ts" | "sub" | "kind") {
                        continue;
                    }
                    if let Some(n) = val.as_i64() {
                        if n >= 0 {
                            s.counters.insert(k.clone(), n as u64);
                        }
                    }
                }
            }
        }
    }
    anyhow::ensure!(s.lines > 0, "event log contains no events");
    Ok(s)
}

/// Read `path` and [`summarize_str`] it — the schema gate the
/// `batchrep obs summarize` subcommand and ci.sh run on every event
/// artifact.
pub fn validate_file(path: &Path) -> anyhow::Result<ObsSummary> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading event log {}: {e}", path.display()))?;
    summarize_str(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global; tests that install one must not
    // overlap. (Separate test *binaries* are separate processes, so
    // this only serializes within the lib-test binary.)
    static TEST_SINK: Mutex<()> = Mutex::new(());

    fn sink_guard() -> MutexGuard<'static, ()> {
        TEST_SINK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn counters_snapshot_delta_and_merge() {
        let before = snapshot();
        bump(Counter::McShards, 3);
        bump(Counter::McTrials, 1000);
        // Other tests bump concurrently, so deltas are lower bounds.
        let d = snapshot().delta(&before);
        assert!(d.get(Counter::McShards) >= 3);
        assert!(d.get(Counter::McTrials) >= 1000);
        let mut merged = d;
        merged.merge(&d);
        assert_eq!(merged.get(Counter::McShards), 2 * d.get(Counter::McShards));
        assert_eq!(merged.get(Counter::CtHit), 2 * d.get(Counter::CtHit));
        let names: Vec<&str> = d.nonzero().iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"mc.shards"));
        assert!(names.contains(&"mc.trials"));
        // Every nonzero entry really is nonzero, in declaration order.
        for (_, v) in d.nonzero() {
            assert!(v > 0);
        }
        // delta of identical snapshots is all-zero.
        let z = d.delta(&d);
        assert!(z.nonzero().is_empty());
    }

    #[test]
    fn counter_names_are_stable_and_unique() {
        assert_eq!(Counter::CtHit.name(), "analysis.ct_cache.hit");
        assert_eq!(Counter::LiveRelaunches.name(), "coordinator.relaunches");
        let mut names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "counter names must be unique");
        assert!(n >= 20, "registry should absorb the crate's ad-hoc counters");
    }

    #[test]
    fn emit_and_span_are_noops_without_a_sink() {
        let _g = sink_guard();
        assert!(!enabled());
        emit("test", "noop", &[("x", 1i64.into())]);
        let sp = span("test.noop");
        assert!(sp.start.is_none(), "no clock read without a sink");
        drop(sp);
        uninstall(); // idempotent no-op
    }

    #[test]
    fn sink_round_trips_through_the_validator() {
        let _g = sink_guard();
        let mem = install_memory().unwrap();
        emit("study", "plan", &[("cells", 4usize.into())]);
        {
            let _sp = span("des.shard");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        bump(Counter::DesShards, 1);
        emit(
            "coordinator",
            "round",
            &[
                ("round", 0usize.into()),
                ("relaunches", 2usize.into()),
                ("wall_s", 0.5.into()),
            ],
        );
        uninstall();
        let s = summarize_str(&mem.contents()).unwrap();
        // Concurrent lib tests may emit too — assert lower bounds only.
        assert!(s.subsystems.contains("obs"), "install/counters markers present");
        assert!(s.subsystems.contains("study"));
        assert!(s.subsystems.contains("des"));
        assert!(s.subsystems.contains("coordinator"));
        assert!(s.event_counts.get("study/plan").copied().unwrap_or(0) >= 1);
        let sp = s.spans.get("des.shard").expect("span aggregated by name");
        assert!(sp.count >= 1);
        assert!(sp.total_s > 0.0, "the span slept ≥ 1ms");
        assert!(sp.max_s <= sp.total_s + 1e-12);
        assert!(s.relaunch_hist.get(&2).copied().unwrap_or(0) >= 1);
        assert!(
            s.counters.get("des.shards").copied().unwrap_or(0) >= 1,
            "uninstall writes the final registry snapshot"
        );
        assert!(s.last_ts >= s.first_ts);
        assert!(s.duration_s() >= 0.0);
    }

    #[test]
    fn double_install_is_an_error_and_reinstall_works() {
        let _g = sink_guard();
        let _m = install_memory().unwrap();
        assert!(install_memory().is_err(), "the sink is process-wide");
        uninstall();
        let m2 = install_memory().unwrap();
        emit("test", "alive", &[]);
        uninstall();
        assert!(m2.contents().contains("\"kind\":\"alive\""));
    }

    #[test]
    fn validator_rejects_malformed_logs() {
        assert!(summarize_str("").is_err(), "empty log");
        assert!(summarize_str("not json\n").is_err());
        assert!(
            summarize_str("{\"v\":999,\"ts\":0,\"sub\":\"x\",\"kind\":\"y\"}\n").is_err(),
            "wrong version"
        );
        assert!(
            summarize_str("{\"v\":1,\"ts\":0,\"sub\":\"x\"}\n").is_err(),
            "missing kind"
        );
        let non_monotone = "{\"v\":1,\"ts\":2,\"sub\":\"x\",\"kind\":\"y\"}\n\
                            {\"v\":1,\"ts\":1,\"sub\":\"x\",\"kind\":\"y\"}\n";
        assert!(summarize_str(non_monotone).is_err(), "non-monotone ts");
        assert!(
            summarize_str("{\"v\":1,\"ts\":0,\"sub\":\"x\",\"kind\":\"span\",\"name\":\"x.y\"}\n")
                .is_err(),
            "span without dur_s"
        );
        let ok = "{\"v\":1,\"ts\":0,\"sub\":\"x\",\"kind\":\"y\"}\n";
        let s = summarize_str(ok).unwrap();
        assert_eq!(s.lines, 1);
        assert_eq!(s.event_counts.get("x/y"), Some(&1));
    }
}
