//! TOML-subset parser (stand-in for `toml`/`serde` in the offline
//! environment).
//!
//! Supported grammar — everything the config files need, nothing more:
//! `[section]` headers, `key = value` pairs, `#` comments, values of
//! type integer, float, boolean, quoted string, and flat arrays of
//! those. Keys outside a section land in the `""` section.

use std::collections::BTreeMap;

/// A TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As integer (accepts integral floats).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            TomlValue::Float(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    /// As float (accepts integers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(x) => Some(*x as f64),
            TomlValue::Float(x) => Some(*x),
            _ => None,
        }
    }

    /// As boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array.
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: section → key → value.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> anyhow::Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                .trim();
            anyhow::ensure!(!name.is_empty(), "line {}: empty section name", lineno + 1);
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone()).or_default().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<TomlValue> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        anyhow::ensure!(!inner.contains('"'), "embedded quote in string");
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

/// Split a flat array body on commas, respecting quoted strings.
fn split_top_level(s: &str) -> anyhow::Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            '[' | ']' if !in_str => anyhow::bail!("nested arrays unsupported"),
            _ => {}
        }
    }
    anyhow::ensure!(!in_str, "unterminated string in array");
    out.push(&s[start..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
            # top comment
            seed = 42
            [system]
            n_workers = 24        # inline comment
            time_scale = 0.001
            policy = "balanced_disjoint"
            cancel = true
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["seed"].as_i64(), Some(42));
        assert_eq!(doc["system"]["n_workers"].as_i64(), Some(24));
        assert_eq!(doc["system"]["time_scale"].as_f64(), Some(0.001));
        assert_eq!(doc["system"]["policy"].as_str(), Some("balanced_disjoint"));
        assert_eq!(doc["system"]["cancel"].as_bool(), Some(true));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse(r#"xs = [1, 2, 3]
                           ys = ["a", "b"]
                           empty = []"#)
            .unwrap();
        let xs = doc[""]["xs"].as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_i64(), Some(3));
        assert_eq!(doc[""]["ys"].as_array().unwrap()[1].as_str(), Some("b"));
        assert!(doc[""]["empty"].as_array().unwrap().is_empty());
    }

    #[test]
    fn hash_in_string_is_not_comment() {
        let doc = parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc[""]["k"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"open").is_err());
        assert!(parse("k = [1, [2]]").is_err());
    }

    #[test]
    fn float_int_coercions() {
        let doc = parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc[""]["a"].as_f64(), Some(3.0));
        assert_eq!(doc[""]["b"].as_i64(), None);
        assert_eq!(doc[""]["b"].as_f64(), Some(3.5));
    }
}
