//! Command-line argument parser (a `clap` stand-in).
//!
//! Grammar: `batchrep <subcommand> [positional...] [--key value]...
//! [--flag]`. `--key=value` is also accepted. The parser collects
//! positionals and a key→value map; subcommand code pulls typed values
//! with [`Args::get`] / [`Args::flag`] and finishes with
//! [`Args::finish`] to reject unknown options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (subcommand first).
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                anyhow::ensure!(!name.is_empty(), "bare '--' not supported");
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process's own command line.
    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(String::as_str)
    }

    /// Typed option lookup; `None` when absent.
    pub fn get<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.borrow_mut().push(key.to_string());
        match self.options.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {raw}: {e}")),
        }
    }

    /// Typed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// Deterministic root seed (`--seed N`), defaulting to `default`.
    /// Subcommands pass this single value into every evaluator (via the
    /// scenario or the experiment context), so tables are
    /// bit-reproducible across runs.
    pub fn seed(&self, default: u64) -> anyhow::Result<u64> {
        self.get_or("seed", default)
    }

    /// Boolean flag presence (`--foo`).
    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag that no subcommand consumed.
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.options.keys() {
            anyhow::ensure!(consumed.contains(k), "unknown option --{k}");
        }
        for f in &self.flags {
            anyhow::ensure!(consumed.contains(f), "unknown flag --{f}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("experiment fig2 --trials 5000 --out results");
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positionals[1], "fig2");
        assert_eq!(a.get::<u64>("trials").unwrap(), Some(5000));
        assert_eq!(a.get::<String>("out").unwrap().unwrap(), "results");
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("run --seed=9 --verbose");
        assert_eq!(a.get::<u64>("seed").unwrap(), Some(9));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b value");
        assert!(a.flag("a"));
        assert_eq!(a.get::<String>("b").unwrap().unwrap(), "value");
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("x --mystery 3");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_type_reported() {
        let a = parse("x --n notanumber");
        assert!(a.get::<u64>("n").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or::<u64>("trials", 77).unwrap(), 77);
    }

    #[test]
    fn seed_helper() {
        let a = parse("x --seed 9");
        assert_eq!(a.seed(42).unwrap(), 9);
        a.finish().unwrap();
        let b = parse("x");
        assert_eq!(b.seed(42).unwrap(), 42);
    }
}
