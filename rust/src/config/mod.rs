//! Configuration system: typed [`SystemConfig`], a TOML-subset file
//! format ([`toml`]), and a CLI argument parser ([`cli`]).
//!
//! Precedence: built-in defaults < config file (`--config path`) <
//! command-line overrides (`--key value`).

pub mod cli;
pub mod toml;

use crate::assignment::Policy;
use crate::dist::{BatchModel, ServiceSpec};
use toml::{TomlDoc, TomlValue};

/// Full configuration of a System1 run (simulated or live).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Number of workers `N`.
    pub n_workers: usize,
    /// Number of batches `B` (must divide `N` for balanced policies).
    pub n_batches: usize,
    /// Batch→worker assignment policy.
    pub policy: Policy,
    /// Use an overlapping (cyclic) sample→batch layout instead of the
    /// disjoint partition.
    pub overlapping: bool,
    /// Per-unit service-time distribution (compact spec string, e.g.
    /// `sexp:1.0,0.2`).
    pub service: ServiceSpec,
    /// Batch service composition model.
    pub batch_model: BatchModel,
    /// Cancel sibling replicas on batch completion (live + engine).
    pub cancellation: bool,
    /// Root RNG seed.
    pub seed: u64,
    /// Monte-Carlo / engine trial count.
    pub trials: u64,
    /// Live runtime: artifacts directory (AOT HLO text + manifest).
    pub artifacts_dir: String,
    /// Live runtime: seconds of injected sleep per unit of sampled
    /// service time (scales the abstract service times to wall clock).
    pub time_scale: f64,
    /// Live runtime: compute kernel to run per batch (`grad` | `mapsum`).
    pub kernel: String,
    /// Live runtime: model feature dimension.
    pub dim: usize,
    /// Live runtime: total dataset rows.
    pub n_samples: usize,
    /// Live runtime: training steps (rounds of the job).
    pub steps: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            n_batches: 4,
            policy: Policy::BalancedDisjoint,
            overlapping: false,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            batch_model: BatchModel::SizeScaled,
            cancellation: true,
            seed: 42,
            trials: 100_000,
            artifacts_dir: "artifacts".to_string(),
            time_scale: 0.02,
            kernel: "grad".to_string(),
            dim: 64,
            n_samples: 4096,
            steps: 20,
        }
    }
}

impl SystemConfig {
    /// Load from a TOML-subset file (missing keys keep defaults).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config {}: {e}", path.display()))?;
        let doc = toml::parse(&text)?;
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed document (`[system]` section and root keys).
    pub fn apply_doc(&mut self, doc: &TomlDoc) -> anyhow::Result<()> {
        for section in ["", "system"] {
            if let Some(map) = doc.get(section) {
                for (k, v) in map {
                    self.apply_kv(k, v)
                        .map_err(|e| anyhow::anyhow!("key '{k}': {e}"))?;
                }
            }
        }
        self.validate()
    }

    /// Apply a single `key = value` pair.
    pub fn apply_kv(&mut self, key: &str, v: &TomlValue) -> anyhow::Result<()> {
        let want_i = || v.as_i64().ok_or_else(|| anyhow::anyhow!("expected integer"));
        let want_f = || v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number"));
        let want_b = || v.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"));
        let want_s = || {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("expected string"))
        };
        match key {
            "n_workers" => self.n_workers = want_i()? as usize,
            "n_batches" => self.n_batches = want_i()? as usize,
            "policy" => self.policy = Policy::parse(&want_s()?)?,
            "overlapping" => self.overlapping = want_b()?,
            "service" => self.service = ServiceSpec::parse(&want_s()?)?,
            "batch_model" => self.batch_model = BatchModel::parse(&want_s()?)?,
            "cancellation" => self.cancellation = want_b()?,
            "seed" => self.seed = want_i()? as u64,
            "trials" => self.trials = want_i()? as u64,
            "artifacts_dir" => self.artifacts_dir = want_s()?,
            "time_scale" => self.time_scale = want_f()?,
            "kernel" => self.kernel = want_s()?,
            "dim" => self.dim = want_i()? as usize,
            "n_samples" => self.n_samples = want_i()? as usize,
            "steps" => self.steps = want_i()? as u64,
            other => anyhow::bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Cross-field validation.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "n_workers must be >= 1");
        anyhow::ensure!(
            self.n_batches >= 1 && self.n_batches <= self.n_workers,
            "need 1 <= n_batches <= n_workers"
        );
        anyhow::ensure!(self.time_scale > 0.0, "time_scale must be positive");
        anyhow::ensure!(
            matches!(self.kernel.as_str(), "grad" | "mapsum"),
            "kernel must be 'grad' or 'mapsum'"
        );
        anyhow::ensure!(self.dim >= 1 && self.n_samples >= self.n_workers, "bad dims");
        Ok(())
    }

    /// Build the simulation [`crate::des::Scenario`] this config
    /// describes.
    pub fn scenario(&self) -> anyhow::Result<crate::des::Scenario> {
        let mut rng = crate::util::rng::Rng::new(self.seed ^ 0x5EED);
        let assignment = self.policy.assign(self.n_workers, self.n_batches, &mut rng)?;
        let eff_b = assignment.n_batches;
        let layout = if self.overlapping {
            let stride = self.n_workers / eff_b;
            crate::batching::overlapping(self.n_workers, eff_b, stride)?
        } else {
            crate::batching::disjoint(self.n_workers, eff_b)?
        };
        crate::des::Scenario::new(
            layout,
            assignment,
            crate::dist::BatchService { spec: self.service.clone(), model: self.batch_model },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        SystemConfig::default().validate().unwrap();
        SystemConfig::default().scenario().unwrap();
    }

    #[test]
    fn apply_doc_overrides() {
        let doc = toml::parse(
            r#"
            seed = 7
            [system]
            n_workers = 24
            n_batches = 6
            policy = "full_diversity"
            service = "exp:2.0"
            overlapping = false
            "#,
        )
        .unwrap();
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.n_workers, 24);
        assert_eq!(cfg.seed, 7);
        assert!(matches!(cfg.policy, Policy::FullDiversity));
        assert!(matches!(cfg.service, ServiceSpec::Exp { .. }));
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = toml::parse("nonsense = 1").unwrap();
        let mut cfg = SystemConfig::default();
        assert!(cfg.apply_doc(&doc).is_err());
    }

    #[test]
    fn invalid_combination_rejected() {
        let doc = toml::parse("n_workers = 2\nn_batches = 5").unwrap();
        let mut cfg = SystemConfig::default();
        assert!(cfg.apply_doc(&doc).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("batchrep_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.toml");
        std::fs::write(&p, "n_workers = 12\nn_batches = 3\nservice = \"sexp:1.0,0.5\"\n")
            .unwrap();
        let cfg = SystemConfig::from_file(&p).unwrap();
        assert_eq!(cfg.n_workers, 12);
        std::fs::remove_dir_all(&dir).ok();
    }
}
