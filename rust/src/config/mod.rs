//! Configuration system: typed [`SystemConfig`], a TOML-subset file
//! format ([`toml`]), and a CLI argument parser ([`cli`]).
//!
//! Precedence: built-in defaults < config file (`--config path`) <
//! command-line overrides (`--key value`).

pub mod cli;
pub mod toml;

use crate::assignment::Policy;
use crate::dist::{BatchModel, ServiceSpec};
use toml::{TomlDoc, TomlValue};

/// Full configuration of a System1 run (simulated or live).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Number of workers `N`.
    pub n_workers: usize,
    /// Number of batches `B` (must divide `N` for balanced policies).
    pub n_batches: usize,
    /// Batch→worker assignment policy.
    pub policy: Policy,
    /// Use an overlapping (cyclic) sample→batch layout instead of the
    /// disjoint partition.
    pub overlapping: bool,
    /// Per-unit service-time distribution (compact spec string, e.g.
    /// `sexp:1.0,0.2`).
    pub service: ServiceSpec,
    /// Batch service composition model.
    pub batch_model: BatchModel,
    /// Cancel sibling replicas on batch completion (live + engine).
    pub cancellation: bool,
    /// Speculative-relaunch deadline factor; 0 = upfront replication
    /// (the paper's model). Nonzero values make the scenario's
    /// redundancy mode `Speculative { deadline_factor }`.
    pub speculative: f64,
    /// k-of-B partial-aggregation target; 0 = full completion. Nonzero
    /// values set the scenario's `k_of_b` field (must be ≤ n_batches).
    pub k_of_b: usize,
    /// Root RNG seed (plumbed into every evaluator via the scenario).
    pub seed: u64,
    /// Monte-Carlo / engine trial count.
    pub trials: u64,
    /// Live runtime: artifacts directory (AOT HLO text + manifest).
    pub artifacts_dir: String,
    /// Live runtime: seconds of injected sleep per unit of sampled
    /// service time (scales the abstract service times to wall clock).
    pub time_scale: f64,
    /// Live runtime: compute kernel to run per batch (`grad` | `mapsum`).
    pub kernel: String,
    /// Live runtime: model feature dimension.
    pub dim: usize,
    /// Live runtime: total dataset rows.
    pub n_samples: usize,
    /// Live runtime: training steps (rounds of the job).
    pub steps: u64,
    /// Live runtime: a batch's speculative relaunch deadline (and the
    /// whole-round liveness bound) is this factor times its slowest
    /// dispatched injected delay — the live analogue of the DES
    /// engine's `relaunch_timeout_factor`.
    pub relaunch_factor: f64,
    /// Live runtime: maximum deadline relaunches per batch per round
    /// before the round fails with a liveness error.
    pub max_relaunches: u64,
    /// Result-integrity verification level: every batch waits for its
    /// m-th replica and the coordinator votes on the collected values;
    /// 0 = off (paper semantics, first replica wins). Nonzero values
    /// set the scenario's `verify_m` field (must be ≤ the minimum
    /// replication degree — checked when the scenario is built).
    pub verify_m: usize,
    /// Strikes (flagged disagreements) before a worker is quarantined:
    /// marked dead, excluded from dispatch, and handed to the respawn
    /// machinery. Strikes reset when the worker respawns.
    pub verify_strikes: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            n_batches: 4,
            policy: Policy::BalancedDisjoint,
            overlapping: false,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            batch_model: BatchModel::SizeScaled,
            cancellation: true,
            speculative: 0.0,
            k_of_b: 0,
            seed: 42,
            trials: 100_000,
            artifacts_dir: "artifacts".to_string(),
            time_scale: 0.02,
            kernel: "grad".to_string(),
            dim: 64,
            n_samples: 4096,
            steps: 20,
            relaunch_factor: 3.0,
            max_relaunches: 5,
            verify_m: 0,
            verify_strikes: 2,
        }
    }
}

impl SystemConfig {
    /// Load from a TOML-subset file (missing keys keep defaults).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config {}: {e}", path.display()))?;
        let doc = toml::parse(&text)?;
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed document (`[system]` section and root keys).
    pub fn apply_doc(&mut self, doc: &TomlDoc) -> anyhow::Result<()> {
        for section in ["", "system"] {
            if let Some(map) = doc.get(section) {
                for (k, v) in map {
                    self.apply_kv(k, v)
                        .map_err(|e| anyhow::anyhow!("key '{k}': {e}"))?;
                }
            }
        }
        self.validate()
    }

    /// Apply a single `key = value` pair.
    pub fn apply_kv(&mut self, key: &str, v: &TomlValue) -> anyhow::Result<()> {
        let want_i = || v.as_i64().ok_or_else(|| anyhow::anyhow!("expected integer"));
        let want_f = || v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number"));
        let want_b = || v.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"));
        let want_s = || {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("expected string"))
        };
        match key {
            "n_workers" => self.n_workers = want_i()? as usize,
            "n_batches" => self.n_batches = want_i()? as usize,
            "policy" => self.policy = Policy::parse(&want_s()?)?,
            "overlapping" => self.overlapping = want_b()?,
            "service" => self.service = ServiceSpec::parse(&want_s()?)?,
            "batch_model" => self.batch_model = BatchModel::parse(&want_s()?)?,
            "cancellation" => self.cancellation = want_b()?,
            "speculative" => self.speculative = want_f()?,
            "k_of_b" => self.k_of_b = want_i()? as usize,
            "seed" => self.seed = want_i()? as u64,
            "trials" => self.trials = want_i()? as u64,
            "artifacts_dir" => self.artifacts_dir = want_s()?,
            "time_scale" => self.time_scale = want_f()?,
            "kernel" => self.kernel = want_s()?,
            "dim" => self.dim = want_i()? as usize,
            "n_samples" => self.n_samples = want_i()? as usize,
            "steps" => self.steps = want_i()? as u64,
            "relaunch_factor" => self.relaunch_factor = want_f()?,
            "max_relaunches" => self.max_relaunches = want_i()? as u64,
            "verify_m" => self.verify_m = want_i()? as usize,
            "verify_strikes" => self.verify_strikes = want_i()? as u64,
            other => anyhow::bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Cross-field validation.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "n_workers must be >= 1");
        anyhow::ensure!(
            self.n_batches >= 1 && self.n_batches <= self.n_workers,
            "need 1 <= n_batches <= n_workers"
        );
        anyhow::ensure!(self.time_scale > 0.0, "time_scale must be positive");
        anyhow::ensure!(self.speculative >= 0.0, "speculative factor must be >= 0");
        anyhow::ensure!(
            self.k_of_b <= self.n_batches,
            "k_of_b must be <= n_batches (0 = full completion)"
        );
        anyhow::ensure!(
            matches!(self.kernel.as_str(), "grad" | "mapsum"),
            "kernel must be 'grad' or 'mapsum'"
        );
        anyhow::ensure!(self.dim >= 1 && self.n_samples >= self.n_workers, "bad dims");
        anyhow::ensure!(
            self.relaunch_factor.is_finite() && self.relaunch_factor > 1.0,
            "relaunch_factor must be finite and > 1"
        );
        anyhow::ensure!(self.max_relaunches >= 1, "max_relaunches must be >= 1");
        anyhow::ensure!(
            self.verify_m == 0 || self.verify_strikes >= 1,
            "verify_strikes must be >= 1 when verify_m is enabled"
        );
        Ok(())
    }

    /// The [`ReplicationPolicy`] this config describes (assignment
    /// policy plus the overlapping-layout flag).
    pub fn replication_policy(&self) -> crate::evaluator::ReplicationPolicy {
        use crate::evaluator::ReplicationPolicy as Rp;
        if self.overlapping {
            return Rp::OverlappingCyclic;
        }
        match self.policy {
            Policy::BalancedDisjoint => Rp::BalancedDisjoint,
            Policy::RandomBalanced => Rp::RandomBalanced,
            Policy::SkewedUnbalanced => Rp::SkewedUnbalanced,
            Policy::FullDiversity => Rp::FullDiversity,
            Policy::FullParallelism => Rp::FullParallelism,
        }
    }

    /// Build the fully self-describing [`crate::des::Scenario`] this
    /// config describes — the value every evaluator backend consumes.
    pub fn scenario(&self) -> anyhow::Result<crate::des::Scenario> {
        // The overlapping layout fixes the assignment to one cyclic
        // window per worker; refuse to silently discard an explicitly
        // requested assignment policy.
        anyhow::ensure!(
            !self.overlapping || self.policy == Policy::BalancedDisjoint,
            "overlapping layout is incompatible with policy '{}'; \
             it implies one cyclic window per worker (leave policy at \
             balanced_disjoint)",
            self.policy.name()
        );
        let redundancy = if self.speculative > 0.0 {
            crate::des::engine::Redundancy::Speculative { deadline_factor: self.speculative }
        } else {
            crate::des::engine::Redundancy::Upfront
        };
        let mut scn = crate::des::Scenario::from_policy(
            self.replication_policy(),
            self.n_workers,
            self.n_batches,
            crate::dist::BatchService { spec: self.service.clone(), model: self.batch_model },
            self.seed,
        )?
        .with_redundancy(redundancy);
        if self.k_of_b > 0 {
            scn = scn.with_k_of_b(self.k_of_b)?;
        }
        if self.verify_m > 0 {
            scn = scn.with_verify_m(self.verify_m)?;
        }
        Ok(scn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        SystemConfig::default().validate().unwrap();
        SystemConfig::default().scenario().unwrap();
    }

    #[test]
    fn apply_doc_overrides() {
        let doc = toml::parse(
            r#"
            seed = 7
            [system]
            n_workers = 24
            n_batches = 6
            policy = "full_diversity"
            service = "exp:2.0"
            overlapping = false
            "#,
        )
        .unwrap();
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.n_workers, 24);
        assert_eq!(cfg.seed, 7);
        assert!(matches!(cfg.policy, Policy::FullDiversity));
        assert!(matches!(cfg.service, ServiceSpec::Exp { .. }));
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = toml::parse("nonsense = 1").unwrap();
        let mut cfg = SystemConfig::default();
        assert!(cfg.apply_doc(&doc).is_err());
    }

    #[test]
    fn invalid_combination_rejected() {
        let doc = toml::parse("n_workers = 2\nn_batches = 5").unwrap();
        let mut cfg = SystemConfig::default();
        assert!(cfg.apply_doc(&doc).is_err());
    }

    #[test]
    fn k_of_b_key_flows_into_the_scenario() {
        let cfg = SystemConfig { k_of_b: 3, ..SystemConfig::default() };
        assert_eq!(cfg.scenario().unwrap().k_of_b, Some(3));
        let off = SystemConfig { k_of_b: 0, ..SystemConfig::default() };
        assert_eq!(off.scenario().unwrap().k_of_b, None);
        let bad = SystemConfig { k_of_b: 9, ..SystemConfig::default() };
        assert!(bad.validate().is_err());
        let doc = toml::parse("k_of_b = 2").unwrap();
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.k_of_b, 2);
    }

    #[test]
    fn scenario_is_self_describing() {
        let cfg = SystemConfig { seed: 123, speculative: 1.5, ..SystemConfig::default() };
        let scn = cfg.scenario().unwrap();
        assert_eq!(scn.seed, 123);
        match scn.redundancy {
            crate::des::engine::Redundancy::Speculative { deadline_factor } => {
                assert_eq!(deadline_factor, 1.5)
            }
            other => panic!("expected speculative redundancy, got {other:?}"),
        }
        assert_eq!(scn.policy, crate::evaluator::ReplicationPolicy::BalancedDisjoint);
        let overlap = SystemConfig { overlapping: true, ..SystemConfig::default() };
        assert_eq!(
            overlap.replication_policy(),
            crate::evaluator::ReplicationPolicy::OverlappingCyclic
        );
        assert!(overlap.scenario().unwrap().layout.is_overlapping);
        // Overlapping + an explicit non-balanced policy is refused
        // rather than silently discarding the policy.
        let clash = SystemConfig {
            overlapping: true,
            policy: Policy::SkewedUnbalanced,
            ..SystemConfig::default()
        };
        assert!(clash.scenario().is_err());
    }

    #[test]
    fn relaunch_knobs_parse_and_validate() {
        let doc = toml::parse("relaunch_factor = 4.5\nmax_relaunches = 2").unwrap();
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.relaunch_factor, 4.5);
        assert_eq!(cfg.max_relaunches, 2);
        let bad = SystemConfig { relaunch_factor: 1.0, ..SystemConfig::default() };
        assert!(bad.validate().is_err());
        let bad = SystemConfig { max_relaunches: 0, ..SystemConfig::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn verify_keys_parse_validate_and_flow_into_the_scenario() {
        let doc = toml::parse("verify_m = 2\nverify_strikes = 3").unwrap();
        let mut cfg = SystemConfig::default();
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.verify_m, 2);
        assert_eq!(cfg.verify_strikes, 3);
        // Default 8/4 layout has g = 2, so verify_m = 2 is accepted.
        assert_eq!(cfg.scenario().unwrap().verify_m, Some(2));
        let off = SystemConfig::default();
        assert_eq!(off.scenario().unwrap().verify_m, None);
        // g = 1 layouts refuse verification at scenario build, naming
        // the field (the satellite's "g=1 with verify_m: 2" case).
        let lone = SystemConfig { n_batches: 8, verify_m: 2, ..SystemConfig::default() };
        let err = lone.scenario().unwrap_err().to_string();
        assert!(err.contains("Scenario::verify_m"), "{err}");
        let bad = SystemConfig { verify_m: 2, verify_strikes: 0, ..SystemConfig::default() };
        assert!(bad.validate().is_err());
        // strikes knob is inert while verification is off.
        let inert = SystemConfig { verify_strikes: 0, ..SystemConfig::default() };
        assert!(inert.validate().is_ok());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("batchrep_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.toml");
        std::fs::write(&p, "n_workers = 12\nn_batches = 3\nservice = \"sexp:1.0,0.5\"\n")
            .unwrap();
        let cfg = SystemConfig::from_file(&p).unwrap();
        assert_eq!(cfg.n_workers, 12);
        std::fs::remove_dir_all(&dir).ok();
    }
}
