//! Synthetic straggler traces.
//!
//! The paper's stragglers come from "resource contention, network
//! congestion, I/O" in production clusters; we have no such traces in
//! this environment, so this module *synthesizes* them (documented
//! substitution, DESIGN.md §4): a worker's slowdown follows a two-state
//! Markov-modulated process (NORMAL ↔ CONGESTED) — the standard bursty
//! contention model — and the per-unit service time is the base service
//! time multiplied by the state's slowdown factor. Traces are
//! deterministic given a seed and can be saved/loaded as CSV for replay.

use crate::dist::ServiceSpec;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Parameters of the two-state Markov-modulated slowdown process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MarkovTraceParams {
    /// Probability of entering congestion from the normal state, per draw.
    pub p_enter: f64,
    /// Probability of leaving congestion, per draw (mean burst length is
    /// `1/p_exit` draws).
    pub p_exit: f64,
    /// Multiplicative slowdown while congested.
    pub slowdown: f64,
    /// Base per-unit service time distribution (sampled per draw).
    pub base_mu: f64,
    /// Base shift (SExp shift of the underlying service).
    pub base_delta: f64,
}

impl Default for MarkovTraceParams {
    fn default() -> Self {
        // ~5% of time congested in bursts of mean length 20, 8× slower —
        // the "contention + I/O burst" regime described in the paper's
        // straggler citations (Dean & Barroso, The Tail at Scale).
        Self {
            p_enter: 1.0 / 380.0,
            p_exit: 1.0 / 20.0,
            slowdown: 8.0,
            base_mu: 1.0,
            base_delta: 0.2,
        }
    }
}

/// Generate a service-time trace of `n` per-unit draws.
pub fn generate_markov_trace(params: &MarkovTraceParams, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let base = ServiceSpec::shifted_exp(params.base_mu, params.base_delta);
    let mut congested = false;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if congested {
            if rng.coin(params.p_exit) {
                congested = false;
            }
        } else if rng.coin(params.p_enter) {
            congested = true;
        }
        let factor = if congested { params.slowdown } else { 1.0 };
        out.push(base.sample(&mut rng) * factor);
    }
    out
}

/// Wrap a trace as a replayable [`ServiceSpec`].
pub fn trace_spec(samples: Vec<f64>) -> ServiceSpec {
    ServiceSpec::Trace { samples: Arc::new(samples) }
}

/// Save a trace as one-value-per-line CSV. Values are written with the
/// shortest representation that parses back to the *exact* same f64
/// (`{:?}`), so a saved trace replays bit-identically to the original —
/// not merely within rounding error.
pub fn save_trace(path: &std::path::Path, samples: &[f64]) -> std::io::Result<()> {
    let body: String = samples.iter().map(|x| format!("{x:?}\n")).collect();
    std::fs::write(path, body)
}

/// Load a trace saved by [`save_trace`].
pub fn load_trace(path: &std::path::Path) -> anyhow::Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("bad trace line '{l}': {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let p = MarkovTraceParams::default();
        let a = generate_markov_trace(&p, 1000, 42);
        let b = generate_markov_trace(&p, 1000, 42);
        assert_eq!(a, b);
        let c = generate_markov_trace(&p, 1000, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn congestion_fraction_plausible() {
        let p = MarkovTraceParams::default();
        let t = generate_markov_trace(&p, 200_000, 1);
        // Stationary congested fraction ≈ p_enter/(p_enter+p_exit) ≈ 5%.
        // Values above 5.0 are overwhelmingly congested draws
        // (P[normal draw > 5] = e^{-4.8} ≈ 0.8%, while a congested draw
        // exceeds 5 with probability e^{-(5/8-0.2)} ≈ 65%).
        let slow = t.iter().filter(|&&x| x > 5.0).count() as f64 / t.len() as f64;
        assert!(slow > 0.01 && slow < 0.12, "slow fraction {slow}");
    }

    #[test]
    fn trace_mean_exceeds_base_mean() {
        let p = MarkovTraceParams::default();
        let t = generate_markov_trace(&p, 100_000, 2);
        let mean = t.iter().sum::<f64>() / t.len() as f64;
        // Base mean = delta + 1/mu = 1.2; bursts push it up.
        assert!(mean > 1.2, "mean={mean}");
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join("batchrep_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let t = generate_markov_trace(&MarkovTraceParams::default(), 100, 3);
        save_trace(&path, &t).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(t.len(), loaded.len());
        for (i, (a, b)) in t.iter().zip(&loaded).enumerate() {
            // Bit-exact: a replayed trace must be stream-identical to
            // the one that was saved, not just close.
            assert_eq!(a.to_bits(), b.to_bits(), "sample {i}: {a} != {b}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
