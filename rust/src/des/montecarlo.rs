//! Fast Monte-Carlo sampler of the job completion time.
//!
//! One trial: draw every worker's batch service time, then find the
//! earliest time at which the union of finished workers' data units
//! covers the dataset. For disjoint layouts this reduces to
//! `max_b min_{w ∈ batch b} t_w` and runs in O(N); overlapping layouts
//! use an O(N log N) sort + incremental coverage count. Scenarios with
//! a [`Scenario::k_of_b`] partial-aggregation target reduce instead to
//! the k-th order statistic of the per-batch earliest-replica times.
//!
//! # Throughput architecture
//!
//! The trial loop is built for millions of trials per second:
//!
//! * **Block sampling** — service times for many trials are drawn in one
//!   [`crate::dist::BatchService::fill_batch_times`] call, so the
//!   uniform→service transform runs as a tight vectorizable loop
//!   (`fast_ln`, no libm calls) instead of one enum dispatch per draw.
//! * **Zero-allocation trials** — a reusable [`TrialScratch`] holds the
//!   block time buffer, the sort-order index buffer, and a
//!   generation-stamped coverage array, so steady-state trials perform
//!   no heap allocation at all (overlapping layouts included).
//! * **Deterministic sharding** — [`run_trials_parallel`] splits trials
//!   over [`LOGICAL_SHARDS`] fixed logical shards with per-shard RNG
//!   substreams and merges shard summaries in shard-index order; OS
//!   threads only execute the plan, so a fixed `(seed, trials)` pair is
//!   bit-reproducible regardless of thread scheduling **and of the
//!   thread count itself**.
//!
//! [`run_trials_reference`] retains the pre-block scalar sampler as the
//! measured baseline for the `bench-mc` perf harness.

use super::Scenario;
use crate::util::rng::Rng;
use crate::util::stats::{Samples, Welford};
use std::cell::RefCell;

/// Upper bound on raw samples retained per run for quantile estimates
/// (shared with the DES engine's trial runners).
pub(crate) const SAMPLE_CAP: u64 = 200_000;

/// Sample-thinning rate for `trials` trials under [`SAMPLE_CAP`] — the
/// one formula every trial runner (MC and DES engine) uses, so their
/// retained sample sets obey the same cap.
pub(crate) fn keep_every(trials: u64) -> u64 {
    trials.div_ceil(SAMPLE_CAP).max(1)
}

/// Size cap (in f64 elements) of the block time buffer: `n_workers ×
/// trials-per-fill` stays under this so the working set lives in L1/L2.
const BLOCK_ELEMS: usize = 8192;

/// Trials drawn per `fill_batch_times` call for an `n`-worker scenario.
#[inline]
fn trials_per_block(n: usize) -> usize {
    (BLOCK_ELEMS / n.max(1)).clamp(1, 512)
}

/// Reusable per-trial working memory. One instance amortizes every
/// allocation of the trial loop: the block of per-worker finish times,
/// the sort-order indices for overlapping layouts, and a coverage array
/// stamped with a generation counter so it never needs clearing.
#[derive(Debug, Default)]
pub struct TrialScratch {
    /// Per-worker finish times for a block of trials (trial-major).
    times: Vec<f64>,
    /// Worker indices sorted by finish time (overlapping layouts).
    order: Vec<u32>,
    /// `covered[u] == generation` ⇔ unit `u` covered in this trial.
    covered: Vec<u32>,
    /// Coverage generation stamp of the current trial.
    generation: u32,
    /// Per-batch earliest-replica times (k-of-B partial aggregation).
    batch_min: Vec<f64>,
    /// Per-replica times of one batch (m-of-g verified completion).
    replica: Vec<f64>,
}

impl TrialScratch {
    /// Fresh (empty) scratch; buffers grow on first use and are reused
    /// afterwards.
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the time buffer to at least `len` elements.
    fn ensure_times(&mut self, len: usize) {
        if self.times.len() < len {
            self.times.resize(len, 0.0);
        }
    }

    /// Completion time of the trial stored at `times[lo .. lo+n]`.
    #[inline]
    fn completion_at(&mut self, scn: &Scenario, lo: usize) -> f64 {
        if let Some(m) = scn.verify_m {
            return self.verified_completion_at(scn, lo, m);
        }
        if let Some(k) = scn.k_of_b {
            return self.partial_completion_at(scn, lo, k);
        }
        let n = scn.n_workers();
        let times = &self.times[lo..lo + n];
        if !scn.layout.is_overlapping {
            return disjoint_completion(scn, times);
        }
        // Overlapping: incremental coverage in time order, with the
        // order/coverage buffers reused across trials.
        self.order.clear();
        self.order.extend(0..n as u32);
        self.order
            .sort_unstable_by(|&a, &b| times[a as usize].total_cmp(&times[b as usize]));
        let n_units = scn.layout.n_units;
        if self.covered.len() < n_units {
            self.covered.resize(n_units, 0);
        }
        self.generation = self.generation.wrapping_add(1);
        if self.generation == 0 {
            // Stamp wraparound: clear once every 2^32 trials.
            self.covered.fill(0);
            self.generation = 1;
        }
        let gen = self.generation;
        let mut n_covered = 0usize;
        for &w in &self.order {
            let w = w as usize;
            let b = scn.assignment.batch_of_worker[w];
            for &u in &scn.layout.units_of_batch[b] {
                if self.covered[u] != gen {
                    self.covered[u] = gen;
                    n_covered += 1;
                }
            }
            if n_covered == n_units {
                return times[w];
            }
        }
        // Layout validation guarantees coverage; unreachable in practice.
        f64::INFINITY
    }

    /// k-of-B completion of the trial at `times[lo .. lo+n]`: the k-th
    /// earliest batch completion, where a batch completes when its
    /// earliest replica finishes (layout-independent — overlapping
    /// layouts count batches, not units, under partial aggregation).
    #[inline]
    fn partial_completion_at(&mut self, scn: &Scenario, lo: usize, k: usize) -> f64 {
        let n = scn.n_workers();
        let times = &self.times[lo..lo + n];
        self.batch_min.clear();
        for ws in &scn.assignment.workers_of_batch {
            let mut best = f64::INFINITY;
            for &w in ws {
                best = best.min(times[w]);
            }
            self.batch_min.push(best);
        }
        let k = k.clamp(1, self.batch_min.len());
        let (_, kth, _) = self.batch_min.select_nth_unstable_by(k - 1, f64::total_cmp);
        *kth
    }

    /// m-of-g verified completion of the trial at `times[lo .. lo+n]`:
    /// a batch completes at the m-th order statistic of its replica
    /// finish times (the voting quorum), and the job at the k-th
    /// earliest batch (k = B when no partial-aggregation target).
    /// `with_verify_m` guarantees every batch has ≥ m replicas.
    #[inline]
    fn verified_completion_at(&mut self, scn: &Scenario, lo: usize, m: usize) -> f64 {
        self.batch_min.clear();
        for ws in &scn.assignment.workers_of_batch {
            self.replica.clear();
            for &w in ws {
                self.replica.push(self.times[lo + w]);
            }
            let mi = m.clamp(1, self.replica.len());
            let (_, mth, _) = self.replica.select_nth_unstable_by(mi - 1, f64::total_cmp);
            let t = *mth;
            self.batch_min.push(t);
        }
        match scn.k_of_b {
            Some(k) => {
                let k = k.clamp(1, self.batch_min.len());
                let (_, kth, _) =
                    self.batch_min.select_nth_unstable_by(k - 1, f64::total_cmp);
                *kth
            }
            None => crate::util::stats::fold_max_total(self.batch_min.iter().copied()),
        }
    }
}

/// Disjoint-layout reduction: per-batch earliest replica, then the
/// slowest batch.
#[inline]
fn disjoint_completion(scn: &Scenario, times: &[f64]) -> f64 {
    let mut worst = f64::NEG_INFINITY;
    for ws in &scn.assignment.workers_of_batch {
        let mut best = f64::INFINITY;
        for &w in ws {
            best = best.min(times[w]);
        }
        worst = worst.max(best);
    }
    worst
}

/// Draw the per-worker finish times of `cnt` trials into
/// `times[.. cnt*n]` (trial-major) and apply heterogeneous speeds.
#[inline]
fn fill_trials(scn: &Scenario, rng: &mut Rng, times: &mut [f64], n: usize) {
    scn.service.fill_batch_times(scn.batch_units(), times, rng);
    if let Some(speeds) = &scn.worker_speeds {
        for trial in times.chunks_exact_mut(n) {
            for (x, sp) in trial.iter_mut().zip(speeds) {
                *x *= sp;
            }
        }
    }
}

thread_local! {
    /// Per-thread scratch behind [`sample_completion`], so one-off draws
    /// are allocation-free in steady state too.
    static LOCAL_SCRATCH: RefCell<TrialScratch> = RefCell::new(TrialScratch::new());
}

/// Draw one completion time (reuses a thread-local [`TrialScratch`];
/// bulk callers should hold their own scratch and use
/// [`sample_completion_into`]).
#[inline]
pub fn sample_completion(scn: &Scenario, rng: &mut Rng) -> f64 {
    LOCAL_SCRATCH.with(|s| sample_completion_into(scn, rng, &mut s.borrow_mut()))
}

/// Draw one completion time reusing `scratch` for all working memory.
#[inline]
pub fn sample_completion_into(scn: &Scenario, rng: &mut Rng, scratch: &mut TrialScratch) -> f64 {
    let n = scn.n_workers();
    scratch.ensure_times(n);
    fill_trials(scn, rng, &mut scratch.times[..n], n);
    scratch.completion_at(scn, 0)
}

/// Completion time for a given vector of per-worker finish times — the
/// generic reference reduction, shared with the event engine, the live
/// coordinator's post-hoc validation, and the property tests that pin
/// the scratch-based fast paths to it.
pub fn completion_from_times(scn: &Scenario, times: &[f64]) -> f64 {
    if let Some(m) = scn.verify_m {
        // m-of-g verification: every batch waits for its m-th replica;
        // the job completes at the k-th batch (k = B without a partial
        // target).
        let mut batch: Vec<f64> = scn
            .assignment
            .workers_of_batch
            .iter()
            .map(|ws| {
                let mut xs: Vec<f64> = ws.iter().map(|&w| times[w]).collect();
                let mi = m.clamp(1, xs.len());
                let (_, mth, _) = xs.select_nth_unstable_by(mi - 1, f64::total_cmp);
                *mth
            })
            .collect();
        batch.sort_unstable_by(f64::total_cmp);
        let k = scn.k_of_b.unwrap_or(batch.len()).clamp(1, batch.len());
        return batch[k - 1];
    }
    if let Some(k) = scn.k_of_b {
        // k-of-B: the k-th earliest batch completion (a batch completes
        // when its earliest replica finishes), regardless of layout.
        let b = scn.assignment.n_batches;
        let mut mins: Vec<f64> = scn
            .assignment
            .workers_of_batch
            .iter()
            .map(|ws| {
                let mut best = f64::INFINITY;
                for &w in ws {
                    best = best.min(times[w]);
                }
                best
            })
            .collect();
        mins.sort_unstable_by(f64::total_cmp);
        return mins[k.clamp(1, b) - 1];
    }
    if !scn.layout.is_overlapping {
        disjoint_completion(scn, times)
    } else {
        // Overlapping: incremental coverage in time order.
        let n_units = scn.layout.n_units;
        let mut order: Vec<usize> = (0..times.len()).collect();
        order.sort_unstable_by(|&a, &b| times[a].total_cmp(&times[b]));
        let mut covered = vec![false; n_units];
        let mut n_covered = 0usize;
        for &w in &order {
            let b = scn.assignment.batch_of_worker[w];
            for &u in &scn.layout.units_of_batch[b] {
                if !covered[u] {
                    covered[u] = true;
                    n_covered += 1;
                }
            }
            if n_covered == n_units {
                return times[w];
            }
        }
        // Layout validation guarantees coverage; unreachable in practice.
        f64::INFINITY
    }
}

/// Summary of a Monte-Carlo run.
#[derive(Debug, Clone)]
pub struct McSummary {
    /// Streaming statistics over all trials.
    pub welford: Welford,
    /// Retained raw samples (capped) for quantile estimates.
    pub samples: Samples,
}

impl McSummary {
    /// Mean completion time.
    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    /// Completion-time variance.
    pub fn variance(&self) -> f64 {
        self.welford.variance()
    }

    /// 95% confidence half-width of the mean.
    pub fn ci95(&self) -> f64 {
        1.96 * self.welford.sem()
    }
}

/// One shard of the trial loop: `trials` block-sampled trials from an
/// already-positioned RNG, keeping every `keep_every`-th sample.
/// Crate-visible so the [`crate::study`] planner can schedule shards of
/// *different* cells across one shared worker pool while reproducing
/// [`run_trials_parallel`]'s per-cell results bit-for-bit.
pub(crate) fn run_shard(
    scn: &Scenario,
    trials: u64,
    mut rng: Rng,
    keep_every: u64,
    scratch: &mut TrialScratch,
) -> McSummary {
    let n = scn.n_workers();
    let _span = crate::obs::span("mc.shard");
    crate::obs::bump(crate::obs::Counter::McShards, 1);
    crate::obs::bump(crate::obs::Counter::McTrials, trials);
    if crate::obs::enabled() {
        crate::obs::emit("mc", "shard", &[("trials", trials.into()), ("workers", n.into())]);
    }
    let block = trials_per_block(n);
    let mut welford = Welford::new();
    let mut samples = Samples::with_capacity((trials / keep_every) as usize + 1);
    scratch.ensure_times(n * block);
    let mut i = 0u64;
    while i < trials {
        let cnt = ((trials - i) as usize).min(block);
        fill_trials(scn, &mut rng, &mut scratch.times[..n * cnt], n);
        for t in 0..cnt {
            let v = scratch.completion_at(scn, t * n);
            welford.push(v);
            if i % keep_every == 0 {
                samples.push(v);
            }
            i += 1;
        }
    }
    McSummary { welford, samples }
}

/// Run `trials` independent trials (single-threaded, block-sampled).
pub fn run_trials(scn: &Scenario, trials: u64, seed: u64) -> McSummary {
    run_trials_with(scn, trials, seed, &mut TrialScratch::new())
}

/// [`run_trials`] with caller-owned scratch, for sweep drivers that run
/// many configurations back to back without reallocating.
pub fn run_trials_with(
    scn: &Scenario,
    trials: u64,
    seed: u64,
    scratch: &mut TrialScratch,
) -> McSummary {
    run_shard(scn, trials, Rng::new(seed), keep_every(trials), scratch)
}

/// One pre-block trial: scalar `sample_batch` calls per draw, including
/// the old homogeneous-disjoint fold (per-batch min / global max with
/// no times materialization) and the old allocating overlapping path.
fn reference_sample_completion(scn: &Scenario, rng: &mut Rng, scratch: &mut Vec<f64>) -> f64 {
    let n = scn.n_workers();
    let s = scn.batch_units();
    scratch.clear();
    match &scn.worker_speeds {
        None => {
            if !scn.layout.is_overlapping && scn.k_of_b.is_none() && scn.verify_m.is_none() {
                // Homogeneous disjoint fast path of the pre-block code:
                // fold directly without materializing times at all.
                // (k-of-B and verify_m postdate this baseline; those
                // scenarios take the generic reduction below.)
                let mut worst = f64::NEG_INFINITY;
                for ws in &scn.assignment.workers_of_batch {
                    let mut best = f64::INFINITY;
                    for _ in 0..ws.len() {
                        let t = scn.service.sample_batch(s, rng);
                        if t < best {
                            best = t;
                        }
                    }
                    if best > worst {
                        worst = best;
                    }
                }
                return worst;
            }
            for _ in 0..n {
                scratch.push(scn.service.sample_batch(s, rng));
            }
        }
        Some(speeds) => {
            for w in 0..n {
                scratch.push(scn.service.sample_batch(s, rng) * speeds[w]);
            }
        }
    }
    completion_from_times(scn, scratch)
}

/// The pre-block scalar sampler — one `sample_batch` enum dispatch per
/// draw, the old disjoint fold, per-trial order/coverage allocations on
/// overlapping layouts — faithfully reproducing the trial loop as it
/// worked before the block kernel. Kept (not dead code) as the measured
/// baseline of the `bench-mc` throughput harness; evaluators never call
/// it.
pub fn run_trials_reference(scn: &Scenario, trials: u64, seed: u64) -> McSummary {
    let mut rng = Rng::new(seed);
    let mut welford = Welford::new();
    let keep_every = keep_every(trials);
    let mut samples = Samples::with_capacity((trials / keep_every) as usize + 1);
    let mut times = Vec::with_capacity(scn.n_workers());
    for i in 0..trials {
        let t = reference_sample_completion(scn, &mut rng, &mut times);
        welford.push(t);
        if i % keep_every == 0 {
            samples.push(t);
        }
    }
    McSummary { welford, samples }
}

/// Number of fixed *logical* shards every parallel trial runner splits
/// its trials into (fewer when there are fewer trials than shards). The
/// shard count — and therefore every shard's RNG substream and trial
/// budget — is a constant of the run, **not** a function of the worker
/// thread count, so results are identical no matter how many OS threads
/// execute the plan.
pub(crate) const LOGICAL_SHARDS: u64 = 64;

/// Deterministic shard plan shared by every parallel trial runner (this
/// sampler and the DES engine's [`crate::des::engine::simulate_many_parallel`]):
/// per-shard trial counts (the remainder spread over the first shards)
/// and per-shard RNG substreams over [`LOGICAL_SHARDS`] fixed shards.
/// The plan depends only on `(trials, seed)` — thread counts never
/// enter it — so sharded results are reproducible across machines and
/// across any `threads` setting.
pub(crate) fn shard_plan(trials: u64, seed: u64) -> Vec<(u64, Rng)> {
    let shards = LOGICAL_SHARDS.min(trials.max(1));
    let per = trials / shards;
    let extra = trials % shards;
    let root = Rng::new(seed);
    (0..shards)
        .map(|t| {
            // Substream seeds: independent per logical shard, stable
            // across runs and thread counts for a fixed seed.
            (per + u64::from(t < extra), root.substream(t + 1))
        })
        .collect()
}

/// Execute a [`shard_plan`] on up to `threads` OS threads (shard `i`
/// goes to worker `i % workers`; each worker owns one reusable `state`)
/// and return the per-shard results **in shard-index order** — the one
/// shared execution scaffold of every parallel trial runner, so the
/// thread-count-invariance argument lives in exactly one place.
pub(crate) fn execute_shard_plan<T, S>(
    plan: Vec<(u64, Rng)>,
    threads: usize,
    make_state: impl Fn() -> S + Sync,
    run: impl Fn(&mut S, u64, Rng) -> T + Sync,
) -> Vec<T>
where
    T: Send,
{
    let workers = threads.max(1).min(plan.len());
    if workers <= 1 {
        let mut state = make_state();
        return plan.into_iter().map(|(t, rng)| run(&mut state, t, rng)).collect();
    }
    let mut tagged: Vec<(usize, T)> = Vec::with_capacity(plan.len());
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let plan_ref = &plan;
                let make_ref = &make_state;
                let run_ref = &run;
                scope.spawn(move || {
                    let mut state = make_ref();
                    let mut out = Vec::new();
                    let mut i = w;
                    while i < plan_ref.len() {
                        let (t, rng) = plan_ref[i].clone();
                        out.push((i, run_ref(&mut state, t, rng)));
                        i += workers;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(shard) => tagged.extend(shard),
                // Re-raise a shard worker's panic on the caller thread
                // with its original payload.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    // Shard results are merged in shard-index order, never in thread
    // completion order — the heart of the any-thread-count determinism.
    tagged.sort_by_key(|(i, _)| *i);
    tagged.into_iter().map(|(_, s)| s).collect()
}

/// Sharded trial runner: splits `trials` over the fixed
/// [`LOGICAL_SHARDS`] logical shards with independent RNG substreams
/// ([`shard_plan`]) and executes the plan via [`execute_shard_plan`].
/// Shard summaries are merged in shard-index order after all threads
/// join, so the result is independent of thread completion order **and
/// of the thread count itself**: a fixed `(scenario, trials, seed)`
/// triple produces a bit-identical [`McSummary`] for every
/// `threads ∈ {1, 2, 4, …}`.
pub fn run_trials_parallel(
    scn: &Scenario,
    trials: u64,
    seed: u64,
    threads: usize,
) -> McSummary {
    // One shared thinning rate, so the union of shard sample sets obeys
    // the global cap and depends only on the trial count.
    let keep_every = keep_every(trials);
    let shards = execute_shard_plan(
        shard_plan(trials, seed),
        threads,
        TrialScratch::new,
        |scratch, t, rng| run_shard(scn, t, rng, keep_every, scratch),
    );
    merge_shard_summaries(shards)
}

/// Merge per-shard summaries **in shard-index order**: Welford merges
/// for the moments, shard-order concatenation for the retained
/// samples. The single definition shared by [`run_trials_parallel`]
/// and the study pool ([`crate::study`]), so their per-cell bitwise
/// equality holds by construction.
pub(crate) fn merge_shard_summaries(
    shards: impl IntoIterator<Item = McSummary>,
) -> McSummary {
    let mut welford = Welford::new();
    let mut samples = Samples::new();
    for sh in shards {
        welford.merge(&sh.welford);
        for &x in sh.samples.raw() {
            samples.push(x);
        }
    }
    McSummary { welford, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::completion_time_stats;
    use crate::assignment::Policy;
    use crate::dist::{BatchService, ServiceSpec};
    use crate::testkit;

    fn paper_scn(n: usize, b: usize, spec: ServiceSpec) -> Scenario {
        Scenario::paper_balanced(n, b, BatchService::paper(spec)).unwrap()
    }

    #[test]
    fn matches_closed_form_sexp() {
        // The crucial cross-validation: MC ≈ Eq. (4).
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        for (n, b) in [(8usize, 2usize), (12, 4), (24, 6)] {
            let scn = paper_scn(n, b, spec.clone());
            let mc = run_trials(&scn, 200_000, 42);
            let cf = completion_time_stats(n as u64, b as u64, &spec).unwrap();
            assert!(
                (mc.mean() - cf.mean).abs() < 4.0 * mc.ci95().max(1e-3),
                "n={n} B={b}: mc={} cf={}",
                mc.mean(),
                cf.mean
            );
            let rel_var = (mc.variance() - cf.var).abs() / cf.var;
            assert!(rel_var < 0.05, "var: mc={} cf={}", mc.variance(), cf.var);
        }
    }

    #[test]
    fn matches_closed_form_exp() {
        let spec = ServiceSpec::exp(2.0);
        let scn = paper_scn(12, 3, spec.clone());
        let mc = run_trials(&scn, 200_000, 7);
        let cf = completion_time_stats(12, 3, &spec).unwrap();
        assert!((mc.mean() - cf.mean).abs() < 0.01, "mc={} cf={}", mc.mean(), cf.mean);
    }

    #[test]
    fn deterministic_given_seed() {
        let scn = paper_scn(8, 4, ServiceSpec::exp(1.0));
        let a = run_trials(&scn, 1000, 5).mean();
        let b = run_trials(&scn, 1000, 5).mean();
        assert_eq!(a, b);
    }

    #[test]
    fn block_sampler_agrees_with_scalar_reference() {
        // The block kernel must describe the same system as the retained
        // scalar baseline: identical RNG stream, values within fast_ln
        // rounding of each other.
        for overlap in [false, true] {
            let svc = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.3));
            let scn = if overlap {
                let layout = crate::batching::overlapping(12, 12, 3).unwrap();
                let assignment = crate::assignment::balanced(12, 12).unwrap();
                Scenario::new(layout, assignment, svc).unwrap()
            } else {
                Scenario::paper_balanced(12, 4, svc).unwrap()
            };
            let blk = run_trials(&scn, 20_000, 9);
            let refr = run_trials_reference(&scn, 20_000, 9);
            assert!(
                (blk.mean() - refr.mean()).abs() <= 1e-9 * refr.mean(),
                "overlap={overlap}: block {} vs reference {}",
                blk.mean(),
                refr.mean()
            );
            assert!(
                (blk.variance() - refr.variance()).abs() <= 1e-6 * refr.variance().max(1e-9),
                "overlap={overlap}: var block {} vs reference {}",
                blk.variance(),
                refr.variance()
            );
        }
    }

    #[test]
    fn scratch_reuse_across_scenarios_is_clean() {
        // One scratch driven through scenarios of different shapes and
        // layouts must give the same answers as fresh scratch each time.
        let mut scratch = TrialScratch::new();
        let configs: Vec<Scenario> = vec![
            paper_scn(24, 6, ServiceSpec::exp(1.0)),
            {
                let svc = BatchService::paper(ServiceSpec::exp(1.0));
                let layout = crate::batching::overlapping(8, 8, 2).unwrap();
                let assignment = crate::assignment::balanced(8, 8).unwrap();
                Scenario::new(layout, assignment, svc).unwrap()
            },
            paper_scn(4, 2, ServiceSpec::shifted_exp(1.0, 0.5)),
        ];
        for scn in &configs {
            let reused = run_trials_with(scn, 5_000, 3, &mut scratch);
            let fresh = run_trials(scn, 5_000, 3);
            assert_eq!(reused.mean().to_bits(), fresh.mean().to_bits());
            assert_eq!(reused.variance().to_bits(), fresh.variance().to_bits());
        }
    }

    #[test]
    fn overlapping_coverage_semantics() {
        // 4 units, 4 windows of 2 (stride 1). Hand-crafted times:
        // worker i holds units {i, i+1 mod 4}.
        let layout = crate::batching::overlapping(4, 4, 2).unwrap();
        let assignment = crate::assignment::balanced(4, 4).unwrap();
        let scn = Scenario::new(
            layout,
            assignment,
            BatchService::paper(ServiceSpec::exp(1.0)),
        )
        .unwrap();
        // Workers 0 and 2 cover {0,1} ∪ {2,3} = everything at t=2.
        let t = completion_from_times(&scn, &[1.0, 10.0, 2.0, 10.0]);
        assert_eq!(t, 2.0);
        // Without worker 2, needs workers 1 and 3 as well.
        let t = completion_from_times(&scn, &[1.0, 3.0, 10.0, 4.0]);
        assert_eq!(t, 4.0);
    }

    #[test]
    fn full_diversity_is_min_of_all_workers() {
        let scn = paper_scn(6, 1, ServiceSpec::exp(1.0));
        let t = completion_from_times(&scn, &[5.0, 3.0, 9.0, 4.0, 8.0, 7.0]);
        assert_eq!(t, 3.0);
    }

    #[test]
    fn full_parallelism_is_max_of_all_workers() {
        let scn = paper_scn(6, 6, ServiceSpec::exp(1.0));
        let t = completion_from_times(&scn, &[5.0, 3.0, 9.0, 4.0, 8.0, 7.0]);
        assert_eq!(t, 9.0);
    }

    #[test]
    fn k_of_b_matches_partial_closed_form() {
        // The scenario-level partial-aggregation field must reproduce
        // the k-th-order-statistic closed form.
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        for (n, b, k) in [(24u64, 6u64, 3u64), (12, 4, 2), (24, 4, 4)] {
            let scn = paper_scn(n as usize, b as usize, spec.clone())
                .with_k_of_b(k as usize)
                .unwrap();
            let mc = run_trials(&scn, 150_000, 11);
            let cf =
                crate::analysis::partial_completion_stats(n, b, k, &spec).unwrap();
            assert!(
                (mc.mean() - cf.mean).abs() < 4.0 * mc.ci95().max(1e-3),
                "n={n} B={b} k={k}: mc {} vs cf {}",
                mc.mean(),
                cf.mean
            );
            let rel_var = (mc.variance() - cf.var).abs() / cf.var;
            assert!(rel_var < 0.06, "n={n} B={b} k={k}: var mc {} vs cf {}", mc.variance(), cf.var);
        }
    }

    #[test]
    fn verify_m_matches_verified_closed_form() {
        // The m-of-g MC path must reproduce the polynomial closed form
        // (analysis::verified_completion_stats) for both full and
        // partial completion.
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        for (n, b, m, k) in
            [(24u64, 4u64, 2u64, 4u64), (24, 4, 3, 4), (12, 3, 2, 2), (24, 6, 2, 6)]
        {
            let mut scn = paper_scn(n as usize, b as usize, spec.clone())
                .with_verify_m(m as usize)
                .unwrap();
            if k < b {
                scn = scn.with_k_of_b(k as usize).unwrap();
            }
            let mc = run_trials(&scn, 150_000, 17);
            let cf =
                crate::analysis::verified_completion_stats(n, b, m, k, &spec).unwrap();
            assert!(
                (mc.mean() - cf.mean).abs() < 4.0 * mc.ci95().max(1e-3),
                "n={n} B={b} m={m} k={k}: mc {} vs cf {}",
                mc.mean(),
                cf.mean
            );
            let rel_var = (mc.variance() - cf.var).abs() / cf.var;
            assert!(
                rel_var < 0.06,
                "n={n} B={b} m={m} k={k}: var mc {} vs cf {}",
                mc.variance(),
                cf.var
            );
        }
    }

    #[test]
    fn verify_m_1_is_bitwise_the_unverified_stream() {
        // m = 1 normalizes to None in with_verify_m, so the block
        // sampler's stream is untouched — the PR-7 bit-compat guarantee.
        let base = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.2));
        let normalized = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.2))
            .with_verify_m(1)
            .unwrap();
        let a = run_trials(&base, 20_000, 3);
        let b = run_trials(&normalized, 20_000, 3);
        assert_eq!(a.mean().to_bits(), b.mean().to_bits());
        assert_eq!(a.variance().to_bits(), b.variance().to_bits());
    }

    #[test]
    fn k_of_b_full_equals_unrestricted_on_disjoint_layouts() {
        // k = B on a disjoint layout is the ordinary completion: the
        // k-th smallest batch min is the max, bit-for-bit.
        let scn_full = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.2));
        let scn_k = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.2))
            .with_k_of_b(4)
            .unwrap();
        let a = run_trials(&scn_full, 20_000, 3);
        let b = run_trials(&scn_k, 20_000, 3);
        assert_eq!(a.mean().to_bits(), b.mean().to_bits());
        assert_eq!(a.variance().to_bits(), b.variance().to_bits());
    }

    #[test]
    fn heterogeneous_speeds_slow_down_completion() {
        let svc = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.5));
        let base = Scenario::paper_balanced(8, 4, svc.clone()).unwrap();
        let slow = Scenario::paper_balanced(8, 4, svc)
            .unwrap()
            .with_speeds(vec![3.0; 8])
            .unwrap();
        let m_base = run_trials(&base, 50_000, 1).mean();
        let m_slow = run_trials(&slow, 50_000, 1).mean();
        assert!((m_slow / m_base - 3.0).abs() < 0.1, "{m_base} vs {m_slow}");
    }

    #[test]
    fn parallel_matches_sequential_statistics() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        let scn = paper_scn(12, 4, spec.clone());
        let seq = run_trials(&scn, 100_000, 9);
        let par = run_trials_parallel(&scn, 100_000, 9, 4);
        assert_eq!(par.welford.count(), 100_000);
        assert!(
            (par.mean() - seq.mean()).abs() < 3.0 * (par.ci95() + seq.ci95()),
            "par {} vs seq {}",
            par.mean(),
            seq.mean()
        );
        let cf = completion_time_stats(12, 4, &spec).unwrap();
        assert!((par.mean() - cf.mean).abs() < 4.0 * par.ci95().max(1e-3));
        // Deterministic given (seed, threads).
        let par2 = run_trials_parallel(&scn, 100_000, 9, 4);
        assert_eq!(par.mean(), par2.mean());
    }

    #[test]
    fn parallel_bit_identical_across_runs() {
        // The acceptance bar: run_trials_parallel(seed, k) is fully
        // bit-reproducible — mean, variance, and the retained sample set.
        let scn = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.3));
        for k in [2usize, 4] {
            let a = run_trials_parallel(&scn, 30_000, 11, k);
            let b = run_trials_parallel(&scn, 30_000, 11, k);
            assert_eq!(a.welford.count(), 30_000);
            assert_eq!(a.mean().to_bits(), b.mean().to_bits(), "k={k}");
            assert_eq!(a.variance().to_bits(), b.variance().to_bits(), "k={k}");
            assert_eq!(a.samples.raw(), b.samples.raw(), "k={k}");
        }
    }

    #[test]
    fn parallel_degenerate_cases() {
        let scn = paper_scn(4, 2, ServiceSpec::exp(1.0));
        // threads > trials: the plan clamps to one shard per trial.
        let a = run_trials_parallel(&scn, 5, 3, 16);
        assert_eq!(a.welford.count(), 5);
        // threads = 1 executes the same logical-shard plan sequentially.
        let b = run_trials_parallel(&scn, 1000, 3, 1);
        assert_eq!(b.welford.count(), 1000);
    }

    #[test]
    fn parallel_is_invariant_to_thread_count() {
        // The logical-shard plan is fixed per (trials, seed), so the
        // thread count changes wall-clock only: every statistic —
        // moments, sem, and the retained sample set — is bit-identical
        // across thread counts (the conformance harness's determinism
        // property relies on this).
        let scn = paper_scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.3));
        let base = run_trials_parallel(&scn, 20_000, 13, 1);
        for threads in [2usize, 4, 8] {
            let run = run_trials_parallel(&scn, 20_000, 13, threads);
            assert_eq!(base.mean().to_bits(), run.mean().to_bits(), "threads={threads}");
            assert_eq!(
                base.variance().to_bits(),
                run.variance().to_bits(),
                "threads={threads}"
            );
            assert_eq!(base.samples.raw(), run.samples.raw(), "threads={threads}");
        }
    }

    #[test]
    fn prop_fast_path_matches_generic_reduction() {
        // The scratch-based sampler (disjoint fold and generation-stamped
        // coverage) must agree exactly with the generic
        // completion_from_times on the same drawn times — homogeneous
        // and heterogeneous speeds, disjoint and overlapping layouts.
        testkit::check("mc-fastpath-vs-generic", 80, |g| {
            let n = *g.pick(&[2usize, 4, 6, 8, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let overlap = g.coin(0.5);
            let svc = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2));
            let mut scn = if overlap {
                let stride = (n / b).max(1);
                let layout = crate::batching::overlapping(n, n, stride).unwrap();
                let assignment = crate::assignment::balanced(n, n).unwrap();
                Scenario::new(layout, assignment, svc).unwrap()
            } else {
                Scenario::paper_balanced(n, b, svc).unwrap()
            };
            if g.coin(0.5) {
                let speeds: Vec<f64> = (0..n).map(|_| g.f64_in(0.5, 3.0)).collect();
                scn = scn.with_speeds(speeds).unwrap();
            }
            if g.coin(0.4) {
                let bb = scn.assignment.n_batches;
                scn = scn.with_k_of_b(g.usize_in(1, bb)).unwrap();
            }
            let g_min = (0..scn.assignment.n_batches)
                .map(|bb| scn.assignment.replication(bb))
                .min()
                .unwrap_or(1);
            if g_min >= 2 && g.coin(0.4) {
                scn = scn.with_verify_m(g.usize_in(2, g_min)).unwrap();
            }
            let seed = g.u64_in(0, 1 << 40);
            let mut scratch = TrialScratch::new();
            let mut rng_fast = crate::util::rng::Rng::new(seed);
            // Several trials in sequence, so the generation stamps and
            // buffer reuse are exercised, not just the first trial.
            for trial in 0..4 {
                let fast = sample_completion_into(&scn, &mut rng_fast, &mut scratch);
                // Reproduce the exact same drawn times from a lockstep RNG.
                let mut rng_ref = crate::util::rng::Rng::new(seed);
                let mut times = vec![0.0f64; n * (trial + 1)];
                for t in 0..=trial {
                    fill_trials(&scn, &mut rng_ref, &mut times[t * n..(t + 1) * n], n);
                }
                let generic = completion_from_times(&scn, &times[trial * n..]);
                assert_eq!(
                    fast.to_bits(),
                    generic.to_bits(),
                    "n={n} b={b} overlap={overlap} trial={trial}: {fast} vs {generic}"
                );
            }
        });
    }

    #[test]
    fn prop_completion_bounded_by_extremes() {
        // For any scenario and any finish times, completion lies between
        // the fastest and slowest worker.
        testkit::check("mc-bounds", 150, |g| {
            let n = *g.pick(&[2usize, 4, 6, 8, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let policy = *g.pick(Policy::all());
            let mut rng = g.rng();
            let assignment = policy.assign(n, b, &mut rng).unwrap();
            let eff_b = assignment.n_batches;
            let layout = if g.coin(0.5) && n % eff_b == 0 {
                crate::batching::disjoint(n, eff_b).unwrap()
            } else {
                let stride = n / eff_b;
                crate::batching::overlapping(n, eff_b, stride.max(1)).unwrap()
            };
            let scn = Scenario::new(
                layout,
                assignment,
                crate::dist::BatchService::paper(ServiceSpec::exp(1.0)),
            )
            .unwrap();
            let times: Vec<f64> = (0..n).map(|_| rng.f64_in(0.1, 10.0)).collect();
            let t = completion_from_times(&scn, &times);
            let lo = crate::util::stats::fold_min_total(times.iter().cloned());
            let hi = crate::util::stats::fold_max_total(times.iter().cloned());
            assert!(t >= lo - 1e-12 && t <= hi + 1e-12, "t={t} not in [{lo},{hi}]");
        });
    }

    #[test]
    fn prop_more_replication_never_hurts_mean() {
        // Monotonicity along the spectrum for Exp: smaller B (more
        // diversity) has smaller MC mean (Theorem 2, sampled form).
        testkit::check("mc-exp-monotone", 20, |g| {
            let n = *g.pick(&[8usize, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let spec = ServiceSpec::exp(1.0);
            let seed = g.u64_in(0, u64::MAX / 2);
            let means: Vec<f64> = divisors
                .iter()
                .map(|&b| {
                    let scn = Scenario::paper_balanced(
                        n,
                        b,
                        crate::dist::BatchService::paper(spec.clone()),
                    )
                    .unwrap();
                    run_trials(&scn, 40_000, seed).mean()
                })
                .collect();
            for w in means.windows(2) {
                // Allow MC noise: 3% slack.
                assert!(w[1] >= w[0] * 0.97, "means not increasing: {means:?}");
            }
        });
    }
}
