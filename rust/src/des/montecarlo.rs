//! Fast Monte-Carlo sampler of the job completion time.
//!
//! One trial: draw every worker's batch service time, then find the
//! earliest time at which the union of finished workers' data units
//! covers the dataset. For disjoint layouts this reduces to
//! `max_b min_{w ∈ batch b} t_w` and runs in O(N); overlapping layouts
//! use an O(N log N) sort + incremental coverage count.

use super::Scenario;
use crate::util::rng::Rng;
use crate::util::stats::{Samples, Welford};

/// Draw one completion time (allocates a scratch buffer; the bulk-trial
/// path [`run_trials`] uses [`sample_completion_into`] to amortize it).
#[inline]
pub fn sample_completion(scn: &Scenario, rng: &mut Rng) -> f64 {
    let mut scratch = Vec::with_capacity(scn.n_workers());
    sample_completion_into(scn, rng, &mut scratch)
}

/// Draw one completion time reusing `scratch` for the per-worker times.
#[inline]
pub fn sample_completion_into(scn: &Scenario, rng: &mut Rng, scratch: &mut Vec<f64>) -> f64 {
    let n = scn.n_workers();
    let s = scn.batch_units();
    scratch.clear();
    match &scn.worker_speeds {
        None => {
            // Homogeneous fast path: skip the per-worker speed lookup.
            if !scn.layout.is_overlapping {
                // Disjoint layouts only need per-batch min / global max:
                // fold directly without materializing times at all.
                let mut worst = f64::NEG_INFINITY;
                for ws in &scn.assignment.workers_of_batch {
                    let mut best = f64::INFINITY;
                    for _ in 0..ws.len() {
                        let t = scn.service.sample_batch(s, rng);
                        if t < best {
                            best = t;
                        }
                    }
                    if best > worst {
                        worst = best;
                    }
                }
                return worst;
            }
            for _ in 0..n {
                scratch.push(scn.service.sample_batch(s, rng));
            }
        }
        Some(speeds) => {
            for w in 0..n {
                scratch.push(scn.service.sample_batch(s, rng) * speeds[w]);
            }
        }
    }
    completion_from_times(scn, scratch)
}

/// Completion time for a given vector of per-worker finish times —
/// shared with the event engine and with the live coordinator's
/// post-hoc validation.
pub fn completion_from_times(scn: &Scenario, times: &[f64]) -> f64 {
    if !scn.layout.is_overlapping {
        // Disjoint: per-batch earliest replica, then the slowest batch.
        let mut worst = f64::NEG_INFINITY;
        for ws in &scn.assignment.workers_of_batch {
            let mut best = f64::INFINITY;
            for &w in ws {
                if times[w] < best {
                    best = times[w];
                }
            }
            if best > worst {
                worst = best;
            }
        }
        worst
    } else {
        // Overlapping: incremental coverage in time order.
        let n_units = scn.layout.n_units;
        let mut order: Vec<usize> = (0..times.len()).collect();
        order.sort_unstable_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap());
        let mut covered = vec![false; n_units];
        let mut n_covered = 0usize;
        for &w in &order {
            let b = scn.assignment.batch_of_worker[w];
            for &u in &scn.layout.units_of_batch[b] {
                if !covered[u] {
                    covered[u] = true;
                    n_covered += 1;
                }
            }
            if n_covered == n_units {
                return times[w];
            }
        }
        // Layout validation guarantees coverage; unreachable in practice.
        f64::INFINITY
    }
}

/// Summary of a Monte-Carlo run.
#[derive(Debug, Clone)]
pub struct McSummary {
    /// Streaming statistics over all trials.
    pub welford: Welford,
    /// Retained raw samples (capped) for quantile estimates.
    pub samples: Samples,
}

impl McSummary {
    /// Mean completion time.
    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    /// Completion-time variance.
    pub fn variance(&self) -> f64 {
        self.welford.variance()
    }

    /// 95% confidence half-width of the mean.
    pub fn ci95(&self) -> f64 {
        1.96 * self.welford.sem()
    }
}

/// Run `trials` independent trials.
pub fn run_trials(scn: &Scenario, trials: u64, seed: u64) -> McSummary {
    const SAMPLE_CAP: u64 = 200_000;
    let mut rng = Rng::new(seed);
    let mut welford = Welford::new();
    let keep_every = trials.div_ceil(SAMPLE_CAP).max(1);
    let mut samples = Samples::with_capacity((trials / keep_every) as usize + 1);
    let mut scratch = Vec::with_capacity(scn.n_workers());
    for i in 0..trials {
        let t = sample_completion_into(scn, &mut rng, &mut scratch);
        welford.push(t);
        if i % keep_every == 0 {
            samples.push(t);
        }
    }
    McSummary { welford, samples }
}

/// Multi-threaded trial runner: shards `trials` across `threads` OS
/// threads with independent RNG substreams and merges the Welford
/// accumulators (quantile samples are kept per-shard and concatenated).
/// Deterministic for a fixed `(seed, threads)` pair.
pub fn run_trials_parallel(
    scn: &Scenario,
    trials: u64,
    seed: u64,
    threads: usize,
) -> McSummary {
    let threads = threads.max(1).min(trials.max(1) as usize);
    if threads == 1 {
        return run_trials(scn, trials, seed);
    }
    let per = trials / threads as u64;
    let extra = trials % threads as u64;
    let shards: Vec<McSummary> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let scn_ref = &*scn;
            let shard_trials = per + if (t as u64) < extra { 1 } else { 0 };
            // Substream seeds derived like Rng::substream: independent
            // per shard, stable across runs.
            let shard_seed = crate::util::rng::Rng::new(seed).substream(t as u64 + 1);
            handles.push(scope.spawn(move || {
                let mut rng = shard_seed;
                let mut welford = Welford::new();
                let keep_every = shard_trials.div_ceil(200_000 / threads as u64 + 1).max(1);
                let mut samples =
                    Samples::with_capacity((shard_trials / keep_every) as usize + 1);
                let mut scratch = Vec::with_capacity(scn_ref.n_workers());
                for i in 0..shard_trials {
                    let v = sample_completion_into(scn_ref, &mut rng, &mut scratch);
                    welford.push(v);
                    if i % keep_every == 0 {
                        samples.push(v);
                    }
                }
                McSummary { welford, samples }
            }));
        }
        handles.into_iter().map(|h| h.join().expect("mc shard panicked")).collect()
    });
    let mut welford = Welford::new();
    let mut samples = Samples::new();
    for s in shards {
        welford.merge(&s.welford);
        for &x in s.samples.raw() {
            samples.push(x);
        }
    }
    McSummary { welford, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::completion_time_stats;
    use crate::assignment::Policy;
    use crate::dist::{BatchService, ServiceSpec};
    use crate::testkit;

    fn paper_scn(n: usize, b: usize, spec: ServiceSpec) -> Scenario {
        Scenario::paper_balanced(n, b, BatchService::paper(spec)).unwrap()
    }

    #[test]
    fn matches_closed_form_sexp() {
        // The crucial cross-validation: MC ≈ Eq. (4).
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        for (n, b) in [(8usize, 2usize), (12, 4), (24, 6)] {
            let scn = paper_scn(n, b, spec.clone());
            let mc = run_trials(&scn, 200_000, 42);
            let cf = completion_time_stats(n as u64, b as u64, &spec).unwrap();
            assert!(
                (mc.mean() - cf.mean).abs() < 4.0 * mc.ci95().max(1e-3),
                "n={n} B={b}: mc={} cf={}",
                mc.mean(),
                cf.mean
            );
            let rel_var = (mc.variance() - cf.var).abs() / cf.var;
            assert!(rel_var < 0.05, "var: mc={} cf={}", mc.variance(), cf.var);
        }
    }

    #[test]
    fn matches_closed_form_exp() {
        let spec = ServiceSpec::exp(2.0);
        let scn = paper_scn(12, 3, spec.clone());
        let mc = run_trials(&scn, 200_000, 7);
        let cf = completion_time_stats(12, 3, &spec).unwrap();
        assert!((mc.mean() - cf.mean).abs() < 0.01, "mc={} cf={}", mc.mean(), cf.mean);
    }

    #[test]
    fn deterministic_given_seed() {
        let scn = paper_scn(8, 4, ServiceSpec::exp(1.0));
        let a = run_trials(&scn, 1000, 5).mean();
        let b = run_trials(&scn, 1000, 5).mean();
        assert_eq!(a, b);
    }

    #[test]
    fn overlapping_coverage_semantics() {
        // 4 units, 4 windows of 2 (stride 1). Hand-crafted times:
        // worker i holds units {i, i+1 mod 4}.
        let layout = crate::batching::overlapping(4, 4, 2).unwrap();
        let assignment = crate::assignment::balanced(4, 4).unwrap();
        let scn = Scenario::new(
            layout,
            assignment,
            BatchService::paper(ServiceSpec::exp(1.0)),
        )
        .unwrap();
        // Workers 0 and 2 cover {0,1} ∪ {2,3} = everything at t=2.
        let t = completion_from_times(&scn, &[1.0, 10.0, 2.0, 10.0]);
        assert_eq!(t, 2.0);
        // Without worker 2, needs workers 1 and 3 as well.
        let t = completion_from_times(&scn, &[1.0, 3.0, 10.0, 4.0]);
        assert_eq!(t, 4.0);
    }

    #[test]
    fn full_diversity_is_min_of_all_workers() {
        let scn = paper_scn(6, 1, ServiceSpec::exp(1.0));
        let t = completion_from_times(&scn, &[5.0, 3.0, 9.0, 4.0, 8.0, 7.0]);
        assert_eq!(t, 3.0);
    }

    #[test]
    fn full_parallelism_is_max_of_all_workers() {
        let scn = paper_scn(6, 6, ServiceSpec::exp(1.0));
        let t = completion_from_times(&scn, &[5.0, 3.0, 9.0, 4.0, 8.0, 7.0]);
        assert_eq!(t, 9.0);
    }

    #[test]
    fn heterogeneous_speeds_slow_down_completion() {
        let svc = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.5));
        let base = Scenario::paper_balanced(8, 4, svc.clone()).unwrap();
        let slow = Scenario::paper_balanced(8, 4, svc)
            .unwrap()
            .with_speeds(vec![3.0; 8])
            .unwrap();
        let m_base = run_trials(&base, 50_000, 1).mean();
        let m_slow = run_trials(&slow, 50_000, 1).mean();
        assert!((m_slow / m_base - 3.0).abs() < 0.1, "{m_base} vs {m_slow}");
    }

    #[test]
    fn parallel_matches_sequential_statistics() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        let scn = paper_scn(12, 4, spec.clone());
        let seq = run_trials(&scn, 100_000, 9);
        let par = run_trials_parallel(&scn, 100_000, 9, 4);
        assert_eq!(par.welford.count(), 100_000);
        assert!(
            (par.mean() - seq.mean()).abs() < 3.0 * (par.ci95() + seq.ci95()),
            "par {} vs seq {}",
            par.mean(),
            seq.mean()
        );
        let cf = completion_time_stats(12, 4, &spec).unwrap();
        assert!((par.mean() - cf.mean).abs() < 4.0 * par.ci95().max(1e-3));
        // Deterministic given (seed, threads).
        let par2 = run_trials_parallel(&scn, 100_000, 9, 4);
        assert_eq!(par.mean(), par2.mean());
    }

    #[test]
    fn parallel_degenerate_cases() {
        let scn = paper_scn(4, 2, ServiceSpec::exp(1.0));
        // threads > trials, threads = 1
        let a = run_trials_parallel(&scn, 5, 3, 16);
        assert_eq!(a.welford.count(), 5);
        let b = run_trials_parallel(&scn, 1000, 3, 1);
        let c = run_trials(&scn, 1000, 3);
        assert_eq!(b.mean(), c.mean());
    }

    #[test]
    fn prop_completion_bounded_by_extremes() {
        // For any scenario and any finish times, completion lies between
        // the fastest and slowest worker.
        testkit::check("mc-bounds", 150, |g| {
            let n = *g.pick(&[2usize, 4, 6, 8, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let policy = *g.pick(Policy::all());
            let mut rng = g.rng();
            let assignment = policy.assign(n, b, &mut rng).unwrap();
            let eff_b = assignment.n_batches;
            let layout = if g.coin(0.5) && n % eff_b == 0 {
                crate::batching::disjoint(n, eff_b).unwrap()
            } else {
                let stride = n / eff_b;
                crate::batching::overlapping(n, eff_b, stride.max(1)).unwrap()
            };
            let scn = Scenario::new(
                layout,
                assignment,
                crate::dist::BatchService::paper(ServiceSpec::exp(1.0)),
            )
            .unwrap();
            let times: Vec<f64> = (0..n).map(|_| rng.f64_in(0.1, 10.0)).collect();
            let t = completion_from_times(&scn, &times);
            let lo = times.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(t >= lo - 1e-12 && t <= hi + 1e-12, "t={t} not in [{lo},{hi}]");
        });
    }

    #[test]
    fn prop_more_replication_never_hurts_mean() {
        // Monotonicity along the spectrum for Exp: smaller B (more
        // diversity) has smaller MC mean (Theorem 2, sampled form).
        testkit::check("mc-exp-monotone", 20, |g| {
            let n = *g.pick(&[8usize, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let spec = ServiceSpec::exp(1.0);
            let seed = g.u64_in(0, u64::MAX / 2);
            let means: Vec<f64> = divisors
                .iter()
                .map(|&b| {
                    let scn = Scenario::paper_balanced(
                        n,
                        b,
                        crate::dist::BatchService::paper(spec.clone()),
                    )
                    .unwrap();
                    run_trials(&scn, 40_000, seed).mean()
                })
                .collect();
            for w in means.windows(2) {
                // Allow MC noise: 3% slack.
                assert!(w[1] >= w[0] * 0.97, "means not increasing: {means:?}");
            }
        });
    }
}
