//! Event-driven simulator of System1.
//!
//! Beyond the Monte-Carlo sampler, the engine models the *mechanics* the
//! closed forms abstract away:
//!
//! * **replica cancellation** — when the first replica of a batch
//!   finishes, its siblings are cancelled; this never changes the
//!   completion time but determines the *cost* (busy worker-seconds),
//!   the redundancy bill the paper alludes to;
//! * **speculative relaunch** — the reactive MapReduce-style baseline:
//!   run one primary per batch, and only if it has not finished by a
//!   deadline launch the backups. Comparing it against upfront
//!   replication quantifies what the paper's proactive redundancy buys;
//! * **heterogeneous workers** and **straggler traces** via the
//!   scenario's speed factors and service spec;
//! * **k-of-B partial aggregation** via [`Scenario::k_of_b`]: the job
//!   completes once the earliest `k` batches have finished.
//!
//! # Throughput architecture (§Perf iteration 3)
//!
//! The default trial loop applies the same discipline the Monte-Carlo
//! sampler got in the previous perf pass:
//!
//! * **Flat event queue** — instead of a `BinaryHeap` that rebalances on
//!   every push/pop, pending events live in a per-trial **event arena**
//!   and a flat vector of `u32` order indices kept sorted (descending)
//!   by `(time, arena index)` under NaN-safe [`f64::total_cmp`]. The
//!   initial launch burst is appended unsorted and sorted **once** on
//!   the first pop; the rare mid-run insertions (speculative deadlines,
//!   relaunch waves) binary-search into place. Pops are `O(1)` vector
//!   pops from the tail.
//! * **Block-sampled launch waves** — each wave's service times are
//!   drawn with one [`crate::dist::BatchService::fill_batch_times`] call
//!   into a reusable [`Workspace`] buffer (the PR-2 block kernel:
//!   vectorizable transform over `fast_ln`, no per-replica enum dispatch
//!   or libm call). The block form consumes exactly the same RNG stream
//!   as the per-replica scalar draws, so the fast engine is
//!   stream-equivalent to the retained reference (values within
//!   `fast_ln` rounding, ≤ 1e-14 per draw). With failure injection the
//!   crash coins interleave with the service draws, so those waves fall
//!   back to the scalar draw loop and stay **bit-identical** to the
//!   reference.
//! * **Compensated cost accounting** — busy/wasted worker-seconds
//!   accumulate through [`crate::util::stats::Kahan`] sums rather than a
//!   naive `+=` over thousands of events.
//! * **Deterministic parallel sharding** — [`simulate_many_parallel`]
//!   splits trials over a fixed set of logical shards with per-shard
//!   RNG substreams and merges shard summaries in shard-index order
//!   (Welford merges); OS threads only execute the plan, so a fixed
//!   `(seed, trials)` pair is bit-reproducible regardless of thread
//!   scheduling **and of the thread count itself**.
//!
//! [`simulate_many_reference`] retains the pre-flat-queue engine — a
//! `BinaryHeap<Reverse<QueuedEvent>>` and one scalar `sample_batch` call
//! per replica — as the measured baseline of the `bench-des` harness.

use super::montecarlo::{keep_every, shard_plan};
use super::Scenario;
use crate::util::rng::Rng;
use crate::util::stats::{Kahan, Samples, Welford};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Redundancy activation strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Redundancy {
    /// All replicas start at t = 0 (the paper's model).
    Upfront,
    /// One primary per batch at t = 0; backups launch at
    /// `deadline_factor × E[batch service]` if the batch is unfinished.
    Speculative {
        /// Multiple of the mean batch service time to wait before
        /// launching backups.
        deadline_factor: f64,
    },
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Cancel sibling replicas when a batch completes.
    pub cancellation: bool,
    /// Redundancy activation strategy.
    pub redundancy: Redundancy,
    /// Failure injection: each launched replica crash-stops (silently,
    /// producing nothing) with this probability. If *every* replica of
    /// a batch crashes, the master detects the stall after
    /// `relaunch_timeout_factor × E[batch service]` and relaunches the
    /// batch's replicas — replication is the first line of defence,
    /// timeout-relaunch the second.
    pub fail_prob: f64,
    /// Stall-detection timeout as a multiple of the mean batch service.
    pub relaunch_timeout_factor: f64,
    /// Result-integrity strike budget: a worker flagged by replica
    /// voting ([`Scenario::verify_m`]) this many times is quarantined
    /// (marked dead, excluded from dispatch, respawned with backoff).
    /// Only read by [`simulate_fault_rounds`]; the trial engines model
    /// the m-of-g *latency* semantics but have no voting state.
    pub verify_strikes: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            cancellation: true,
            redundancy: Redundancy::Upfront,
            fail_prob: 0.0,
            relaunch_timeout_factor: 3.0,
            verify_strikes: 2,
        }
    }
}

/// Per-trial result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrialResult {
    /// Job completion time.
    pub completion: f64,
    /// Σ busy worker-seconds actually spent.
    pub busy: f64,
    /// Busy seconds spent on replicas that were cancelled or finished
    /// after their batch was already complete (pure redundancy cost).
    pub wasted: f64,
    /// Events processed.
    pub events: u64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// A worker finishes its (possibly backup) task on a batch.
    Finish { worker: usize, batch: usize },
    /// Speculative deadline for a batch: launch backups if unfinished.
    Deadline { batch: usize },
    /// Stall-detection timeout: relaunch the batch if unfinished (all
    /// its replicas crashed).
    Relaunch { batch: usize },
}

// ---------------------------------------------------------------------
// Flat event queue
// ---------------------------------------------------------------------

/// Index-sorted flat event queue: events are appended to a reusable
/// arena (their arena index doubles as the FIFO sequence number) and a
/// vector of `u32` order indices is kept sorted **descending** by
/// `(time, index)` under [`f64::total_cmp`], so the next event is an
/// `O(1)` pop from the tail.
///
/// The initial launch burst (all of upfront mode's events) is appended
/// unsorted and sorted once, lazily, on the first pop; later insertions
/// (speculative deadlines firing, relaunch waves) binary-search their
/// slot. This removes the per-event sift-up/sift-down rebalancing of a
/// binary heap from the hot loop — and the NaN-unsafe `partial_cmp`
/// ordering the heap's `Ord` impl needed.
#[derive(Debug, Default)]
struct FlatQueue {
    /// Every event scheduled this trial; index = schedule order (FIFO
    /// tie-break).
    arena: Vec<(f64, Ev)>,
    /// Pending arena indices, sorted descending by `(time, index)` once
    /// `dirty` is cleared; tail = earliest event.
    order: Vec<u32>,
    /// Pushes since [`FlatQueue::clear`] are unsorted; the first pop
    /// sorts once.
    dirty: bool,
}

impl FlatQueue {
    /// Reset for a new trial, keeping both buffers' capacity.
    fn clear(&mut self) {
        self.arena.clear();
        self.order.clear();
        self.dirty = true;
    }

    /// Schedule an event. During the initial (pre-pop) burst this is an
    /// O(1) append; afterwards a binary-search insertion that preserves
    /// the descending order (pending counts are small — at most one
    /// event per worker plus one per batch).
    #[inline]
    fn push(&mut self, time: f64, ev: Ev) {
        let idx = self.arena.len() as u32;
        self.arena.push((time, ev));
        if self.dirty {
            self.order.push(idx);
        } else {
            // Keep strictly-later events ahead of the new one; at equal
            // times the new event has the largest arena index and sits
            // ahead of its elders, which therefore pop first (FIFO).
            let arena = &self.arena;
            let pos = self
                .order
                .partition_point(|&i| arena[i as usize].0.total_cmp(&time).is_gt());
            self.order.insert(pos, idx);
        }
    }

    /// Pop the earliest pending event (ties FIFO by schedule order).
    #[inline]
    fn pop(&mut self) -> Option<(f64, Ev)> {
        if self.dirty {
            let arena = &self.arena;
            self.order.sort_unstable_by(|&a, &b| {
                arena[b as usize]
                    .0
                    .total_cmp(&arena[a as usize].0)
                    .then(b.cmp(&a))
            });
            self.dirty = false;
        }
        self.order.pop().map(|i| self.arena[i as usize])
    }
}

// ---------------------------------------------------------------------
// Fast engine (flat queue + block-sampled waves)
// ---------------------------------------------------------------------

/// Reusable per-trial state: lets [`simulate_many`] run the engine
/// allocation-free after the first trial. Holds the flat event queue
/// (arena + order indices) and the block-sample buffer every launch
/// wave — upfront, speculative backups, relaunches — draws into.
#[derive(Debug, Default)]
pub struct Workspace {
    queue: FlatQueue,
    /// Block-sampled service times of the wave being launched.
    wave: Vec<f64>,
    start_time: Vec<f64>,
    unit_covered: Vec<bool>,
    batch_done: Vec<bool>,
    /// Replica finishes collected per batch (m-of-g verification).
    batch_hits: Vec<u32>,
    cancelled: Vec<bool>,
}

/// Run a single trial through the event engine (allocating wrapper).
pub fn simulate_one(scn: &Scenario, cfg: &EngineConfig, rng: &mut Rng) -> TrialResult {
    simulate_one_with(scn, cfg, rng, &mut Workspace::default())
}

/// Launch one wave of replicas for a batch at `now`. Without failure
/// injection the wave's service times are drawn with one block
/// [`crate::dist::BatchService::fill_batch_times`] call (same RNG stream
/// as per-replica scalar draws); with `fail_prob > 0` the crash coins
/// interleave with the draws, so the wave falls back to the scalar loop
/// and stays bit-identical to the reference engine. Returns the number
/// of survivors; the caller schedules a Relaunch when zero.
#[allow(clippy::too_many_arguments)]
#[inline]
fn launch_wave_fast(
    scn: &Scenario,
    cfg: &EngineConfig,
    s: u64,
    queue: &mut FlatQueue,
    wave: &mut Vec<f64>,
    start_time: &mut [f64],
    batch: usize,
    replicas: &[usize],
    now: f64,
    rng: &mut Rng,
) -> usize {
    let m = replicas.len();
    if m == 0 {
        return 0;
    }
    if cfg.fail_prob == 0.0 {
        if wave.len() < m {
            wave.resize(m, 0.0);
        }
        scn.service.fill_batch_times(s, &mut wave[..m], rng);
        for (i, &w) in replicas.iter().enumerate() {
            let mut t = wave[i];
            if let Some(speeds) = &scn.worker_speeds {
                t *= speeds[w];
            }
            start_time[w] = now;
            queue.push(now + t, Ev::Finish { worker: w, batch });
        }
        return m;
    }
    let mut survivors = 0;
    for &w in replicas {
        if rng.coin(cfg.fail_prob) {
            continue;
        }
        let mut t = scn.service.sample_batch(s, rng);
        if let Some(speeds) = &scn.worker_speeds {
            t *= speeds[w];
        }
        start_time[w] = now;
        queue.push(now + t, Ev::Finish { worker: w, batch });
        survivors += 1;
    }
    survivors
}

/// Run a single trial reusing `ws` across calls.
pub fn simulate_one_with(
    scn: &Scenario,
    cfg: &EngineConfig,
    rng: &mut Rng,
    ws: &mut Workspace,
) -> TrialResult {
    let n = scn.n_workers();
    let b = scn.assignment.n_batches;
    let s = scn.batch_units();

    let Workspace { queue, wave, start_time, unit_covered, batch_done, batch_hits, cancelled } =
        ws;
    queue.clear();

    // m-of-g verification: a batch completes (and cancels its losers)
    // only at its `quorum`-th replica finish. `with_verify_m` guarantees
    // every batch has at least `quorum` replicas; the supported regime
    // is `fail_prob == 0` (see [`crate::evaluator::DesEvaluator`]'s
    // named refusal), where launched waves never lose replicas and the
    // quorum is therefore always reachable without a relaunch.
    let quorum = scn.verify_m.unwrap_or(1) as u32;

    // Stall-detection timeout for crash relaunch (only needed when
    // failures are injected).
    let relaunch_after = if cfg.fail_prob > 0.0 {
        cfg.relaunch_timeout_factor
            * scn
                .service
                .batch_mean(s)
                // lint:allow(D4): DesEvaluator refuses fail_prob > 0 with infinite-mean service before the engine runs
                .expect("failure injection needs a finite mean batch service")
    } else {
        f64::INFINITY
    };

    // Launch per the redundancy strategy.
    start_time.clear(); // NaN = not launched
    start_time.resize(n, f64::NAN);
    match cfg.redundancy {
        Redundancy::Upfront => {
            for (batch, replicas) in scn.assignment.workers_of_batch.iter().enumerate() {
                let survivors = launch_wave_fast(
                    scn, cfg, s, queue, wave, start_time, batch, replicas, 0.0, rng,
                );
                if survivors == 0 {
                    queue.push(relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
        Redundancy::Speculative { deadline_factor } => {
            let mean_batch = scn
                .service
                .batch_mean(s)
                // lint:allow(D4): DesEvaluator refuses speculative redundancy with infinite-mean service
                .expect("speculative redundancy needs a finite mean batch service");
            let deadline = deadline_factor * mean_batch;
            for (batch, replicas) in scn.assignment.workers_of_batch.iter().enumerate() {
                let survivors = launch_wave_fast(
                    scn,
                    cfg,
                    s,
                    queue,
                    wave,
                    start_time,
                    batch,
                    &replicas[..1],
                    0.0,
                    rng,
                );
                if replicas.len() > 1 {
                    queue.push(deadline, Ev::Deadline { batch });
                } else if survivors == 0 {
                    queue.push(relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
    }

    // Coverage state.
    let n_units = scn.layout.n_units;
    unit_covered.clear();
    unit_covered.resize(n_units, false);
    let mut units_left = n_units;
    batch_done.clear();
    batch_done.resize(b, false);
    batch_hits.clear();
    batch_hits.resize(b, 0);
    let mut batches_done = 0usize;
    cancelled.clear();
    cancelled.resize(n, false);

    let mut busy = Kahan::new();
    let mut wasted = Kahan::new();
    let mut events = 0u64;
    let mut completion = f64::NAN;

    while let Some((time, ev)) = queue.pop() {
        events += 1;
        match ev {
            Ev::Finish { worker, batch } => {
                if cancelled[worker] {
                    continue;
                }
                let work = time - start_time[worker];
                busy.add(work);
                if batch_done[batch] {
                    // A sibling already finished this batch (cancellation
                    // disabled, or completion raced the cancel).
                    wasted.add(work);
                    continue;
                }
                batch_hits[batch] += 1;
                if batch_hits[batch] < quorum {
                    // Quorum member before the m-th: the batch is still
                    // waiting for more votes. Its work is busy (it is
                    // part of the verification bill), not wasted. NaN
                    // start_time marks it idle so the cancellation
                    // sweeps below do not re-account its finished run.
                    start_time[worker] = f64::NAN;
                    continue;
                }
                batch_done[batch] = true;
                batches_done += 1;
                for &u in &scn.layout.units_of_batch[batch] {
                    if !unit_covered[u] {
                        unit_covered[u] = true;
                        units_left -= 1;
                    }
                }
                if cfg.cancellation {
                    for &sib in &scn.assignment.workers_of_batch[batch] {
                        if sib != worker && !cancelled[sib] && !start_time[sib].is_nan() {
                            cancelled[sib] = true;
                            let partial = time - start_time[sib];
                            busy.add(partial);
                            wasted.add(partial);
                        }
                    }
                }
                let done = match scn.k_of_b {
                    Some(k) => batches_done >= k,
                    None => units_left == 0,
                };
                if done && completion.is_nan() {
                    completion = time;
                    if cfg.cancellation {
                        // All remaining work (other batches' stragglers
                        // in overlapping layouts, or batches beyond the
                        // k-of-B target) is moot once the job is
                        // complete.
                        for w in 0..n {
                            if !cancelled[w] && !start_time[w].is_nan() {
                                // Workers of already-done batches were
                                // handled by sibling cancellation above.
                                if batch_done[scn.assignment.batch_of_worker[w]] {
                                    continue;
                                }
                                cancelled[w] = true;
                                let partial = time - start_time[w];
                                busy.add(partial);
                                wasted.add(partial);
                            }
                        }
                    }
                }
            }
            Ev::Deadline { batch } => {
                if batch_done[batch] {
                    continue;
                }
                // Launch every backup replica of this batch now.
                let replicas = &scn.assignment.workers_of_batch[batch];
                let survivors = launch_wave_fast(
                    scn,
                    cfg,
                    s,
                    queue,
                    wave,
                    start_time,
                    batch,
                    &replicas[1..],
                    time,
                    rng,
                );
                if survivors == 0 && cfg.fail_prob > 0.0 {
                    // Backups all crashed; if the primary also crashed
                    // the stall timer is the only way forward (if the
                    // primary is alive this Relaunch will be moot).
                    queue.push(time + relaunch_after, Ev::Relaunch { batch });
                }
            }
            Ev::Relaunch { batch } => {
                if batch_done[batch] {
                    continue;
                }
                let replicas = &scn.assignment.workers_of_batch[batch];
                let survivors = launch_wave_fast(
                    scn, cfg, s, queue, wave, start_time, batch, replicas, time, rng,
                );
                if survivors == 0 {
                    queue.push(time + relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
        // Early exit: once complete and cancellation is on, the queue
        // may still hold events for cancelled workers; drain them
        // cheaply.
        if !completion.is_nan() && cfg.cancellation {
            while let Some((qt, qe)) = queue.pop() {
                events += 1;
                if let Ev::Finish { worker, .. } = qe {
                    if !cancelled[worker] {
                        // Shouldn't happen for disjoint full-completion
                        // layouts; be safe and account the full run.
                        let work = qt - start_time[worker];
                        busy.add(work);
                        wasted.add(work);
                    }
                }
            }
            break;
        }
    }

    debug_assert!(!completion.is_nan(), "job never completed");
    TrialResult { completion, busy: busy.sum(), wasted: wasted.sum(), events }
}

// ---------------------------------------------------------------------
// Aggregation: sequential, reference, and parallel trial loops
// ---------------------------------------------------------------------

/// Aggregate over many trials.
#[derive(Debug, Clone)]
pub struct EngineSummary {
    /// Completion-time statistics.
    pub completion: Welford,
    /// Busy worker-seconds statistics.
    pub busy: Welford,
    /// Wasted worker-seconds statistics.
    pub wasted: Welford,
    /// Total events processed.
    pub total_events: u64,
    /// Retained completion-time samples (thinned to the shared cap) for
    /// quantile estimates.
    pub samples: Samples,
}

impl EngineSummary {
    fn empty() -> Self {
        Self {
            completion: Welford::new(),
            busy: Welford::new(),
            wasted: Welford::new(),
            total_events: 0,
            samples: Samples::new(),
        }
    }
}

/// Shared trial-summary loop of every engine runner.
fn summarize_trials(
    trials: u64,
    keep_every: u64,
    mut trial: impl FnMut() -> TrialResult,
) -> EngineSummary {
    let mut sum = EngineSummary::empty();
    for i in 0..trials {
        let r = trial();
        sum.completion.push(r.completion);
        sum.busy.push(r.busy);
        sum.wasted.push(r.wasted);
        sum.total_events += r.events;
        if i % keep_every == 0 {
            sum.samples.push(r.completion);
        }
    }
    sum
}

/// One shard of the engine trial loop: `trials` flat-queue trials from
/// an already-positioned RNG, keeping every `keep_every`-th sample.
/// Crate-visible so the [`crate::study`] planner can schedule shards of
/// *different* cells across one shared worker pool while reproducing
/// [`simulate_many_parallel`]'s per-cell results bit-for-bit.
pub(crate) fn simulate_shard(
    scn: &Scenario,
    cfg: &EngineConfig,
    trials: u64,
    mut rng: Rng,
    keep_every: u64,
    ws: &mut Workspace,
) -> EngineSummary {
    let _span = crate::obs::span("des.shard");
    crate::obs::bump(crate::obs::Counter::DesShards, 1);
    crate::obs::bump(crate::obs::Counter::DesTrials, trials);
    if crate::obs::enabled() {
        crate::obs::emit(
            "des",
            "shard",
            &[("trials", trials.into()), ("workers", scn.n_workers().into())],
        );
    }
    summarize_trials(trials, keep_every, || simulate_one_with(scn, cfg, &mut rng, ws))
}

/// Run `trials` trials (single-threaded, flat queue + block sampling).
pub fn simulate_many(
    scn: &Scenario,
    cfg: &EngineConfig,
    trials: u64,
    seed: u64,
) -> EngineSummary {
    let mut rng = Rng::new(seed);
    let mut ws = Workspace::default();
    summarize_trials(trials, keep_every(trials), || {
        simulate_one_with(scn, cfg, &mut rng, &mut ws)
    })
}

/// Sharded trial runner: splits `trials` over the fixed logical shards
/// of the shared `shard_plan` (the same plan the Monte-Carlo sampler
/// uses — per-shard RNG substreams, shard count independent of the
/// thread count) and executes the plan on up to `threads` OS threads.
/// Shard summaries are merged in shard-index order after all threads
/// join — Welford merges for the moments, concatenation for the
/// retained samples — so the result is independent of thread completion
/// order **and of the thread count itself**: a fixed
/// `(scenario, trials, seed)` triple produces a bit-identical
/// [`EngineSummary`] for every `threads ∈ {1, 2, 4, …}`.
pub fn simulate_many_parallel(
    scn: &Scenario,
    cfg: &EngineConfig,
    trials: u64,
    seed: u64,
    threads: usize,
) -> EngineSummary {
    // One shared thinning rate, so the union of shard sample sets obeys
    // the global cap and depends only on the trial count.
    let keep = keep_every(trials);
    let shards = super::montecarlo::execute_shard_plan(
        shard_plan(trials, seed),
        threads,
        Workspace::default,
        |ws, shard_trials, rng| simulate_shard(scn, cfg, shard_trials, rng, keep, ws),
    );
    merge_shard_summaries(shards)
}

/// Merge per-shard engine summaries **in shard-index order** — the
/// single definition shared by [`simulate_many_parallel`] and the
/// study pool ([`crate::study`]), so their per-cell bitwise equality
/// holds by construction.
pub(crate) fn merge_shard_summaries(
    shards: impl IntoIterator<Item = EngineSummary>,
) -> EngineSummary {
    let mut out = EngineSummary::empty();
    for sh in shards {
        out.completion.merge(&sh.completion);
        out.busy.merge(&sh.busy);
        out.wasted.merge(&sh.wasted);
        out.total_events += sh.total_events;
        for &x in sh.samples.raw() {
            out.samples.push(x);
        }
    }
    out
}

// ---------------------------------------------------------------------
// Reference engine (retained pre-flat-queue baseline)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct QueuedEvent {
    time: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for QueuedEvent {}
impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Total order: by time (NaN-safe total_cmp — times are never
        // NaN, but the ordering must not silently degrade if they were),
        // ties broken by sequence number (FIFO).
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Per-trial state of the retained reference engine.
#[derive(Debug, Default)]
struct ReferenceWorkspace {
    heap: BinaryHeap<Reverse<QueuedEvent>>,
    start_time: Vec<f64>,
    unit_covered: Vec<bool>,
    batch_done: Vec<bool>,
    batch_hits: Vec<u32>,
    cancelled: Vec<bool>,
}

#[inline]
fn push_ev(heap: &mut BinaryHeap<Reverse<QueuedEvent>>, seq: &mut u64, time: f64, ev: Ev) {
    let q = QueuedEvent { time, seq: *seq, ev };
    *seq += 1;
    heap.push(Reverse(q));
}

/// Reference launch wave: one scalar `sample_batch` enum dispatch (and
/// libm `ln`) per replica.
#[allow(clippy::too_many_arguments)]
fn launch_wave_reference(
    scn: &Scenario,
    cfg: &EngineConfig,
    s: u64,
    heap: &mut BinaryHeap<Reverse<QueuedEvent>>,
    seq: &mut u64,
    start_time: &mut [f64],
    batch: usize,
    replicas: &[usize],
    now: f64,
    rng: &mut Rng,
) -> usize {
    let mut survivors = 0;
    for &w in replicas {
        if cfg.fail_prob > 0.0 && rng.coin(cfg.fail_prob) {
            continue;
        }
        let mut t = scn.service.sample_batch(s, rng);
        if let Some(speeds) = &scn.worker_speeds {
            t *= speeds[w];
        }
        start_time[w] = now;
        push_ev(heap, seq, now + t, Ev::Finish { worker: w, batch });
        survivors += 1;
    }
    survivors
}

/// One trial of the retained reference engine: `BinaryHeap` event queue,
/// scalar per-replica service draws, naive cost accumulation.
fn simulate_one_reference_with(
    scn: &Scenario,
    cfg: &EngineConfig,
    rng: &mut Rng,
    ws: &mut ReferenceWorkspace,
) -> TrialResult {
    let n = scn.n_workers();
    let b = scn.assignment.n_batches;
    let s = scn.batch_units();

    let heap = &mut ws.heap;
    heap.clear();
    let mut seq = 0u64;

    let relaunch_after = if cfg.fail_prob > 0.0 {
        cfg.relaunch_timeout_factor
            * scn
                .service
                .batch_mean(s)
                // lint:allow(D4): DesEvaluator refuses fail_prob > 0 with infinite-mean service before the engine runs
                .expect("failure injection needs a finite mean batch service")
    } else {
        f64::INFINITY
    };

    let start_time = &mut ws.start_time; // NaN = not launched
    start_time.clear();
    start_time.resize(n, f64::NAN);
    match cfg.redundancy {
        Redundancy::Upfront => {
            for (batch, replicas) in scn.assignment.workers_of_batch.iter().enumerate() {
                let survivors = launch_wave_reference(
                    scn, cfg, s, heap, &mut seq, start_time, batch, replicas, 0.0, rng,
                );
                if survivors == 0 {
                    push_ev(heap, &mut seq, relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
        Redundancy::Speculative { deadline_factor } => {
            let mean_batch = scn
                .service
                .batch_mean(s)
                // lint:allow(D4): DesEvaluator refuses speculative redundancy with infinite-mean service
                .expect("speculative redundancy needs a finite mean batch service");
            let deadline = deadline_factor * mean_batch;
            for (batch, replicas) in scn.assignment.workers_of_batch.iter().enumerate() {
                let survivors = launch_wave_reference(
                    scn,
                    cfg,
                    s,
                    heap,
                    &mut seq,
                    start_time,
                    batch,
                    &replicas[..1],
                    0.0,
                    rng,
                );
                if replicas.len() > 1 {
                    push_ev(heap, &mut seq, deadline, Ev::Deadline { batch });
                } else if survivors == 0 {
                    push_ev(heap, &mut seq, relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
    }

    let n_units = scn.layout.n_units;
    let unit_covered = &mut ws.unit_covered;
    unit_covered.clear();
    unit_covered.resize(n_units, false);
    let mut units_left = n_units;
    let batch_done = &mut ws.batch_done;
    batch_done.clear();
    batch_done.resize(b, false);
    let batch_hits = &mut ws.batch_hits;
    batch_hits.clear();
    batch_hits.resize(b, 0);
    let quorum = scn.verify_m.unwrap_or(1) as u32;
    let mut batches_done = 0usize;
    let cancelled = &mut ws.cancelled;
    cancelled.clear();
    cancelled.resize(n, false);

    let mut busy = 0.0f64;
    let mut wasted = 0.0f64;
    let mut events = 0u64;
    let mut completion = f64::NAN;

    while let Some(Reverse(QueuedEvent { time, ev, .. })) = heap.pop() {
        events += 1;
        match ev {
            Ev::Finish { worker, batch } => {
                if cancelled[worker] {
                    continue;
                }
                let work = time - start_time[worker];
                busy += work;
                if batch_done[batch] {
                    wasted += work;
                    continue;
                }
                batch_hits[batch] += 1;
                if batch_hits[batch] < quorum {
                    // Pre-m quorum member: busy, not wasted; NaN marks
                    // it idle so cancellation sweeps skip it.
                    start_time[worker] = f64::NAN;
                    continue;
                }
                batch_done[batch] = true;
                batches_done += 1;
                for &u in &scn.layout.units_of_batch[batch] {
                    if !unit_covered[u] {
                        unit_covered[u] = true;
                        units_left -= 1;
                    }
                }
                if cfg.cancellation {
                    for &sib in &scn.assignment.workers_of_batch[batch] {
                        if sib != worker && !cancelled[sib] && !start_time[sib].is_nan() {
                            cancelled[sib] = true;
                            let partial = time - start_time[sib];
                            busy += partial;
                            wasted += partial;
                        }
                    }
                }
                let done = match scn.k_of_b {
                    Some(k) => batches_done >= k,
                    None => units_left == 0,
                };
                if done && completion.is_nan() {
                    completion = time;
                    if cfg.cancellation {
                        for w in 0..n {
                            if !cancelled[w] && !start_time[w].is_nan() {
                                if batch_done[scn.assignment.batch_of_worker[w]] {
                                    continue;
                                }
                                cancelled[w] = true;
                                let partial = time - start_time[w];
                                busy += partial;
                                wasted += partial;
                            }
                        }
                    }
                }
            }
            Ev::Deadline { batch } => {
                if batch_done[batch] {
                    continue;
                }
                let replicas = &scn.assignment.workers_of_batch[batch];
                let survivors = launch_wave_reference(
                    scn,
                    cfg,
                    s,
                    heap,
                    &mut seq,
                    start_time,
                    batch,
                    &replicas[1..],
                    time,
                    rng,
                );
                if survivors == 0 && cfg.fail_prob > 0.0 {
                    push_ev(heap, &mut seq, time + relaunch_after, Ev::Relaunch { batch });
                }
            }
            Ev::Relaunch { batch } => {
                if batch_done[batch] {
                    continue;
                }
                let replicas = &scn.assignment.workers_of_batch[batch];
                let survivors = launch_wave_reference(
                    scn, cfg, s, heap, &mut seq, start_time, batch, replicas, time, rng,
                );
                if survivors == 0 {
                    push_ev(heap, &mut seq, time + relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
        if !completion.is_nan() && cfg.cancellation {
            while let Some(Reverse(q)) = heap.pop() {
                events += 1;
                if let Ev::Finish { worker, .. } = q.ev {
                    if !cancelled[worker] {
                        let work = q.time - start_time[worker];
                        busy += work;
                        wasted += work;
                    }
                }
            }
            break;
        }
    }

    debug_assert!(!completion.is_nan(), "job never completed");
    TrialResult { completion, busy, wasted, events }
}

/// The retained pre-flat-queue engine — `BinaryHeap` event queue with
/// per-event rebalancing, one scalar `sample_batch` enum dispatch (and
/// libm `ln` call) per replica, naive `+=` cost accumulation — faithfully
/// reproducing the trial loop as it worked before this perf pass. Kept
/// (not dead code) as the measured baseline of the `bench-des`
/// throughput harness and the stream-equivalence oracle of the fast
/// engine's tests; evaluators never call it.
pub fn simulate_many_reference(
    scn: &Scenario,
    cfg: &EngineConfig,
    trials: u64,
    seed: u64,
) -> EngineSummary {
    let mut rng = Rng::new(seed);
    let mut ws = ReferenceWorkspace::default();
    summarize_trials(trials, keep_every(trials), || {
        simulate_one_reference_with(scn, cfg, &mut rng, &mut ws)
    })
}

/// Per-round statistics of [`simulate_fault_rounds`] — the DES mirror
/// of the live coordinator's self-healing round loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRoundStats {
    /// Round index (the fault plan's clock).
    pub round: u64,
    /// Injected completion time of the round in normalized units — the
    /// exact observable the live coordinator records as
    /// `injected_s / time_scale`.
    pub completion: f64,
    /// Workers that died this round.
    pub crashes: u64,
    /// Dead workers respawned at the start of this round.
    pub respawns: u64,
    /// Batches recovered by a deadline relaunch this round.
    pub relaunches: u64,
    /// Degraded-mode re-plans performed this round.
    pub degradations: u64,
    /// Tasks dropped before dispatch this round.
    pub dropped: u64,
    /// Results returned corrupted this round (the plan's corruption
    /// coin — a pure function of `(seed, worker, round)`, so this
    /// column is replicate-invariant like the counters above).
    pub corrupted: u64,
    /// Corrupt replicas flagged by m-of-g voting this round (zero when
    /// `Scenario::verify_m` is off — corruption is then invisible).
    pub flagged: u64,
    /// Workers quarantined at the end of this round (strike budget
    /// exhausted; they re-enter through the respawn machinery).
    pub quarantined: u64,
    /// Workers alive at the end of the round.
    pub live_workers: usize,
}

/// Mark worker `w` dead and, for a transient crash, schedule its
/// respawn with the same capped exponential backoff the live
/// coordinator applies (`d`, `2d`, `4d`, `8d` rounds).
fn fault_kill(
    w: usize,
    round: u64,
    respawn_after: Option<u64>,
    dead: &mut [bool],
    respawn_at: &mut [Option<u64>],
    respawn_attempts: &mut [u32],
    crashes: &mut u64,
) {
    dead[w] = true;
    *crashes += 1;
    if let Some(d) = respawn_after {
        let backoff = 1u64 << respawn_attempts[w].min(3);
        respawn_at[w] = Some(round + d.saturating_mul(backoff));
        respawn_attempts[w] = respawn_attempts[w].saturating_add(1);
    }
}

/// Batches holding at least one live, non-crashing replica (the
/// pre-dispatch feasibility count; plan-dropped tasks do not count
/// against it — the deadline relaunch recovers them within the round).
fn fault_covered(
    assignment: &crate::assignment::Assignment,
    dead: &[bool],
    crashing: &[Option<crate::fault::CrashSpec>],
) -> usize {
    let mut ok = vec![false; assignment.n_batches];
    for (w, &batch) in assignment.batch_of_worker.iter().enumerate() {
        if !dead[w] && crashing[w].is_none() {
            ok[batch] = true;
        }
    }
    ok.iter().filter(|&&x| x).count()
}

/// Worker-level fault simulation: run `rounds` rounds of System1 under
/// a compiled [`crate::fault::CompiledPlan`], mirroring the live
/// coordinator's self-healing round loop step for step — respawns due
/// at round start, scheduled crashes with backoff-scheduled transient
/// respawn, pre-dispatch coverage feasibility with graceful degradation
/// onto survivors, per-worker dispatch draws (skipping plan-dropped
/// tasks, scaling by plan slowdowns), and deadline relaunch of batches
/// left with no completable replica (fresh draw on the batch's first
/// live replica, drop coin not re-flipped). Draw order matches the live
/// dispatch loop (worker id order, then relaunches in batch order), so
/// round `completion` estimates the same injected observable the live
/// run records — the live↔DES fault conformance contract.
///
/// **Result integrity** (PR 8): when the plan carries
/// [`crate::fault::FaultEvent::Corruption`] events, a completable
/// result is silently corrupted per the plan's deterministic coin
/// ([`crate::fault::CompiledPlan::corrupts_result`] — no RNG consumed,
/// so the PR-7 draw streams are byte-identical). With
/// [`Scenario::verify_m`] set, every batch waits for its m-th replica
/// and votes: honest replicas agree bit-exactly, corrupt ones agree
/// with nobody (the live perturbation is worker-dependent), so the
/// batch accepts at the first arrival where some agreement group has
/// ≥ 2 members and ≥ m results are in (arrival order, exact-time ties
/// by worker index under `total_cmp`). Flagging is modeled
/// *plan-deterministically*: every corrupt completable replica of a
/// batch with ≥ 2 honest comparators is flagged (struck), so the
/// flagged/quarantined schedule — and therefore `live_workers` — stays
/// replicate-invariant (the chaos harness's cross-replicate identity
/// check). A worker reaching `cfg.verify_strikes` strikes is
/// quarantined at end of round: marked dead and handed to the respawn
/// machinery with the crash backoff
/// ([`crate::fault::QUARANTINE_RESPAWN_ROUNDS`] doubling per attempt);
/// its strikes reset on respawn. A batch with fewer than 2 honest
/// replicas is detected-but-unrecoverable: the earliest value is
/// accepted at the last arrival, a degradation is counted, and nobody
/// is flagged (attribution is impossible).
///
/// Upfront redundancy and disjoint layouts only; the existing engine
/// RNG streams are untouched (callers pass their own `rng`).
pub fn simulate_fault_rounds(
    scn: &Scenario,
    plan: &crate::fault::CompiledPlan,
    rounds: u64,
    cfg: &EngineConfig,
    rng: &mut Rng,
) -> anyhow::Result<Vec<FaultRoundStats>> {
    anyhow::ensure!(
        matches!(cfg.redundancy, Redundancy::Upfront),
        "fault-round simulation models upfront replication only"
    );
    anyhow::ensure!(
        !scn.layout.is_overlapping,
        "fault-round simulation requires a disjoint layout"
    );
    anyhow::ensure!(
        plan.n_workers() == scn.n_workers(),
        "fault plan compiled for {} workers, scenario has {}",
        plan.n_workers(),
        scn.n_workers()
    );
    let n = scn.n_workers();
    let n_units = scn.layout.n_units;
    let mut assignment = scn.assignment.clone();
    let mut batch_units = scn.layout.batch_units();
    let mut k_of_b = scn.k_of_b;
    let mut dead = vec![false; n];
    let mut respawn_at: Vec<Option<u64>> = vec![None; n];
    let mut respawn_attempts = vec![0u32; n];
    let mut strikes = vec![0u64; n];
    let verify_m = scn.verify_m;
    let strikes_limit = cfg.verify_strikes.max(1);
    let mut batch_time: Vec<f64> = Vec::new();
    // Completable replicas per batch: (finish time, worker, corrupt).
    let mut batch_votes: Vec<Vec<(f64, usize, bool)>> = Vec::new();
    let mut out = Vec::with_capacity(rounds as usize);

    for round in 0..rounds {
        let (mut crashes, mut respawns, mut relaunches) = (0u64, 0u64, 0u64);
        let (mut degradations, mut dropped) = (0u64, 0u64);
        let (mut corrupted, mut flagged, mut quarantined) = (0u64, 0u64, 0u64);

        // Respawns due at round start (strikes reset with the fresh
        // process — a respawned worker starts with a clean record).
        for w in 0..n {
            if dead[w] && respawn_at[w].is_some_and(|at| round >= at) {
                respawn_at[w] = None;
                dead[w] = false;
                strikes[w] = 0;
                respawns += 1;
            }
        }

        // Crashes firing this round on live workers.
        let mut crashing: Vec<Option<crate::fault::CrashSpec>> = vec![None; n];
        for w in 0..n {
            if let Some(c) = plan.crash_of(w) {
                if !dead[w] && c.round == round {
                    crashing[w] = Some(c);
                }
            }
        }

        // Pre-dispatch feasibility; degrade onto survivors if broken.
        let b_cur = assignment.n_batches;
        let need = k_of_b.unwrap_or(b_cur);
        if fault_covered(&assignment, &dead, &crashing) < need {
            for w in 0..n {
                if !dead[w] {
                    if let Some(c) = crashing[w].take() {
                        fault_kill(
                            w,
                            round,
                            c.respawn_after,
                            &mut dead,
                            &mut respawn_at,
                            &mut respawn_attempts,
                            &mut crashes,
                        );
                    }
                }
            }
            let n_live = dead.iter().filter(|&&d| !d).count();
            anyhow::ensure!(n_live >= 1, "every worker is dead at round {round}");
            let b_new = crate::fault::degraded_batch_count(n_units, n_live, b_cur);
            assignment = crate::fault::degraded_assignment(n, &dead, b_new)?;
            batch_units = n_units / b_new;
            if let Some(k) = &mut k_of_b {
                *k = (*k).min(b_new);
            }
            degradations += 1;
            anyhow::ensure!(
                fault_covered(&assignment, &dead, &crashing) >= k_of_b.unwrap_or(b_new),
                "degraded re-plan still infeasible at round {round}"
            );
        }
        let b = assignment.n_batches;
        let s_units = batch_units as u64;

        // Dispatch draws in worker id order (the live RNG order); a
        // crashing replica consumes its draw but never completes. The
        // corruption coin is a pure function of the plan — it consumes
        // no RNG, so these streams are byte-identical to PR-7 runs.
        batch_time.clear();
        batch_time.resize(b, f64::INFINITY);
        batch_votes.resize_with(b, Vec::new);
        for v in batch_votes.iter_mut() {
            v.clear();
        }
        for w in 0..n {
            if dead[w] {
                continue;
            }
            if plan.drops_task(w, round) {
                dropped += 1;
                continue;
            }
            let speed = scn.worker_speeds.as_ref().map_or(1.0, |sp| sp[w]);
            let draw = scn.service.sample_batch(s_units, rng) * plan.slow_factor(w, round);
            if crashing[w].is_some() {
                continue;
            }
            let batch = assignment.batch_of_worker[w];
            let t = draw * speed;
            let corrupt = plan.corrupts_result(w, round);
            if corrupt {
                corrupted += 1;
            }
            if verify_m.is_some() {
                batch_votes[batch].push((t, w, corrupt));
            } else if t < batch_time[batch] {
                batch_time[batch] = t;
            }
        }

        // Deadline relaunch of every batch left with no completable
        // replica, in batch order (fresh draw, drop coin not
        // re-flipped) — matching the live relaunch of such batches at
        // their near-immediate deadline.
        for bi in 0..b {
            let starved = match verify_m {
                Some(_) => batch_votes[bi].is_empty(),
                None => !batch_time[bi].is_finite(),
            };
            if !starved {
                continue;
            }
            let target = assignment.workers_of_batch[bi]
                .iter()
                .copied()
                .find(|&w| !dead[w] && crashing[w].is_none());
            let Some(w) = target else { continue };
            let speed = scn.worker_speeds.as_ref().map_or(1.0, |sp| sp[w]);
            let draw = scn.service.sample_batch(s_units, rng) * plan.slow_factor(w, round);
            let t = draw * speed;
            if verify_m.is_some() {
                let corrupt = plan.corrupts_result(w, round);
                if corrupt {
                    corrupted += 1;
                }
                batch_votes[bi].push((t, w, corrupt));
            } else {
                batch_time[bi] = t;
            }
            relaunches += 1;
        }

        // m-of-g voting: per batch, accept at the first arrival where
        // some agreement group has ≥ 2 members and ≥ m results are in
        // (arrival order; exact-time ties by worker index). Honest
        // replicas agree bit-exactly, corrupt ones with nobody.
        let mut to_quarantine: Vec<usize> = Vec::new();
        if let Some(m) = verify_m {
            for (bi, votes) in batch_votes.iter_mut().enumerate().take(b) {
                if votes.is_empty() {
                    continue; // no live replica at all; caught below
                }
                votes.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let honest = votes.iter().filter(|v| !v.2).count();
                let corrupt_n = votes.len() - honest;
                let mut h_seen = 0usize;
                let mut accept = None;
                for (i, v) in votes.iter().enumerate() {
                    if !v.2 {
                        h_seen += 1;
                    }
                    if h_seen >= 2 && i + 1 >= m {
                        accept = Some(v.0);
                        break;
                    }
                }
                // No accepting prefix: the batch exhausted its replicas
                // (quorum short, or < 2 honest comparators). It resolves
                // at the last arrival with the earliest value; with no
                // arrivals at all it never resolves (∞), though scenario
                // validation guarantees every batch has a replica.
                batch_time[bi] = match accept {
                    Some(t) => t,
                    None => votes.last().map(|v| v.0).unwrap_or(f64::INFINITY),
                };
                if corrupt_n > 0 {
                    if honest >= 2 {
                        // Voting succeeded: every corrupt replica of
                        // this batch is flagged (plan-deterministic, so
                        // the quarantine schedule is replicate-invariant
                        // — the chaos identity-key contract).
                        for v in votes.iter().filter(|v| v.2) {
                            flagged += 1;
                            strikes[v.1] += 1;
                            if strikes[v.1] >= strikes_limit
                                && !to_quarantine.contains(&v.1)
                            {
                                to_quarantine.push(v.1);
                            }
                        }
                    } else {
                        // Detected-but-unrecoverable: disagreement with
                        // no attributable majority. Nobody is flagged;
                        // the round degrades.
                        degradations += 1;
                    }
                }
            }
        }

        // Round completion: k-th finished batch or full coverage.
        let completion = match k_of_b {
            Some(k) => {
                let mut ts = batch_time.clone();
                ts.sort_by(|a, b| a.total_cmp(b));
                ts[k - 1]
            }
            None => batch_time.iter().fold(0.0f64, |a, &t| a.max(t)),
        };
        anyhow::ensure!(
            completion.is_finite(),
            "round {round} could not complete (a needed batch has no live replica)"
        );

        // Crashing workers die at end of round (even if their task was
        // dropped — the node goes down either way).
        for w in 0..n {
            if !dead[w] {
                if let Some(c) = crashing[w] {
                    fault_kill(
                        w,
                        round,
                        c.respawn_after,
                        &mut dead,
                        &mut respawn_at,
                        &mut respawn_attempts,
                        &mut crashes,
                    );
                }
            }
        }
        // Strike-budget quarantine, also at end of round (the worker's
        // rejected result is already accounted): exclude from dispatch
        // and hand to the respawn machinery with the crash backoff. A
        // worker that crashed this same round is already dead.
        for &w in &to_quarantine {
            if dead[w] {
                continue;
            }
            dead[w] = true;
            quarantined += 1;
            let backoff = 1u64 << respawn_attempts[w].min(3);
            respawn_at[w] = Some(
                round + crate::fault::QUARANTINE_RESPAWN_ROUNDS.saturating_mul(backoff),
            );
            respawn_attempts[w] = respawn_attempts[w].saturating_add(1);
        }
        let live_workers = dead.iter().filter(|&&d| !d).count();
        out.push(FaultRoundStats {
            round,
            completion,
            crashes,
            respawns,
            relaunches,
            degradations,
            dropped,
            corrupted,
            flagged,
            quarantined,
            live_workers,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{completion_time_stats, partial_completion_stats};
    use crate::dist::{BatchService, ServiceSpec};
    use crate::testkit;

    fn scn(n: usize, b: usize, spec: ServiceSpec) -> Scenario {
        Scenario::paper_balanced(n, b, BatchService::paper(spec)).unwrap()
    }

    #[test]
    fn engine_matches_closed_form() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.25);
        let s = scn(12, 4, spec.clone());
        let sum = simulate_many(&s, &EngineConfig::default(), 100_000, 3);
        let cf = completion_time_stats(12, 4, &spec).unwrap();
        let err = (sum.completion.mean() - cf.mean).abs();
        assert!(err < 0.02, "engine {} vs cf {}", sum.completion.mean(), cf.mean);
    }

    #[test]
    fn engine_matches_montecarlo() {
        // Two independent implementations must agree.
        let spec = ServiceSpec::exp(1.0);
        let s = scn(8, 2, spec);
        let e = simulate_many(&s, &EngineConfig::default(), 100_000, 9);
        let m = super::super::montecarlo::run_trials(&s, 100_000, 10);
        assert!(
            (e.completion.mean() - m.mean()).abs() < 0.02,
            "engine {} vs mc {}",
            e.completion.mean(),
            m.mean()
        );
    }

    #[test]
    fn prop_flat_queue_orders_like_a_heap() {
        // The flat queue must behave exactly like a (time, seq) min-heap:
        // pops ascend in time with FIFO tie-breaking, across interleaved
        // pushes (arena index = push order = seq).
        testkit::check("flat-queue-vs-model", 100, |g| {
            let mut q = FlatQueue::default();
            q.clear();
            let mut model: Vec<(f64, usize)> = Vec::new();
            let mut seq = 0usize;
            let mut push = |q: &mut FlatQueue, model: &mut Vec<(f64, usize)>, t: f64| {
                q.push(t, Ev::Deadline { batch: seq });
                model.push((t, seq));
                seq += 1;
            };
            // Initial burst (ties forced so FIFO ordering is exercised).
            for _ in 0..g.usize_in(1, 40) {
                let t = *g.pick(&[0.5, 1.0, 1.0, 2.0, 2.0, 3.5]);
                push(&mut q, &mut model, t);
            }
            while !model.is_empty() {
                model.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let expect = model.remove(0);
                let (t, ev) = q.pop().expect("queue drained early");
                let got = match ev {
                    Ev::Deadline { batch } => batch,
                    _ => unreachable!(),
                };
                assert_eq!(t.to_bits(), expect.0.to_bits());
                assert_eq!(got, expect.1, "FIFO tie-break violated");
                // Occasionally interleave mid-run insertions (the
                // deadline/relaunch pattern).
                if g.coin(0.3) {
                    let t2 = g.f64_in(0.0, 4.0);
                    push(&mut q, &mut model, t2);
                }
            }
            assert!(q.pop().is_none());
        });
    }

    #[test]
    fn fast_engine_matches_reference_stream() {
        // The flat-queue + block-kernel engine consumes the same RNG
        // stream as the retained reference (fill_batch_times contract),
        // so with no failure injection the two describe identical
        // trajectories up to fast_ln rounding: same event counts, means
        // within 1e-9 relative.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        for redundancy in
            [Redundancy::Upfront, Redundancy::Speculative { deadline_factor: 1.5 }]
        {
            let s = scn(12, 3, spec.clone());
            let cfg = EngineConfig { redundancy, ..EngineConfig::default() };
            let fast = simulate_many(&s, &cfg, 20_000, 9);
            let refr = simulate_many_reference(&s, &cfg, 20_000, 9);
            assert_eq!(fast.total_events, refr.total_events, "{redundancy:?}");
            assert_eq!(fast.completion.count(), refr.completion.count());
            let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
            assert!(
                rel(fast.completion.mean(), refr.completion.mean()) <= 1e-9,
                "{redundancy:?}: completion {} vs {}",
                fast.completion.mean(),
                refr.completion.mean()
            );
            assert!(
                rel(fast.busy.mean(), refr.busy.mean()) <= 1e-9,
                "{redundancy:?}: busy {} vs {}",
                fast.busy.mean(),
                refr.busy.mean()
            );
            assert!(
                rel(fast.wasted.mean(), refr.wasted.mean()) <= 1e-9,
                "{redundancy:?}: wasted {} vs {}",
                fast.wasted.mean(),
                refr.wasted.mean()
            );
        }
    }

    #[test]
    fn fast_engine_failure_path_is_bit_identical_to_reference() {
        // With failure injection the crash coins interleave with the
        // service draws, so the fast engine uses the scalar draw loop:
        // trajectories (and hence completion statistics) must be
        // bit-identical to the reference; only the Kahan vs naive cost
        // accumulation may differ, at rounding level.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        for redundancy in
            [Redundancy::Upfront, Redundancy::Speculative { deadline_factor: 1.5 }]
        {
            let s = scn(12, 3, spec.clone());
            let cfg =
                EngineConfig { redundancy, fail_prob: 0.3, ..EngineConfig::default() };
            let fast = simulate_many(&s, &cfg, 10_000, 21);
            let refr = simulate_many_reference(&s, &cfg, 10_000, 21);
            assert_eq!(fast.total_events, refr.total_events, "{redundancy:?}");
            assert_eq!(
                fast.completion.mean().to_bits(),
                refr.completion.mean().to_bits(),
                "{redundancy:?}"
            );
            assert_eq!(
                fast.completion.variance().to_bits(),
                refr.completion.variance().to_bits(),
                "{redundancy:?}"
            );
            let rel = (fast.busy.mean() - refr.busy.mean()).abs()
                / refr.busy.mean().abs().max(1.0);
            assert!(rel <= 1e-12, "{redundancy:?}: busy {rel}");
        }
    }

    #[test]
    fn parallel_engine_bit_identical_across_runs() {
        // The acceptance bar: simulate_many_parallel(seed, k) is fully
        // bit-reproducible — moments, event totals, and the retained
        // sample set.
        let s = scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.3));
        let cfg = EngineConfig::default();
        for k in [2usize, 4] {
            let a = simulate_many_parallel(&s, &cfg, 30_000, 11, k);
            let b = simulate_many_parallel(&s, &cfg, 30_000, 11, k);
            assert_eq!(a.completion.count(), 30_000, "k={k}");
            assert_eq!(a.completion.mean().to_bits(), b.completion.mean().to_bits());
            assert_eq!(
                a.completion.variance().to_bits(),
                b.completion.variance().to_bits()
            );
            assert_eq!(a.busy.mean().to_bits(), b.busy.mean().to_bits(), "k={k}");
            assert_eq!(a.total_events, b.total_events, "k={k}");
            assert_eq!(a.samples.raw(), b.samples.raw(), "k={k}");
        }
        // The logical-shard plan makes the result invariant to the
        // thread count, not just to scheduling: threads = 1 executes
        // the identical plan sequentially.
        let p1 = simulate_many_parallel(&s, &cfg, 5_000, 3, 1);
        let p3 = simulate_many_parallel(&s, &cfg, 5_000, 3, 3);
        assert_eq!(p1.completion.mean().to_bits(), p3.completion.mean().to_bits());
        assert_eq!(p1.busy.mean().to_bits(), p3.busy.mean().to_bits());
        assert_eq!(p1.total_events, p3.total_events);
        assert_eq!(p1.samples.raw(), p3.samples.raw());
    }

    #[test]
    fn parallel_engine_matches_closed_form() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.25);
        let s = scn(12, 4, spec.clone());
        let sum = simulate_many_parallel(&s, &EngineConfig::default(), 100_000, 3, 4);
        assert_eq!(sum.completion.count(), 100_000);
        let cf = completion_time_stats(12, 4, &spec).unwrap();
        let err = (sum.completion.mean() - cf.mean).abs();
        assert!(err < 0.02, "parallel engine {} vs cf {}", sum.completion.mean(), cf.mean);
        // Shard-merged busy/wasted must match a sequential run of the
        // same trial count statistically (different substreams).
        let seq = simulate_many(&s, &EngineConfig::default(), 100_000, 3);
        let rel = (sum.busy.mean() - seq.busy.mean()).abs() / seq.busy.mean();
        assert!(rel < 0.02, "busy parallel {} vs seq {}", sum.busy.mean(), seq.busy.mean());
    }

    #[test]
    fn k_of_b_completion_matches_partial_closed_form() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        for (n, b, k) in [(24usize, 6usize, 3usize), (12, 4, 2)] {
            let s = scn(n, b, spec.clone()).with_k_of_b(k).unwrap();
            let sum = simulate_many(&s, &EngineConfig::default(), 100_000, 13);
            let cf =
                partial_completion_stats(n as u64, b as u64, k as u64, &spec).unwrap();
            let err = (sum.completion.mean() - cf.mean).abs();
            assert!(
                err < 0.02,
                "n={n} B={b} k={k}: engine {} vs cf {}",
                sum.completion.mean(),
                cf.mean
            );
        }
    }

    #[test]
    fn k_of_b_equal_to_b_matches_full_completion() {
        // k = B on a disjoint layout is the ordinary completion rule:
        // identical RNG stream, identical trajectories, bit-equal stats.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let full = scn(12, 4, spec.clone());
        let kfull = scn(12, 4, spec).with_k_of_b(4).unwrap();
        let a = simulate_many(&full, &EngineConfig::default(), 20_000, 5);
        let b = simulate_many(&kfull, &EngineConfig::default(), 20_000, 5);
        assert_eq!(a.completion.mean().to_bits(), b.completion.mean().to_bits());
        assert_eq!(a.busy.mean().to_bits(), b.busy.mean().to_bits());
        assert_eq!(a.total_events, b.total_events);
    }

    #[test]
    fn cancellation_reduces_cost_not_completion() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let with = simulate_many(
            &s,
            &EngineConfig { cancellation: true, ..EngineConfig::default() },
            50_000,
            4,
        );
        let without = simulate_many(
            &s,
            &EngineConfig { cancellation: false, ..EngineConfig::default() },
            50_000,
            4,
        );
        // Same completion distribution (same seed ⇒ same draws in same
        // order for upfront mode).
        assert!(
            (with.completion.mean() - without.completion.mean()).abs() < 1e-9,
            "completion should not depend on cancellation"
        );
        assert!(
            with.busy.mean() < without.busy.mean(),
            "cancellation must reduce busy time: {} !< {}",
            with.busy.mean(),
            without.busy.mean()
        );
    }

    #[test]
    fn speculative_trades_latency_for_cost() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let upfront = simulate_many(&s, &EngineConfig::default(), 50_000, 5);
        let spec_cfg = EngineConfig {
            redundancy: Redundancy::Speculative { deadline_factor: 1.5 },
            ..EngineConfig::default()
        };
        let reactive = simulate_many(&s, &spec_cfg, 50_000, 5);
        // Reactive waits before helping: strictly slower on average...
        assert!(
            reactive.completion.mean() > upfront.completion.mean(),
            "reactive {} !> upfront {}",
            reactive.completion.mean(),
            upfront.completion.mean()
        );
        // ...but cheaper (backups usually never launch).
        assert!(
            reactive.busy.mean() < upfront.busy.mean(),
            "reactive busy {} !< upfront busy {}",
            reactive.busy.mean(),
            upfront.busy.mean()
        );
    }

    #[test]
    fn no_redundancy_means_no_waste() {
        // B = N: one worker per batch, nothing to cancel.
        let s = scn(8, 8, ServiceSpec::exp(1.0));
        let sum = simulate_many(&s, &EngineConfig::default(), 10_000, 6);
        assert_eq!(sum.wasted.mean(), 0.0);
    }

    #[test]
    fn failure_injection_zero_is_baseline() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let base = simulate_many(&s, &EngineConfig::default(), 20_000, 8);
        let zero = simulate_many(
            &s,
            &EngineConfig { fail_prob: 0.0, ..EngineConfig::default() },
            20_000,
            8,
        );
        assert_eq!(base.completion.mean(), zero.completion.mean());
    }

    #[test]
    fn failure_injection_slows_but_always_completes() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let base = simulate_many(&s, &EngineConfig::default(), 20_000, 9);
        let faulty = simulate_many(
            &s,
            &EngineConfig { fail_prob: 0.3, ..EngineConfig::default() },
            20_000,
            9,
        );
        // Every trial completed (simulate_one would have paniced in
        // debug, and completion is finite in the Welford min/max).
        assert!(faulty.completion.max().is_finite());
        assert!(
            faulty.completion.mean() > base.completion.mean(),
            "crashes must slow completion: {} !> {}",
            faulty.completion.mean(),
            base.completion.mean()
        );
    }

    #[test]
    fn extreme_failure_rate_relies_on_relaunch() {
        // p=0.9 with g=4 replicas: P(all crash) = 0.66 per batch per
        // wave — most trials need at least one relaunch and still finish.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(8, 2, spec);
        let cfg = EngineConfig { fail_prob: 0.9, ..EngineConfig::default() };
        let sum = simulate_many(&s, &cfg, 5_000, 10);
        assert_eq!(sum.completion.count(), 5_000);
        assert!(sum.completion.max().is_finite());
        // Geometric relaunch chains make the tail long but finite.
        assert!(sum.completion.mean() > 2.0 * 1.567, "relaunches should dominate");
    }

    #[test]
    fn failed_replicas_cost_nothing_when_unreplicated() {
        // B = N with failures: crashed replicas do no work; busy time
        // only accrues for survivors and relaunches.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(4, 4, spec);
        let cfg = EngineConfig { fail_prob: 0.5, ..EngineConfig::default() };
        let sum = simulate_many(&s, &cfg, 10_000, 11);
        assert!(sum.wasted.mean() < 1e-12, "no redundancy => no waste");
    }

    #[test]
    fn prop_engine_invariants() {
        testkit::check("engine-invariants", 100, |g| {
            let n = *g.pick(&[2usize, 4, 6, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let spec = ServiceSpec::shifted_exp(1.0, g.f64_in(0.0, 1.0));
            let mut s = scn(n, b, spec);
            if g.coin(0.3) {
                s = s.with_k_of_b(g.usize_in(1, b)).unwrap();
            }
            let cfg = EngineConfig {
                cancellation: g.coin(0.5),
                redundancy: if g.coin(0.5) {
                    Redundancy::Upfront
                } else {
                    Redundancy::Speculative { deadline_factor: g.f64_in(0.5, 3.0) }
                },
                fail_prob: if g.coin(0.5) { 0.0 } else { g.f64_in(0.0, 0.8) },
                ..EngineConfig::default()
            };
            let mut rng = g.rng();
            let r = simulate_one(&s, &cfg, &mut rng);
            assert!(r.completion.is_finite() && r.completion > 0.0);
            if cfg.fail_prob == 0.0 {
                // Without crashes someone is always working until the
                // job completes; with crashes the cluster can sit idle
                // waiting out a stall timeout, so busy may be smaller.
                assert!(
                    r.busy >= r.completion - 1e-9,
                    "busy {} < completion {}",
                    r.busy,
                    r.completion
                );
            }
            assert!(r.busy >= 0.0);
            assert!(r.wasted >= -1e-12 && r.wasted <= r.busy + 1e-9);
            assert!(r.events >= s.k_of_b.unwrap_or(b) as u64);
        });
    }

    #[test]
    fn prop_fast_and_reference_engines_agree() {
        // Random scenario/config pairs: both engines must describe the
        // same system. fail_prob = 0 pairs are stream-equivalent (tight
        // tolerance); failure-injected pairs are bit-identical (scalar
        // fallback consumes the identical stream).
        testkit::check("engine-fast-vs-reference", 30, |g| {
            let n = *g.pick(&[4usize, 6, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let spec = ServiceSpec::shifted_exp(1.0, g.f64_in(0.0, 0.6));
            let mut s = scn(n, b, spec);
            if g.coin(0.3) {
                s = s.with_k_of_b(g.usize_in(1, b)).unwrap();
            }
            let cfg = EngineConfig {
                cancellation: g.coin(0.5),
                redundancy: if g.coin(0.5) {
                    Redundancy::Upfront
                } else {
                    Redundancy::Speculative { deadline_factor: g.f64_in(0.5, 2.5) }
                },
                fail_prob: if g.coin(0.7) { 0.0 } else { g.f64_in(0.1, 0.6) },
                ..EngineConfig::default()
            };
            let seed = g.u64_in(0, 1 << 40);
            let fast = simulate_many(&s, &cfg, 500, seed);
            let refr = simulate_many_reference(&s, &cfg, 500, seed);
            assert_eq!(fast.total_events, refr.total_events);
            let rel = (fast.completion.mean() - refr.completion.mean()).abs()
                / refr.completion.mean().abs().max(1.0);
            assert!(rel <= 1e-9, "completion rel diff {rel}");
        });
    }

    #[test]
    fn verify_m_engine_matches_verified_closed_form_and_cost() {
        // The quorum path of the trial engine must reproduce both the
        // m-of-g completion closed form and the order-statistic cost
        // bill (analysis::verified_cost_stats).
        let spec = ServiceSpec::shifted_exp(1.0, 0.25);
        for (n, b, m) in [(12usize, 4usize, 2usize), (12, 3, 3), (24, 6, 2)] {
            let s = scn(n, b, spec.clone()).with_verify_m(m).unwrap();
            let sum = simulate_many(&s, &EngineConfig::default(), 60_000, 3);
            let cf = crate::analysis::verified_completion_stats(
                n as u64, b as u64, m as u64, b as u64, &spec,
            )
            .unwrap();
            assert!(
                (sum.completion.mean() - cf.mean).abs() < 4.0 * sum.completion.sem() + 0.01,
                "n={n} b={b} m={m}: engine {} vs cf {}",
                sum.completion.mean(),
                cf.mean
            );
            let (busy, wasted) =
                crate::analysis::verified_cost_stats(n as u64, b as u64, m as u64, &spec)
                    .unwrap();
            assert!(
                (sum.busy.mean() - busy).abs() / busy < 0.02,
                "n={n} b={b} m={m}: busy {} vs cf {busy}",
                sum.busy.mean()
            );
            let w_scale = wasted.max(1.0);
            assert!(
                (sum.wasted.mean() - wasted).abs() / w_scale < 0.03,
                "n={n} b={b} m={m}: wasted {} vs cf {wasted}",
                sum.wasted.mean()
            );
        }
    }

    #[test]
    fn verify_m_fast_and_reference_engines_agree() {
        // Both engines must implement identical quorum semantics.
        for (n, b, m) in [(12usize, 4usize, 2usize), (8, 2, 3), (12, 3, 4)] {
            let s = scn(n, b, ServiceSpec::shifted_exp(1.0, 0.3))
                .with_verify_m(m)
                .unwrap();
            for cancellation in [true, false] {
                let cfg = EngineConfig { cancellation, ..EngineConfig::default() };
                let fast = simulate_many(&s, &cfg, 500, 41);
                let refr = simulate_many_reference(&s, &cfg, 500, 41);
                assert_eq!(fast.total_events, refr.total_events, "n={n} b={b} m={m}");
                let rel = (fast.completion.mean() - refr.completion.mean()).abs()
                    / refr.completion.mean().max(1.0);
                assert!(rel <= 1e-9, "n={n} b={b} m={m}: completion rel diff {rel}");
                let relb =
                    (fast.busy.mean() - refr.busy.mean()).abs() / refr.busy.mean().max(1.0);
                assert!(relb <= 1e-9, "n={n} b={b} m={m}: busy rel diff {relb}");
            }
        }
    }

    #[test]
    fn fault_rounds_flag_and_quarantine_a_corrupt_worker() {
        use crate::fault::{FaultEvent, FaultPlan};
        // Worker 0 corrupts every result from round 1 (prob 1). With
        // g = 3 and verify_m = 2 its batch always has 2 honest
        // comparators, so voting flags it each round; at the default
        // 2-strike budget it is quarantined at the end of round 2 and
        // respawns QUARANTINE_RESPAWN_ROUNDS = 2 rounds later with a
        // clean strike record.
        let s = scn(12, 4, ServiceSpec::shifted_exp(1.0, 0.2)).with_verify_m(2).unwrap();
        let plan = FaultPlan {
            name: "c".into(),
            seed: 5,
            events: vec![(0, FaultEvent::Corruption { from_round: 1, prob: 1.0 })],
        }
        .compile(12)
        .unwrap();
        let mut rng = Rng::new(7);
        let stats =
            simulate_fault_rounds(&s, &plan, 8, &EngineConfig::default(), &mut rng).unwrap();
        assert_eq!(stats[0].corrupted, 0);
        assert_eq!(stats[0].flagged, 0);
        // Rounds 1, 2: corrupt, flagged; strike budget hits at round 2.
        for r in [1usize, 2] {
            assert_eq!(stats[r].corrupted, 1, "round {r}");
            assert_eq!(stats[r].flagged, 1, "round {r}");
            assert_eq!(stats[r].degradations, 0, "round {r}");
        }
        assert_eq!(stats[1].quarantined, 0);
        assert_eq!(stats[2].quarantined, 1);
        assert_eq!(stats[2].live_workers, 11);
        // Quarantined ⇒ excluded from dispatch: no corrupt results
        // while dead (the never-dispatched property, DES side).
        assert_eq!(stats[3].corrupted, 0);
        assert_eq!(stats[3].live_workers, 11);
        // Respawn at 2 + 2: back at round 4, strikes reset, so the
        // second quarantine needs two fresh flags (rounds 4 and 5) and
        // backs off twice as long.
        assert_eq!(stats[4].respawns, 1);
        assert_eq!(stats[4].flagged, 1);
        assert_eq!(stats[4].quarantined, 0, "strike record was reset on respawn");
        assert_eq!(stats[5].quarantined, 1);
        for st in &stats {
            assert!(st.completion.is_finite() && st.completion > 0.0);
        }
        // Plan-deterministic schedule: bit-identical on a fresh RNG.
        let mut rng2 = Rng::new(7);
        let again =
            simulate_fault_rounds(&s, &plan, 8, &EngineConfig::default(), &mut rng2).unwrap();
        assert_eq!(stats, again);
    }

    #[test]
    fn fault_rounds_all_corrupt_batch_is_detected_but_unrecoverable() {
        use crate::fault::{FaultEvent, FaultPlan};
        // Workers 0 and 1 are both replicas of batch 0 (balanced 8/4,
        // g = 2) and both corrupt from round 1: voting sees full
        // disagreement with < 2 honest comparators — detected but
        // unrecoverable, counted as a degradation, nobody flagged.
        let s = scn(8, 4, ServiceSpec::shifted_exp(1.0, 0.2)).with_verify_m(2).unwrap();
        let plan = FaultPlan {
            name: "cc".into(),
            seed: 3,
            events: vec![
                (0, FaultEvent::Corruption { from_round: 1, prob: 1.0 }),
                (1, FaultEvent::Corruption { from_round: 1, prob: 1.0 }),
            ],
        }
        .compile(8)
        .unwrap();
        let mut rng = Rng::new(19);
        let stats =
            simulate_fault_rounds(&s, &plan, 4, &EngineConfig::default(), &mut rng).unwrap();
        assert_eq!(stats[0].degradations, 0);
        for r in 1..4 {
            assert_eq!(stats[r].corrupted, 2, "round {r}");
            assert_eq!(stats[r].flagged, 0, "round {r}: attribution impossible");
            assert_eq!(stats[r].quarantined, 0, "round {r}");
            assert_eq!(stats[r].degradations, 1, "round {r}");
            assert_eq!(stats[r].live_workers, 8, "round {r}");
            assert!(stats[r].completion.is_finite());
        }
    }

    #[test]
    fn fault_rounds_without_verification_accept_corruption_silently() {
        use crate::fault::{FaultEvent, FaultPlan};
        // verify_m off: corruption is counted (the plan's coin is
        // observable) but nothing is flagged — the blind spot the
        // integrity layer exists to close.
        let s = scn(8, 4, ServiceSpec::shifted_exp(1.0, 0.2));
        let plan = FaultPlan {
            name: "s".into(),
            seed: 3,
            events: vec![(2, FaultEvent::Corruption { from_round: 0, prob: 1.0 })],
        }
        .compile(8)
        .unwrap();
        let mut rng = Rng::new(23);
        let stats =
            simulate_fault_rounds(&s, &plan, 3, &EngineConfig::default(), &mut rng).unwrap();
        for st in &stats {
            assert_eq!(st.corrupted, 1);
            assert_eq!(st.flagged, 0);
            assert_eq!(st.quarantined, 0);
            assert_eq!(st.degradations, 0);
            assert_eq!(st.live_workers, 8);
        }
    }

    #[test]
    fn fault_rounds_track_transient_crash_and_respawn() {
        use crate::fault::{FaultEvent, FaultPlan};
        let s = scn(6, 3, ServiceSpec::shifted_exp(1.0, 0.25));
        let plan = FaultPlan {
            name: "t".into(),
            seed: 5,
            events: vec![(
                0,
                FaultEvent::TransientCrash { round: 2, fraction: 0.5, respawn_after: 2 },
            )],
        }
        .compile(6)
        .unwrap();
        let mut rng = Rng::new(77);
        let stats =
            simulate_fault_rounds(&s, &plan, 8, &EngineConfig::default(), &mut rng).unwrap();
        assert_eq!(stats.len(), 8);
        assert_eq!(stats[2].crashes, 1);
        assert_eq!(stats[2].live_workers, 5);
        assert_eq!(stats[3].respawns, 0);
        // respawn_at = 2 + 2 = 4.
        assert_eq!(stats[4].respawns, 1);
        assert_eq!(stats[4].live_workers, 6);
        for st in &stats {
            assert!(st.completion.is_finite() && st.completion > 0.0);
        }
        // Deterministic per (plan, seed).
        let mut rng2 = Rng::new(77);
        let again =
            simulate_fault_rounds(&s, &plan, 8, &EngineConfig::default(), &mut rng2).unwrap();
        assert_eq!(stats, again);
    }

    #[test]
    fn fault_rounds_degrade_when_a_sole_replica_dies() {
        use crate::fault::{FaultEvent, FaultPlan};
        // g = 1 (full parallelism): a permanent crash leaves its batch
        // with no replica, forcing a degraded re-plan onto survivors.
        let s = scn(4, 4, ServiceSpec::exp(1.0));
        let plan = FaultPlan {
            name: "p".into(),
            seed: 9,
            events: vec![(1, FaultEvent::PermanentCrash { round: 1, fraction: 0.5 })],
        }
        .compile(4)
        .unwrap();
        let mut rng = Rng::new(3);
        let stats =
            simulate_fault_rounds(&s, &plan, 4, &EngineConfig::default(), &mut rng).unwrap();
        assert_eq!(stats[1].degradations, 1);
        assert_eq!(stats[1].crashes, 1);
        assert_eq!(stats[1].live_workers, 3);
        // 4 units on 3 survivors: largest divisor of 4 that is ≤ 3 is 2.
        for st in &stats[1..] {
            assert_eq!(st.live_workers, 3);
            assert!(st.completion.is_finite());
        }
    }

    #[test]
    fn fault_rounds_relaunch_recovers_certain_drops() {
        use crate::fault::{FaultEvent, FaultPlan};
        // Drop probability 1: every task is dropped every round, so
        // every batch must be recovered by exactly one relaunch.
        let s = scn(4, 2, ServiceSpec::exp(1.0));
        let plan = FaultPlan {
            name: "d".into(),
            seed: 11,
            events: vec![
                (0, FaultEvent::TaskDrop { prob: 0.999_999 }),
                (1, FaultEvent::TaskDrop { prob: 0.999_999 }),
                (2, FaultEvent::TaskDrop { prob: 0.999_999 }),
                (3, FaultEvent::TaskDrop { prob: 0.999_999 }),
            ],
        }
        .compile(4)
        .unwrap();
        let mut rng = Rng::new(21);
        let stats =
            simulate_fault_rounds(&s, &plan, 3, &EngineConfig::default(), &mut rng).unwrap();
        for st in &stats {
            assert_eq!(st.dropped, 4, "all four tasks dropped");
            assert_eq!(st.relaunches, 2, "each batch relaunched once");
            assert!(st.completion.is_finite());
        }
    }
}
