//! Event-driven simulator of System1.
//!
//! Beyond the Monte-Carlo sampler, the engine models the *mechanics* the
//! closed forms abstract away:
//!
//! * **replica cancellation** — when the first replica of a batch
//!   finishes, its siblings are cancelled; this never changes the
//!   completion time but determines the *cost* (busy worker-seconds),
//!   the redundancy bill the paper alludes to;
//! * **speculative relaunch** — the reactive MapReduce-style baseline:
//!   run one primary per batch, and only if it has not finished by a
//!   deadline launch the backups. Comparing it against upfront
//!   replication quantifies what the paper's proactive redundancy buys;
//! * **heterogeneous workers** and **straggler traces** via the
//!   scenario's speed factors and service spec.

use super::Scenario;
use crate::util::rng::Rng;
use crate::util::stats::Welford;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Redundancy activation strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Redundancy {
    /// All replicas start at t = 0 (the paper's model).
    Upfront,
    /// One primary per batch at t = 0; backups launch at
    /// `deadline_factor × E[batch service]` if the batch is unfinished.
    Speculative {
        /// Multiple of the mean batch service time to wait before
        /// launching backups.
        deadline_factor: f64,
    },
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Cancel sibling replicas when a batch completes.
    pub cancellation: bool,
    /// Redundancy activation strategy.
    pub redundancy: Redundancy,
    /// Failure injection: each launched replica crash-stops (silently,
    /// producing nothing) with this probability. If *every* replica of
    /// a batch crashes, the master detects the stall after
    /// `relaunch_timeout_factor × E[batch service]` and relaunches the
    /// batch's replicas — replication is the first line of defence,
    /// timeout-relaunch the second.
    pub fail_prob: f64,
    /// Stall-detection timeout as a multiple of the mean batch service.
    pub relaunch_timeout_factor: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            cancellation: true,
            redundancy: Redundancy::Upfront,
            fail_prob: 0.0,
            relaunch_timeout_factor: 3.0,
        }
    }
}

/// Per-trial result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrialResult {
    /// Job completion time.
    pub completion: f64,
    /// Σ busy worker-seconds actually spent.
    pub busy: f64,
    /// Busy seconds spent on replicas that were cancelled or finished
    /// after their batch was already complete (pure redundancy cost).
    pub wasted: f64,
    /// Events processed.
    pub events: u64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// A worker finishes its (possibly backup) task on a batch.
    Finish { worker: usize, batch: usize },
    /// Speculative deadline for a batch: launch backups if unfinished.
    Deadline { batch: usize },
    /// Stall-detection timeout: relaunch the batch if unfinished (all
    /// its replicas crashed).
    Relaunch { batch: usize },
}

#[derive(Debug, Clone, Copy)]
struct QueuedEvent {
    time: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for QueuedEvent {}
impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Total order: by time, ties broken by sequence number (FIFO).
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Reusable per-trial state: lets [`simulate_many`] run the engine
/// allocation-free after the first trial (§Perf iteration 2).
#[derive(Debug, Default)]
pub struct Workspace {
    heap: BinaryHeap<Reverse<QueuedEvent>>,
    start_time: Vec<f64>,
    unit_covered: Vec<bool>,
    batch_done: Vec<bool>,
    cancelled: Vec<bool>,
}

/// Run a single trial through the event engine (allocating wrapper).
pub fn simulate_one(scn: &Scenario, cfg: &EngineConfig, rng: &mut Rng) -> TrialResult {
    simulate_one_with(scn, cfg, rng, &mut Workspace::default())
}

#[inline]
fn push_ev(heap: &mut BinaryHeap<Reverse<QueuedEvent>>, seq: &mut u64, time: f64, ev: Ev) {
    let q = QueuedEvent { time, seq: *seq, ev };
    *seq += 1;
    heap.push(Reverse(q));
}

/// Launch one wave of replicas for a batch at `now`; each replica
/// independently crash-stops with `cfg.fail_prob` (producing nothing and
/// costing nothing). Returns the number of survivors; the caller
/// schedules a Relaunch when zero.
#[allow(clippy::too_many_arguments)]
fn launch_wave(
    scn: &Scenario,
    cfg: &EngineConfig,
    s: u64,
    heap: &mut BinaryHeap<Reverse<QueuedEvent>>,
    seq: &mut u64,
    start_time: &mut [f64],
    batch: usize,
    replicas: &[usize],
    now: f64,
    rng: &mut Rng,
) -> usize {
    let mut survivors = 0;
    for &w in replicas {
        if cfg.fail_prob > 0.0 && rng.coin(cfg.fail_prob) {
            continue;
        }
        let mut t = scn.service.sample_batch(s, rng);
        if let Some(speeds) = &scn.worker_speeds {
            t *= speeds[w];
        }
        start_time[w] = now;
        push_ev(heap, seq, now + t, Ev::Finish { worker: w, batch });
        survivors += 1;
    }
    survivors
}

/// Run a single trial reusing `ws` across calls.
pub fn simulate_one_with(
    scn: &Scenario,
    cfg: &EngineConfig,
    rng: &mut Rng,
    ws: &mut Workspace,
) -> TrialResult {
    let n = scn.n_workers();
    let b = scn.assignment.n_batches;
    let s = scn.batch_units();

    let heap = &mut ws.heap;
    heap.clear();
    let mut seq = 0u64;

    // Stall-detection timeout for crash relaunch (only needed when
    // failures are injected).
    let relaunch_after = if cfg.fail_prob > 0.0 {
        cfg.relaunch_timeout_factor
            * scn
                .service
                .batch_mean(s)
                .expect("failure injection needs a finite mean batch service")
    } else {
        f64::INFINITY
    };

    // Launch per the redundancy strategy.
    let start_time = &mut ws.start_time; // NaN = not launched
    start_time.clear();
    start_time.resize(n, f64::NAN);
    match cfg.redundancy {
        Redundancy::Upfront => {
            for (batch, replicas) in scn.assignment.workers_of_batch.iter().enumerate() {
                let survivors =
                    launch_wave(scn, cfg, s, heap, &mut seq, start_time, batch, replicas, 0.0, rng);
                if survivors == 0 {
                    push_ev(heap, &mut seq, relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
        Redundancy::Speculative { deadline_factor } => {
            let mean_batch = scn
                .service
                .batch_mean(s)
                .expect("speculative redundancy needs a finite mean batch service");
            let deadline = deadline_factor * mean_batch;
            for (batch, replicas) in scn.assignment.workers_of_batch.iter().enumerate() {
                let survivors = launch_wave(
                    scn, cfg, s, heap, &mut seq, start_time, batch, &replicas[..1], 0.0, rng,
                );
                if replicas.len() > 1 {
                    push_ev(heap, &mut seq, deadline, Ev::Deadline { batch });
                } else if survivors == 0 {
                    push_ev(heap, &mut seq, relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
    }

    // Coverage state.
    let n_units = scn.layout.n_units;
    let unit_covered = &mut ws.unit_covered;
    unit_covered.clear();
    unit_covered.resize(n_units, false);
    let mut units_left = n_units;
    let batch_done = &mut ws.batch_done;
    batch_done.clear();
    batch_done.resize(b, false);
    let cancelled = &mut ws.cancelled;
    cancelled.clear();
    cancelled.resize(n, false);

    let mut busy = 0.0f64;
    let mut wasted = 0.0f64;
    let mut events = 0u64;
    let mut completion = f64::NAN;

    while let Some(Reverse(QueuedEvent { time, ev, .. })) = heap.pop() {
        events += 1;
        match ev {
            Ev::Finish { worker, batch } => {
                if cancelled[worker] {
                    continue;
                }
                let work = time - start_time[worker];
                busy += work;
                if batch_done[batch] {
                    // A sibling already finished this batch (cancellation
                    // disabled, or completion raced the cancel).
                    wasted += work;
                    continue;
                }
                batch_done[batch] = true;
                for &u in &scn.layout.units_of_batch[batch] {
                    if !unit_covered[u] {
                        unit_covered[u] = true;
                        units_left -= 1;
                    }
                }
                if cfg.cancellation {
                    for &sib in &scn.assignment.workers_of_batch[batch] {
                        if sib != worker && !cancelled[sib] && !start_time[sib].is_nan() {
                            cancelled[sib] = true;
                            let partial = time - start_time[sib];
                            busy += partial;
                            wasted += partial;
                        }
                    }
                }
                if units_left == 0 && completion.is_nan() {
                    completion = time;
                    if cfg.cancellation {
                        // All remaining work (other batches' stragglers
                        // in overlapping layouts) is moot once the job
                        // is complete.
                        for w in 0..n {
                            if !cancelled[w] && !start_time[w].is_nan() {
                                // Only cancel workers whose batch is done
                                // or irrelevant; with disjoint layouts
                                // every batch was needed, so this only
                                // fires for overlapping layouts.
                                if batch_done[scn.assignment.batch_of_worker[w]] {
                                    continue;
                                }
                                cancelled[w] = true;
                                let partial = time - start_time[w];
                                busy += partial;
                                wasted += partial;
                            }
                        }
                    }
                }
            }
            Ev::Deadline { batch } => {
                if batch_done[batch] {
                    continue;
                }
                // Launch every backup replica of this batch now.
                let replicas = &scn.assignment.workers_of_batch[batch];
                let survivors = launch_wave(
                    scn, cfg, s, heap, &mut seq, start_time, batch, &replicas[1..], time, rng,
                );
                if survivors == 0 && cfg.fail_prob > 0.0 {
                    // Backups all crashed; if the primary also crashed
                    // the stall timer is the only way forward (if the
                    // primary is alive this Relaunch will be moot).
                    push_ev(heap, &mut seq, time + relaunch_after, Ev::Relaunch { batch });
                }
            }
            Ev::Relaunch { batch } => {
                if batch_done[batch] {
                    continue;
                }
                let replicas = scn.assignment.workers_of_batch[batch].clone();
                let survivors = launch_wave(
                    scn, cfg, s, heap, &mut seq, start_time, batch, &replicas, time, rng,
                );
                if survivors == 0 {
                    push_ev(heap, &mut seq, time + relaunch_after, Ev::Relaunch { batch });
                }
            }
        }
        // Early exit: once complete and cancellation is on, the heap may
        // still hold events for cancelled workers; drain them cheaply.
        if !completion.is_nan() && cfg.cancellation {
            while let Some(Reverse(q)) = heap.pop() {
                events += 1;
                if let Ev::Finish { worker, .. } = q.ev {
                    if !cancelled[worker] {
                        // Shouldn't happen for disjoint layouts; be safe
                        // and account the full run.
                        let work = q.time - start_time[worker];
                        busy += work;
                        wasted += work;
                    }
                }
            }
            break;
        }
    }

    debug_assert!(!completion.is_nan(), "job never completed");
    TrialResult { completion, busy, wasted, events }
}

/// Aggregate over many trials.
#[derive(Debug, Clone)]
pub struct EngineSummary {
    /// Completion-time statistics.
    pub completion: Welford,
    /// Busy worker-seconds statistics.
    pub busy: Welford,
    /// Wasted worker-seconds statistics.
    pub wasted: Welford,
    /// Total events processed.
    pub total_events: u64,
}

/// Run `trials` trials.
pub fn simulate_many(
    scn: &Scenario,
    cfg: &EngineConfig,
    trials: u64,
    seed: u64,
) -> EngineSummary {
    let mut rng = Rng::new(seed);
    let mut completion = Welford::new();
    let mut busy = Welford::new();
    let mut wasted = Welford::new();
    let mut total_events = 0;
    let mut workspace = Workspace::default();
    for _ in 0..trials {
        let r = simulate_one_with(scn, cfg, &mut rng, &mut workspace);
        completion.push(r.completion);
        busy.push(r.busy);
        wasted.push(r.wasted);
        total_events += r.events;
    }
    EngineSummary { completion, busy, wasted, total_events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::completion_time_stats;
    use crate::dist::{BatchService, ServiceSpec};
    use crate::testkit;

    fn scn(n: usize, b: usize, spec: ServiceSpec) -> Scenario {
        Scenario::paper_balanced(n, b, BatchService::paper(spec)).unwrap()
    }

    #[test]
    fn engine_matches_closed_form() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.25);
        let s = scn(12, 4, spec.clone());
        let sum = simulate_many(&s, &EngineConfig::default(), 100_000, 3);
        let cf = completion_time_stats(12, 4, &spec).unwrap();
        let err = (sum.completion.mean() - cf.mean).abs();
        assert!(err < 0.02, "engine {} vs cf {}", sum.completion.mean(), cf.mean);
    }

    #[test]
    fn engine_matches_montecarlo() {
        // Two independent implementations must agree.
        let spec = ServiceSpec::exp(1.0);
        let s = scn(8, 2, spec);
        let e = simulate_many(&s, &EngineConfig::default(), 100_000, 9);
        let m = super::super::montecarlo::run_trials(&s, 100_000, 10);
        assert!(
            (e.completion.mean() - m.mean()).abs() < 0.02,
            "engine {} vs mc {}",
            e.completion.mean(),
            m.mean()
        );
    }

    #[test]
    fn cancellation_reduces_cost_not_completion() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let with = simulate_many(
            &s,
            &EngineConfig { cancellation: true, ..EngineConfig::default() },
            50_000,
            4,
        );
        let without = simulate_many(
            &s,
            &EngineConfig { cancellation: false, ..EngineConfig::default() },
            50_000,
            4,
        );
        // Same completion distribution (same seed ⇒ same draws in same
        // order for upfront mode).
        assert!(
            (with.completion.mean() - without.completion.mean()).abs() < 1e-9,
            "completion should not depend on cancellation"
        );
        assert!(
            with.busy.mean() < without.busy.mean(),
            "cancellation must reduce busy time: {} !< {}",
            with.busy.mean(),
            without.busy.mean()
        );
    }

    #[test]
    fn speculative_trades_latency_for_cost() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let upfront = simulate_many(&s, &EngineConfig::default(), 50_000, 5);
        let spec_cfg = EngineConfig {
            redundancy: Redundancy::Speculative { deadline_factor: 1.5 },
            ..EngineConfig::default()
        };
        let reactive = simulate_many(&s, &spec_cfg, 50_000, 5);
        // Reactive waits before helping: strictly slower on average...
        assert!(
            reactive.completion.mean() > upfront.completion.mean(),
            "reactive {} !> upfront {}",
            reactive.completion.mean(),
            upfront.completion.mean()
        );
        // ...but cheaper (backups usually never launch).
        assert!(
            reactive.busy.mean() < upfront.busy.mean(),
            "reactive busy {} !< upfront busy {}",
            reactive.busy.mean(),
            upfront.busy.mean()
        );
    }

    #[test]
    fn no_redundancy_means_no_waste() {
        // B = N: one worker per batch, nothing to cancel.
        let s = scn(8, 8, ServiceSpec::exp(1.0));
        let sum = simulate_many(&s, &EngineConfig::default(), 10_000, 6);
        assert_eq!(sum.wasted.mean(), 0.0);
    }

    #[test]
    fn failure_injection_zero_is_baseline() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let base = simulate_many(&s, &EngineConfig::default(), 20_000, 8);
        let zero = simulate_many(
            &s,
            &EngineConfig { fail_prob: 0.0, ..EngineConfig::default() },
            20_000,
            8,
        );
        assert_eq!(base.completion.mean(), zero.completion.mean());
    }

    #[test]
    fn failure_injection_slows_but_always_completes() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(12, 3, spec);
        let base = simulate_many(&s, &EngineConfig::default(), 20_000, 9);
        let faulty = simulate_many(
            &s,
            &EngineConfig { fail_prob: 0.3, ..EngineConfig::default() },
            20_000,
            9,
        );
        // Every trial completed (simulate_one would have paniced in
        // debug, and completion is finite in the Welford min/max).
        assert!(faulty.completion.max().is_finite());
        assert!(
            faulty.completion.mean() > base.completion.mean(),
            "crashes must slow completion: {} !> {}",
            faulty.completion.mean(),
            base.completion.mean()
        );
    }

    #[test]
    fn extreme_failure_rate_relies_on_relaunch() {
        // p=0.9 with g=4 replicas: P(all crash) = 0.66 per batch per
        // wave — most trials need at least one relaunch and still finish.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(8, 2, spec);
        let cfg = EngineConfig { fail_prob: 0.9, ..EngineConfig::default() };
        let sum = simulate_many(&s, &cfg, 5_000, 10);
        assert_eq!(sum.completion.count(), 5_000);
        assert!(sum.completion.max().is_finite());
        // Geometric relaunch chains make the tail long but finite.
        assert!(sum.completion.mean() > 2.0 * 1.567, "relaunches should dominate");
    }

    #[test]
    fn failed_replicas_cost_nothing_when_unreplicated() {
        // B = N with failures: crashed replicas do no work; busy time
        // only accrues for survivors and relaunches.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let s = scn(4, 4, spec);
        let cfg = EngineConfig { fail_prob: 0.5, ..EngineConfig::default() };
        let sum = simulate_many(&s, &cfg, 10_000, 11);
        assert!(sum.wasted.mean() < 1e-12, "no redundancy => no waste");
    }

    #[test]
    fn prop_engine_invariants() {
        testkit::check("engine-invariants", 100, |g| {
            let n = *g.pick(&[2usize, 4, 6, 12]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let spec = ServiceSpec::shifted_exp(1.0, g.f64_in(0.0, 1.0));
            let s = scn(n, b, spec);
            let cfg = EngineConfig {
                cancellation: g.coin(0.5),
                redundancy: if g.coin(0.5) {
                    Redundancy::Upfront
                } else {
                    Redundancy::Speculative { deadline_factor: g.f64_in(0.5, 3.0) }
                },
                fail_prob: if g.coin(0.5) { 0.0 } else { g.f64_in(0.0, 0.8) },
                ..EngineConfig::default()
            };
            let mut rng = g.rng();
            let r = simulate_one(&s, &cfg, &mut rng);
            assert!(r.completion.is_finite() && r.completion > 0.0);
            if cfg.fail_prob == 0.0 {
                // Without crashes someone is always working until the
                // job completes; with crashes the cluster can sit idle
                // waiting out a stall timeout, so busy may be smaller.
                assert!(r.busy >= r.completion - 1e-9, "busy {} < completion {}", r.busy, r.completion);
            }
            assert!(r.busy >= 0.0);
            assert!(r.wasted >= -1e-12 && r.wasted <= r.busy + 1e-9);
            assert!(r.events >= b as u64);
        });
    }
}
