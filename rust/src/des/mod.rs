//! Simulation of System1: a fast Monte-Carlo completion-time sampler and
//! a full discrete-event engine.
//!
//! * [`montecarlo`] draws worker service times and computes the job
//!   completion time directly (the earliest instant at which the
//!   finished workers' data covers the whole dataset). This is the hot
//!   path for the paper's sweeps (E1–E5): millions of trials across the
//!   diversity–parallelism spectrum.
//! * [`engine`] is an event-driven simulator with replica cancellation,
//!   speculative-relaunch (the MapReduce-style reactive baseline the
//!   paper's upfront replication competes against), heterogeneous worker
//!   speeds, and cost accounting (busy/wasted worker-seconds) — the
//!   quantities the closed forms do not cover.

pub mod engine;
pub mod montecarlo;

use crate::assignment::Assignment;
use crate::batching::DataLayout;
use crate::dist::BatchService;

/// A fully specified simulation scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Sample→batch layout (stage 1).
    pub layout: DataLayout,
    /// Batch→worker assignment (stage 2).
    pub assignment: Assignment,
    /// Batch service-time model.
    pub service: BatchService,
    /// Optional per-worker speed multipliers (heterogeneous cluster
    /// ablation); service time is multiplied by this factor. `None` =
    /// homogeneous.
    pub worker_speeds: Option<Vec<f64>>,
}

impl Scenario {
    /// Construct and validate a scenario.
    pub fn new(
        layout: DataLayout,
        assignment: Assignment,
        service: BatchService,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            layout.n_batches() == assignment.n_batches,
            "layout has {} batches, assignment {}",
            layout.n_batches(),
            assignment.n_batches
        );
        layout.validate()?;
        assignment.validate()?;
        Ok(Self { layout, assignment, service, worker_speeds: None })
    }

    /// Attach heterogeneous worker speed factors.
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            speeds.len() == self.assignment.n_workers,
            "need one speed per worker"
        );
        anyhow::ensure!(speeds.iter().all(|&s| s > 0.0), "speeds must be positive");
        self.worker_speeds = Some(speeds);
        Ok(self)
    }

    /// Number of workers.
    pub fn n_workers(&self) -> usize {
        self.assignment.n_workers
    }

    /// Batch size in data units.
    pub fn batch_units(&self) -> u64 {
        self.layout.batch_units() as u64
    }

    /// Convenience: the paper's canonical scenario — `n` workers,
    /// `b` balanced disjoint batches (`b | n`, `U = n` units).
    pub fn paper_balanced(
        n: usize,
        b: usize,
        service: BatchService,
    ) -> anyhow::Result<Self> {
        let layout = crate::batching::disjoint(n, b)?;
        let assignment = crate::assignment::balanced(n, b)?;
        Self::new(layout, assignment, service)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceSpec;

    #[test]
    fn scenario_validates_consistency() {
        let layout = crate::batching::disjoint(8, 2).unwrap();
        let assignment = crate::assignment::balanced(8, 4).unwrap();
        let svc = BatchService::paper(ServiceSpec::exp(1.0));
        assert!(Scenario::new(layout, assignment, svc).is_err());
    }

    #[test]
    fn speeds_checked() {
        let svc = BatchService::paper(ServiceSpec::exp(1.0));
        let s = Scenario::paper_balanced(4, 2, svc).unwrap();
        assert!(s.clone().with_speeds(vec![1.0; 3]).is_err());
        assert!(s.clone().with_speeds(vec![1.0, 1.0, 0.0, 1.0]).is_err());
        assert!(s.with_speeds(vec![1.0; 4]).is_ok());
    }
}
