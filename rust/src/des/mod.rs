//! Simulation of System1: a fast Monte-Carlo completion-time sampler and
//! a full discrete-event engine.
//!
//! * [`montecarlo`] draws worker service times and computes the job
//!   completion time directly (the earliest instant at which the
//!   finished workers' data covers the whole dataset). This is the hot
//!   path for the paper's sweeps (E1–E5): millions of trials across the
//!   diversity–parallelism spectrum.
//! * [`engine`] is an event-driven simulator with replica cancellation,
//!   speculative-relaunch (the MapReduce-style reactive baseline the
//!   paper's upfront replication competes against), heterogeneous worker
//!   speeds, and cost accounting (busy/wasted worker-seconds) — the
//!   quantities the closed forms do not cover.
//!
//! The [`Scenario`] defined here is the common input of *every*
//! evaluation backend (see [`crate::evaluator`]): it carries the data
//! layout, the assignment, the batch service law, and — so that it is
//! fully self-describing — the [`ReplicationPolicy`] that built it, the
//! redundancy activation mode, and the root RNG seed.

pub mod engine;
pub mod montecarlo;

use crate::assignment::Assignment;
use crate::batching::DataLayout;
use crate::dist::BatchService;
use crate::evaluator::ReplicationPolicy;

/// Default root seed for scenarios built without an explicit one.
pub const DEFAULT_SEED: u64 = 42;

/// A fully specified evaluation scenario — the single input type every
/// backend (analytic, Monte-Carlo, DES, live) consumes.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Sample→batch layout (stage 1).
    pub layout: DataLayout,
    /// Batch→worker assignment (stage 2).
    pub assignment: Assignment,
    /// Batch service-time model.
    pub service: BatchService,
    /// Optional per-worker speed multipliers (heterogeneous cluster
    /// ablation); service time is multiplied by this factor. `None` =
    /// homogeneous.
    pub worker_speeds: Option<Vec<f64>>,
    /// How the layout/assignment pair was built (`Custom` when supplied
    /// directly to [`Scenario::new`]).
    pub policy: ReplicationPolicy,
    /// Redundancy activation mode backends should model.
    pub redundancy: engine::Redundancy,
    /// Partial-aggregation target (the gradient-coding regime the paper
    /// cites): the job completes once the earliest `k` of the `B`
    /// batches have finished, a batch completing when its earliest
    /// replica does. `None` = full completion (every data unit
    /// covered). Consumed by all four backends — the live coordinator
    /// completes the round at the k-th finished batch and cancels the
    /// rest.
    pub k_of_b: Option<usize>,
    /// Result-integrity verification: a batch completes only once its
    /// `m`-th replica has finished (m-of-g voting — see
    /// [`crate::analysis::verified_completion_stats`]). `None` / `m = 1`
    /// = paper semantics (first replica wins, rest cancelled). Consumed
    /// by all four backends; the live coordinator additionally votes on
    /// the `m` collected values, flags disagreeing replicas, and
    /// quarantines repeat offenders.
    pub verify_m: Option<usize>,
    /// Root RNG seed: all stochastic backends derive their randomness
    /// from it, so results are bit-reproducible given one scenario.
    pub seed: u64,
}

impl Scenario {
    /// Construct and validate a scenario from explicit parts (policy is
    /// recorded as [`ReplicationPolicy::Custom`]).
    pub fn new(
        layout: DataLayout,
        assignment: Assignment,
        service: BatchService,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            layout.n_batches() == assignment.n_batches,
            "layout has {} batches, assignment {}",
            layout.n_batches(),
            assignment.n_batches
        );
        layout.validate()?;
        assignment.validate()?;
        Ok(Self {
            layout,
            assignment,
            service,
            worker_speeds: None,
            policy: ReplicationPolicy::Custom,
            redundancy: engine::Redundancy::Upfront,
            k_of_b: None,
            verify_m: None,
            seed: DEFAULT_SEED,
        })
    }

    /// Build a scenario from a [`ReplicationPolicy`]: `n` workers, `b`
    /// batches, `U = n` data units. Any assignment randomness (e.g.
    /// `RandomBalanced`) is derived from `seed`, so the scenario is
    /// reproducible from its own fields.
    pub fn from_policy(
        policy: ReplicationPolicy,
        n_workers: usize,
        n_batches: usize,
        service: BatchService,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0xA551_6E5E);
        let (layout, assignment) = policy.build(n_workers, n_batches, &mut rng)?;
        let mut scn = Self::new(layout, assignment, service)?;
        scn.policy = policy;
        scn.seed = seed;
        Ok(scn)
    }

    /// Attach heterogeneous worker speed factors.
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            speeds.len() == self.assignment.n_workers,
            "need one speed per worker"
        );
        anyhow::ensure!(speeds.iter().all(|&s| s > 0.0), "speeds must be positive");
        self.worker_speeds = Some(speeds);
        Ok(self)
    }

    /// Set the redundancy activation mode.
    pub fn with_redundancy(mut self, redundancy: engine::Redundancy) -> Self {
        self.redundancy = redundancy;
        self
    }

    /// Set the k-of-B partial-aggregation target (`1 ≤ k ≤ B`; `k = B`
    /// waits for every batch).
    pub fn with_k_of_b(mut self, k: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(
            k >= 1 && k <= self.assignment.n_batches,
            "k-of-B needs 1 <= k <= B (got k={k}, B={})",
            self.assignment.n_batches
        );
        self.k_of_b = Some(k);
        Ok(self)
    }

    /// Set the m-of-g verification level: every batch waits for its
    /// `m`-th replica before completing (`m = 1` is a no-op and is
    /// normalized back to `None`). Refused — naming the offending
    /// field — when `m` exceeds the *minimum* replication degree of
    /// any batch, since such a batch could never collect `m` results.
    pub fn with_verify_m(mut self, m: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(m >= 1, "Scenario::verify_m must be >= 1, got {m}");
        let min_degree = (0..self.assignment.n_batches)
            .map(|b| self.assignment.replication(b))
            .min()
            .unwrap_or(0);
        anyhow::ensure!(
            m <= min_degree,
            "Scenario::verify_m = {m} exceeds the minimum replication degree {min_degree}: \
             some batch has only {min_degree} replica(s) and can never collect {m} votes \
             (raise replication or lower verify_m)"
        );
        self.verify_m = if m >= 2 { Some(m) } else { None };
        Ok(self)
    }

    /// Set the root RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Number of workers.
    pub fn n_workers(&self) -> usize {
        self.assignment.n_workers
    }

    /// Batch size in data units.
    pub fn batch_units(&self) -> u64 {
        self.layout.batch_units() as u64
    }

    /// Convenience: the paper's canonical scenario — `n` workers,
    /// `b` balanced disjoint batches (`b | n`, `U = n` units).
    pub fn paper_balanced(
        n: usize,
        b: usize,
        service: BatchService,
    ) -> anyhow::Result<Self> {
        let layout = crate::batching::disjoint(n, b)?;
        let assignment = crate::assignment::balanced(n, b)?;
        let mut scn = Self::new(layout, assignment, service)?;
        scn.policy = ReplicationPolicy::BalancedDisjoint;
        Ok(scn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceSpec;

    #[test]
    fn scenario_validates_consistency() {
        let layout = crate::batching::disjoint(8, 2).unwrap();
        let assignment = crate::assignment::balanced(8, 4).unwrap();
        let svc = BatchService::paper(ServiceSpec::exp(1.0));
        assert!(Scenario::new(layout, assignment, svc).is_err());
    }

    #[test]
    fn speeds_checked() {
        let svc = BatchService::paper(ServiceSpec::exp(1.0));
        let s = Scenario::paper_balanced(4, 2, svc).unwrap();
        assert!(s.clone().with_speeds(vec![1.0; 3]).is_err());
        assert!(s.clone().with_speeds(vec![1.0, 1.0, 0.0, 1.0]).is_err());
        assert!(s.with_speeds(vec![1.0; 4]).is_ok());
    }

    #[test]
    fn k_of_b_validated() {
        let svc = BatchService::paper(ServiceSpec::exp(1.0));
        let s = Scenario::paper_balanced(8, 4, svc).unwrap();
        assert_eq!(s.k_of_b, None);
        assert!(s.clone().with_k_of_b(0).is_err());
        assert!(s.clone().with_k_of_b(5).is_err());
        assert_eq!(s.with_k_of_b(3).unwrap().k_of_b, Some(3));
    }

    #[test]
    fn verify_m_checked_against_min_replication_degree() {
        let svc = BatchService::paper(ServiceSpec::exp(1.0));
        // Balanced disjoint 8 workers / 4 batches: g = 2 everywhere.
        let s = Scenario::paper_balanced(8, 4, svc.clone()).unwrap();
        assert_eq!(s.verify_m, None);
        assert!(s.clone().with_verify_m(0).is_err());
        assert_eq!(s.clone().with_verify_m(1).unwrap().verify_m, None);
        assert_eq!(s.clone().with_verify_m(2).unwrap().verify_m, Some(2));
        // m = 3 exceeds g = 2 — the refusal names the field and degree.
        let err = s.with_verify_m(3).unwrap_err().to_string();
        assert!(err.contains("Scenario::verify_m"), "{err}");
        assert!(err.contains("minimum replication degree 2"), "{err}");
        // g = 1 (no replication at all) refuses any m >= 2.
        let lone = Scenario::paper_balanced(4, 4, svc).unwrap();
        let err = lone.with_verify_m(2).unwrap_err().to_string();
        assert!(err.contains("minimum replication degree 1"), "{err}");
    }

    #[test]
    fn scenarios_are_self_describing() {
        let svc = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2));
        let scn = Scenario::from_policy(ReplicationPolicy::RandomBalanced, 12, 3, svc, 7)
            .unwrap()
            .with_redundancy(engine::Redundancy::Speculative { deadline_factor: 2.0 });
        assert_eq!(scn.policy, ReplicationPolicy::RandomBalanced);
        assert_eq!(scn.seed, 7);
        assert!(matches!(scn.redundancy, engine::Redundancy::Speculative { .. }));
        // Same seed ⇒ same (possibly random) assignment.
        let svc2 = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2));
        let again =
            Scenario::from_policy(ReplicationPolicy::RandomBalanced, 12, 3, svc2, 7).unwrap();
        assert_eq!(scn.assignment, again.assignment);
    }
}
