//! The single registry of every versioned artifact schema in the crate.
//!
//! D5 cross-checks this table three ways:
//! 1. every source file that declares a `SCHEMA_VERSION` or a
//!    `validate_file`/`validate_json` entry point must appear here,
//! 2. the registered `version` must equal the literal in that file,
//! 3. the registered `version` must equal the live constant (`current`),
//!    so a schema bump that forgets to update the registry — or a registry
//!    edit that forgets the schema — fails the gate either way.
//!
//! Bumping a schema is therefore a two-file change by design: the emitting
//! module and this table, which is the review surface for artifact
//! compatibility.

/// One versioned artifact schema.
#[derive(Debug, Clone, Copy)]
pub struct SchemaEntry {
    /// Artifact file name as written by the CLI (documentation only).
    pub artifact: &'static str,
    /// Defining source file, relative to `rust/src`.
    pub file: &'static str,
    /// Registered schema version (the review-gated value).
    pub version: i64,
    /// The live constant the crate actually emits.
    pub current: i64,
}

/// Source file holding this registry (excluded from the per-file D5 scan,
/// used to anchor registry-level findings).
pub const REGISTRY_FILE: &str = "lint/schemas.rs";

/// Every versioned artifact the crate emits or validates.
pub const SCHEMAS: &[SchemaEntry] = &[
    SchemaEntry {
        artifact: "BENCH_mc.json",
        file: "benchkit/mc.rs",
        version: 1,
        current: crate::benchkit::mc::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "BENCH_des.json",
        file: "benchkit/des.rs",
        version: 1,
        current: crate::benchkit::des::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "STUDY.json",
        file: "study/report.rs",
        version: 1,
        current: crate::study::report::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "CONTROL.json",
        file: "control/report.rs",
        version: 1,
        current: crate::control::report::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "CHAOS.json",
        file: "fault/report.rs",
        version: 2,
        current: crate::fault::report::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "INTEGRITY.json",
        file: "fault/integrity.rs",
        version: 1,
        current: crate::fault::integrity::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "events.jsonl",
        file: "obs/mod.rs",
        version: 1,
        current: crate::obs::SCHEMA_VERSION,
    },
    SchemaEntry {
        artifact: "LINT.json",
        file: "lint/mod.rs",
        version: 1,
        current: crate::lint::SCHEMA_VERSION,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registered_versions_match_live_constants() {
        for e in SCHEMAS {
            assert_eq!(
                e.version, e.current,
                "{}: registry says v{} but the crate emits v{} — update lint::schemas \
                 together with the schema bump",
                e.artifact, e.version, e.current
            );
        }
    }

    #[test]
    fn registry_files_are_unique() {
        for (i, a) in SCHEMAS.iter().enumerate() {
            for b in &SCHEMAS[i + 1..] {
                assert_ne!(a.file, b.file, "duplicate registry entry for {}", a.file);
            }
        }
    }
}
