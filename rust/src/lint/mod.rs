//! `batchrep lint` — a source-level static analyzer for the crate's
//! determinism and hygiene invariants.
//!
//! Every theory-vs-simulation claim in this reproduction rests on
//! invariants that used to be enforced only by convention. This module
//! checks them mechanically on every gate:
//!
//! | rule | invariant |
//! |------|-----------|
//! | D1   | no `partial_cmp` / `f64::max|min` folds in ranking code — `total_cmp` only |
//! | D2   | wall-clock (`Instant::now`, `SystemTime`) and machine-shape probes (`available_parallelism`) confined to `obs`/`coordinator`/`worker`/`benchkit` |
//! | D3   | no OS entropy (`thread_rng`, `from_entropy`); no `HashMap`/`HashSet` in live code (hash-order iteration must never feed an artifact) |
//! | D4   | no `unwrap`/`expect`/`panic!` in library code outside `main.rs`, `testkit`, `#[cfg(test)]` |
//! | D5   | every schema site is registered in [`schemas::SCHEMAS`] and versions agree |
//! | D6   | every counter variant is bumped; every literal event kind is in `obs::KNOWN_KINDS` |
//!
//! Intentional violations carry an inline suppression on (or directly
//! above) the offending line: `// lint:allow(D2): <reason>` — the reason is
//! mandatory and unused suppressions are themselves findings (rule `SUP`).
//! A checked-in baseline (`rust/lint/baseline.json`) can grandfather
//! findings by line-insensitive key; the shipped tree keeps it empty.

pub mod baseline;
pub mod rules;
pub mod schemas;
pub mod tokenizer;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;
use tokenizer::{test_region_mask, tokenize, Comment, Tok};

/// Schema version of the `LINT.json` artifact.
pub const SCHEMA_VERSION: i64 = 1;

/// One rule violation (or suppression problem, rule `SUP`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule id: `D1`..`D6` or `SUP`.
    pub rule: String,
    /// Source file relative to the scanned root (`rust/src`).
    pub file: String,
    pub line: u32,
    pub col: u32,
    /// What fired, e.g. `unwrap` or `f64::max`.
    pub what: String,
    /// The trimmed offending source line.
    pub snippet: String,
    /// How to fix it.
    pub hint: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} src/{}:{}:{} {}\n    | {}\n    = help: {}",
            self.rule, self.file, self.line, self.col, self.what, self.snippet, self.hint
        )
    }
}

/// A parsed `// lint:allow(RULE[,RULE]): reason` comment.
///
/// A standalone comment line covers the next source line; a trailing
/// comment covers its own line. An empty reason never suppresses.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub comment_line: u32,
    pub target_line: u32,
    pub rules: Vec<String>,
    pub reason: String,
}

/// One tokenized source file plus the derived rule inputs.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Path relative to the scanned root, `/`-separated.
    pub rel: String,
    pub lines: Vec<String>,
    pub toks: Vec<Tok>,
    test_mask: Vec<bool>,
    pub sups: Vec<Suppression>,
}

impl SourceFile {
    /// Tokenize `text` and precompute test regions and suppressions.
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let lines: Vec<String> = text.split('\n').map(str::to_string).collect();
        let (toks, comments) = tokenize(text);
        let test_mask = test_region_mask(&toks, lines.len());
        let sups = parse_suppressions(&comments, &lines);
        SourceFile { rel: rel.to_string(), lines, toks, test_mask, sups }
    }

    /// Is `line` (1-based) inside a `#[cfg(test)]` item?
    pub fn in_test(&self, line: u32) -> bool {
        self.test_mask.get(line as usize).copied().unwrap_or(false)
    }

    /// Trimmed source text of `line` (1-based), for finding snippets.
    pub fn snippet(&self, line: u32) -> String {
        self.lines
            .get((line as usize).saturating_sub(1))
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    }
}

fn parse_suppressions(comments: &[Comment], lines: &[String]) -> Vec<Suppression> {
    let mut out = Vec::new();
    for c in comments {
        let body = c.text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("lint:allow(") else {
            continue;
        };
        let Some(close) = rest.find(')') else {
            continue;
        };
        let rules: Vec<String> = rest[..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let reason = rest[close + 1..].trim_start_matches(':').trim().to_string();
        let standalone = lines
            .get((c.line as usize).saturating_sub(1))
            .map(|l| l.trim_start().starts_with("//"))
            .unwrap_or(false);
        let target_line = if standalone { c.line + 1 } else { c.line };
        out.push(Suppression { comment_line: c.line, target_line, rules, reason });
    }
    out
}

/// Analyzer configuration.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Directory to scan recursively for `*.rs` (normally `rust/src`).
    pub root: PathBuf,
    /// Baseline path; `None` disables baselining entirely.
    pub baseline: Option<PathBuf>,
}

impl Default for LintConfig {
    fn default() -> Self {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        LintConfig {
            root: manifest.join("src"),
            baseline: Some(manifest.join("lint").join("baseline.json")),
        }
    }
}

/// Result of a lint run.
#[derive(Debug, Clone)]
pub struct LintReport {
    pub files_scanned: usize,
    /// Non-baselined findings, sorted by (file, line, col, rule).
    pub findings: Vec<Finding>,
    /// Findings absorbed by the baseline.
    pub baselined: usize,
}

/// Recursively load and tokenize every `*.rs` under `root`, sorted by path
/// so the scan order (and therefore the report) is deterministic.
pub fn load_sources(root: &Path) -> Result<Vec<SourceFile>> {
    fn walk(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
        let rd = std::fs::read_dir(dir)
            .with_context(|| format!("reading directory {}", dir.display()))?;
        let mut entries: Vec<PathBuf> = Vec::new();
        for e in rd {
            entries.push(e.with_context(|| format!("listing {}", dir.display()))?.path());
        }
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, root, out)?;
            } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                let text = std::fs::read_to_string(&p)
                    .with_context(|| format!("reading {}", p.display()))?;
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push(SourceFile::parse(&rel, &text));
            }
        }
        Ok(())
    }
    ensure!(root.is_dir(), "lint root {} is not a directory", root.display());
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

/// Run every rule over `files` and apply inline suppressions. Returns the
/// surviving findings (including `SUP` findings for bad suppressions),
/// sorted by position.
pub fn analyze(files: &[SourceFile]) -> Vec<Finding> {
    let mut all = Vec::new();
    for f in files {
        all.extend(rules::token_rules(f));
    }
    all.extend(rules::schema_discipline(files, schemas::SCHEMAS, schemas::REGISTRY_FILE));
    let variants: Vec<String> =
        crate::obs::Counter::ALL.iter().map(|c| format!("{c:?}")).collect();
    all.extend(rules::counter_coverage(files, &variants, "obs/mod.rs"));
    all.extend(rules::event_kinds(files, crate::obs::KNOWN_KINDS));
    apply_suppressions(files, all)
}

/// Drop findings covered by a reasoned `lint:allow`; surface unused or
/// reason-less suppressions as `SUP` findings.
pub fn apply_suppressions(files: &[SourceFile], found: Vec<Finding>) -> Vec<Finding> {
    let mut used: std::collections::BTreeSet<(String, u32)> = std::collections::BTreeSet::new();
    let mut out = Vec::new();
    for f in found {
        let sup = files.iter().find(|sf| sf.rel == f.file).and_then(|sf| {
            sf.sups.iter().find(|s| {
                s.target_line == f.line
                    && s.rules.iter().any(|r| r == &f.rule)
                    && !s.reason.is_empty()
            })
        });
        match sup {
            Some(s) => {
                used.insert((f.file.clone(), s.comment_line));
            }
            None => out.push(f),
        }
    }
    for sf in files {
        for s in &sf.sups {
            if used.contains(&(sf.rel.clone(), s.comment_line)) {
                continue;
            }
            let what = if s.reason.is_empty() {
                format!("lint:allow({}) without a `: reason`", s.rules.join(","))
            } else {
                format!("unused lint:allow({})", s.rules.join(","))
            };
            out.push(Finding {
                rule: "SUP".into(),
                file: sf.rel.clone(),
                line: s.comment_line,
                col: 1,
                what,
                snippet: sf.snippet(s.comment_line),
                hint: rules::hint("SUP").to_string(),
            });
        }
    }
    out.sort_by(|a, b| {
        (&a.file, a.line, a.col, &a.rule).cmp(&(&b.file, b.line, b.col, &b.rule))
    });
    out
}

/// Full lint run: load, analyze, baseline-filter, and record in `obs`.
pub fn run(cfg: &LintConfig) -> Result<LintReport> {
    let files = load_sources(&cfg.root)?;
    let raw = analyze(&files);
    let bl = match &cfg.baseline {
        Some(p) => baseline::Baseline::load(p)?,
        None => baseline::Baseline::default(),
    };
    let (findings, baselined) = bl.apply(raw);
    crate::obs::bump(crate::obs::Counter::LintRuns, 1);
    if crate::obs::enabled() {
        crate::obs::emit(
            "lint",
            "run",
            &[
                ("files", files.len().into()),
                ("findings", findings.len().into()),
                ("baselined", baselined.into()),
            ],
        );
    }
    Ok(LintReport { files_scanned: files.len(), findings, baselined })
}

/// Serialize a report as the `LINT.json` artifact (schema v1).
pub fn report_json(r: &LintReport) -> Json {
    let findings: Vec<Json> = r
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("rule", Json::from(f.rule.as_str())),
                ("file", Json::from(f.file.as_str())),
                ("line", Json::from(i64::from(f.line))),
                ("col", Json::from(i64::from(f.col))),
                ("what", Json::from(f.what.as_str())),
                ("snippet", Json::from(f.snippet.as_str())),
                ("hint", Json::from(f.hint.as_str())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("v", Json::from(SCHEMA_VERSION)),
        ("files_scanned", Json::from(r.files_scanned)),
        ("baselined", Json::from(r.baselined)),
        ("findings", Json::Array(findings)),
    ])
}

/// Validate a `LINT.json` document against schema v1.
pub fn validate_json(j: &Json) -> Result<()> {
    ensure!(
        j.get("v").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "LINT.json schema version mismatch (this validator understands v{SCHEMA_VERSION})"
    );
    ensure!(
        j.get("files_scanned").and_then(Json::as_i64).unwrap_or(-1) >= 0,
        "LINT.json has no files_scanned count"
    );
    let findings = j
        .get("findings")
        .and_then(Json::as_array)
        .context("LINT.json has no findings array")?;
    for (i, f) in findings.iter().enumerate() {
        for key in ["rule", "file", "what"] {
            ensure!(
                f.get(key).and_then(Json::as_str).is_some(),
                "LINT.json finding #{i} lacks string field {key}"
            );
        }
        ensure!(
            f.get("line").and_then(Json::as_i64).is_some(),
            "LINT.json finding #{i} lacks a line number"
        );
    }
    Ok(())
}

/// Read and validate a `LINT.json` artifact from disk.
pub fn validate_file(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    validate_json(&j)?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::schemas::SchemaEntry;

    /// Run the token rules + suppression pass over one fixture snippet.
    fn scan(src: &str) -> Vec<Finding> {
        let f = SourceFile::parse("fixture.rs", src);
        let found = rules::token_rules(&f);
        apply_suppressions(std::slice::from_ref(&f), found)
    }

    fn rules_of(found: &[Finding]) -> Vec<&str> {
        found.iter().map(|f| f.rule.as_str()).collect()
    }

    #[test]
    fn d1_fires_suppresses_and_flags_unused() {
        let fired = scan("let m = xs.iter().fold(f64::NEG_INFINITY, f64::max);\n");
        assert_eq!(rules_of(&fired), ["D1"]);
        assert_eq!(fired[0].what, "f64::max");
        assert_eq!((fired[0].line, fired[0].col), (1, 43));

        let sorted = scan("v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n");
        assert!(rules_of(&sorted).contains(&"D1"));

        // `fn partial_cmp` (trait impl) is the legitimate spelling.
        assert!(scan("fn partial_cmp(&self, o: &Self) -> Option<Ordering> { todo() }\n")
            .is_empty());

        let ok = scan(
            "let m = xs.fold(f64::NEG_INFINITY, f64::max); // lint:allow(D1): fixture\n",
        );
        assert!(ok.is_empty(), "{ok:?}");

        let unused = scan("// lint:allow(D1): nothing here fires\nlet x = 1;\n");
        assert_eq!(rules_of(&unused), ["SUP"]);
    }

    #[test]
    fn d2_respects_module_allowlist_and_tests() {
        let fired = scan("let t0 = std::time::Instant::now();\n");
        assert_eq!(rules_of(&fired), ["D2"]);

        let sys = scan("let t = SystemTime::now();\nlet p = available_parallelism();\n");
        assert_eq!(rules_of(&sys), ["D2", "D2"]);

        // Allowed module prefix: same source, no finding.
        let f = SourceFile::parse("obs/mod.rs", "let t0 = std::time::Instant::now();\n");
        assert!(rules::token_rules(&f).is_empty());

        // #[cfg(test)] region: no finding.
        let t = scan("#[cfg(test)]\nmod tests {\n  fn t() { let x = Instant::now(); }\n}\n");
        assert!(t.is_empty(), "{t:?}");

        let sup = scan("// lint:allow(D2): fixture reason\nlet t0 = Instant::now();\n");
        assert!(sup.is_empty(), "{sup:?}");
    }

    #[test]
    fn d3_flags_entropy_and_hash_containers() {
        let fired = scan("let mut rng = rand::thread_rng();\n");
        assert_eq!(rules_of(&fired), ["D3"]);

        let hm = scan("use std::collections::HashMap;\n");
        assert_eq!(rules_of(&hm), ["D3"]);

        // BTreeMap is the sanctioned container.
        assert!(scan("use std::collections::BTreeMap;\n").is_empty());

        // HashMap in tests is fine; from_entropy is banned even there.
        let t = scan("#[cfg(test)]\nmod tests {\n  fn t() { let m: HashMap<u8, u8> = x(); }\n}\n");
        assert!(t.is_empty(), "{t:?}");
        let e = scan("#[cfg(test)]\nmod tests {\n  fn t() { let r = Rng::from_entropy(); }\n}\n");
        assert_eq!(rules_of(&e), ["D3"]);

        let sup = scan("let m = HashMap::new(); // lint:allow(D3): fixture reason\n");
        assert!(sup.is_empty(), "{sup:?}");
    }

    #[test]
    fn d4_bans_panics_in_library_code_only() {
        let fired = scan("let v = maybe().unwrap();\nlet w = maybe().expect(\"m\");\npanic!(\"boom\");\n");
        assert_eq!(rules_of(&fired), ["D4", "D4", "D4"]);

        // unwrap_or / unwrap_or_else are fine (different identifier).
        assert!(scan("let v = maybe().unwrap_or(0).min(maybe2().unwrap_or_else(z));\n")
            .is_empty());

        // main.rs and testkit/ are exempt wholesale.
        for rel in ["main.rs", "testkit/mod.rs"] {
            let f = SourceFile::parse(rel, "let v = maybe().unwrap();\n");
            assert!(rules::token_rules(&f).is_empty(), "{rel} should be D4-exempt");
        }

        // The word unwrap inside strings/comments never fires.
        assert!(scan("// unwrap here\nlet s = \"call .unwrap() later\";\n").is_empty());

        let t = scan("#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); panic!(\"t\"); }\n}\n");
        assert!(t.is_empty(), "{t:?}");

        let sup = scan("let v = maybe().unwrap(); // lint:allow(D4): fixture reason\n");
        assert!(sup.is_empty(), "{sup:?}");
    }

    #[test]
    fn suppression_without_reason_is_a_finding() {
        let found = scan("let v = maybe().unwrap(); // lint:allow(D4)\n");
        // The violation still fires AND the bare allow is flagged.
        assert_eq!(rules_of(&found), ["D4", "SUP"]);
        assert!(found[1].what.contains("without a `: reason`"));
    }

    #[test]
    fn suppression_for_wrong_rule_does_not_mask() {
        let found = scan("let v = maybe().unwrap(); // lint:allow(D1): wrong rule\n");
        assert_eq!(rules_of(&found), ["D4", "SUP"]);
    }

    #[test]
    fn d5_schema_discipline_fixtures() {
        let reg_src = SourceFile::parse(
            "lint/schemas.rs",
            "pub const SCHEMAS: X = [(\"X.json\", \"x.rs\"), (\"GONE.json\", \"gone.rs\")];\n",
        );
        let x = SourceFile::parse("x.rs", "pub const SCHEMA_VERSION: i64 = 3;\n");
        let unreg =
            SourceFile::parse("y.rs", "pub fn validate_json(j: &Json) -> Result<()> { o() }\n");
        let files = vec![reg_src, x, unreg];

        let registry = [
            // Version literal (3) disagrees with the registered version (2),
            // and the live constant (4) disagrees with both.
            SchemaEntry { artifact: "X.json", file: "x.rs", version: 2, current: 4 },
            // Stale entry: no such file in the corpus.
            SchemaEntry { artifact: "GONE.json", file: "gone.rs", version: 1, current: 1 },
        ];
        let found = rules::schema_discipline(&files, &registry, "lint/schemas.rs");
        let whats: Vec<&str> = found.iter().map(|f| f.what.as_str()).collect();
        assert_eq!(found.len(), 4, "{found:?}");
        assert!(whats.iter().any(|w| w.contains("not registered")));
        assert!(whats.iter().any(|w| w.contains("registers v2")));
        assert!(whats.iter().any(|w| w.contains("stale registry entry")));
        assert!(whats.iter().any(|w| w.contains("crate emits v4")));

        // A consistent corpus is clean.
        let ok_reg = [SchemaEntry { artifact: "X.json", file: "x.rs", version: 3, current: 3 }];
        let clean = rules::schema_discipline(&files[..2], &ok_reg, "lint/schemas.rs");
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn d6_counter_coverage_fixtures() {
        let defs = SourceFile::parse(
            "obs/mod.rs",
            "define_counters! { Hits => hits: \"x.hits\", Misses => misses: \"x.misses\" }\n",
        );
        let user = SourceFile::parse("a.rs", "bump(Counter::Hits, 1);\n");
        let variants = vec!["Hits".to_string(), "Misses".to_string()];
        let found = rules::counter_coverage(&[defs.clone(), user], &variants, "obs/mod.rs");
        assert_eq!(found.len(), 1, "{found:?}");
        assert!(found[0].what.contains("Misses"));
        assert_eq!(found[0].file, "obs/mod.rs");

        // Test-only bumps do not count as coverage.
        let test_user = SourceFile::parse(
            "b.rs",
            "#[cfg(test)]\nmod tests {\n fn t() { bump(Counter::Misses, 1); }\n}\n",
        );
        let found = rules::counter_coverage(&[defs, test_user], &variants, "obs/mod.rs");
        assert_eq!(found.len(), 2, "{found:?}");
    }

    #[test]
    fn d6_event_kind_fixtures() {
        let known = [("mc", "shard")];
        let ok = SourceFile::parse("a.rs", "emit(\"mc\", \"shard\", &[]);\n");
        assert!(rules::event_kinds(&[ok], &known).is_empty());

        let bad = SourceFile::parse("a.rs", "emit(\"mc\", \"bogus\", &[]);\n");
        let found = rules::event_kinds(&[bad], &known);
        assert_eq!(found.len(), 1);
        assert!(found[0].what.contains("mc/bogus"));

        // Non-literal kinds and the generic span kind are out of scope.
        let dynkind = SourceFile::parse("a.rs", "emit(\"mc\", action.name(), &[]);\n");
        assert!(rules::event_kinds(&[dynkind], &known).is_empty());
        let span = SourceFile::parse("a.rs", "emit(sub, \"span\", &[]);\n");
        assert!(rules::event_kinds(&[span], &known).is_empty());
    }

    #[test]
    fn report_round_trips_through_schema_validation() {
        let r = LintReport {
            files_scanned: 2,
            findings: vec![Finding {
                rule: "D4".into(),
                file: "a.rs".into(),
                line: 10,
                col: 5,
                what: "unwrap".into(),
                snippet: "x.unwrap();".into(),
                hint: "return a named error".into(),
            }],
            baselined: 1,
        };
        let j = report_json(&r);
        validate_json(&j).unwrap();
        let parsed = Json::parse(&j.to_string()).unwrap();
        validate_json(&parsed).unwrap();
        assert_eq!(parsed.get("files_scanned").and_then(Json::as_i64), Some(2));

        // A wrong version must be rejected.
        let bad = Json::obj(vec![("v", Json::from(99i64)), ("findings", Json::Array(vec![]))]);
        assert!(validate_json(&bad).is_err());
    }

    #[test]
    fn baseline_filters_by_line_insensitive_key() {
        let src = "let a = maybe().unwrap();\n";
        let f = SourceFile::parse("fixture.rs", src);
        let found = apply_suppressions(std::slice::from_ref(&f), rules::token_rules(&f));
        assert_eq!(found.len(), 1);
        let bl = baseline::Baseline::from_findings(&found);
        let (kept, absorbed) = bl.apply(found);
        assert_eq!((kept.len(), absorbed), (0, 1));
    }
}
