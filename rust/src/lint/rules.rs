//! The six determinism/hygiene rules (D1-D6).
//!
//! Each rule is a pure function from tokenized sources to [`Finding`]s so
//! the unit tests can run every rule against embedded fixture snippets.
//! Banned names are spelled as string literals throughout this file, which
//! keeps the analyzer from flagging its own source (string bodies are
//! opaque to the tokenizer).

use super::{Finding, SourceFile};
use crate::lint::schemas::SchemaEntry;
use crate::lint::tokenizer::{Tok, TokKind};

/// Directory prefixes where wall-clock/thread-count probes are legitimate
/// (live paths and measurement harnesses).
pub const D2_ALLOWED: &[&str] = &["obs/", "coordinator/", "worker/", "benchkit/"];

/// Files exempt from the D4 panic ban: the CLI binary may crash loudly and
/// the property-test kit is test-only by construction.
pub const D4_EXEMPT_FILES: &[&str] = &["main.rs"];
pub const D4_EXEMPT_PREFIXES: &[&str] = &["testkit/"];

pub(crate) fn hint(rule: &str) -> &'static str {
    match rule {
        "D1" => "rank with f64::total_cmp (NaN-total order); see README \u{00a7}Static analysis",
        "D2" => "wall-clock/parallelism probes live in obs/coordinator/worker/benchkit; \
                 thread values in as parameters",
        "D3" => "use seeded substreams and BTreeMap (or sort explicitly before emitting)",
        "D4" => "return a named error (anyhow) instead of panicking in library code",
        "D5" => "register the schema in lint::schemas::SCHEMAS",
        "D6" => "bump the counter in live code or add the event kind to obs::KNOWN_KINDS",
        _ => "remove the stale lint:allow or add the missing `: reason`",
    }
}

fn mk(rule: &str, f: &SourceFile, line: u32, col: u32, what: String) -> Finding {
    Finding {
        rule: rule.to_string(),
        file: f.rel.clone(),
        line,
        col,
        what,
        snippet: f.snippet(line),
        hint: hint(rule).to_string(),
    }
}

fn tok_at<'a>(toks: &'a [Tok], ix: usize) -> Option<&'a Tok> {
    toks.get(ix)
}

fn text_at<'a>(toks: &'a [Tok], ix: usize) -> &'a str {
    toks.get(ix).map(|t| t.text.as_str()).unwrap_or("")
}

/// D1-D4: the per-token rules. One pass over the token stream.
pub fn token_rules(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let d2_applies = !D2_ALLOWED.iter().any(|p| f.rel.starts_with(p));
    let d4_applies = !D4_EXEMPT_FILES.contains(&f.rel.as_str())
        && !D4_EXEMPT_PREFIXES.iter().any(|p| f.rel.starts_with(p));
    let toks = &f.toks;
    for ix in 0..toks.len() {
        let t = &toks[ix];
        if t.kind != TokKind::Ident {
            continue;
        }
        let in_test = f.in_test(t.line);
        let prev = if ix > 0 { text_at(toks, ix - 1) } else { "" };
        let next = text_at(toks, ix + 1);

        // D1: partial float ordering in ranking/argmin code. `fn partial_cmp`
        // (a trait impl definition) is the one legitimate spelling.
        if t.text == "partial_cmp" && prev != "fn" {
            out.push(mk("D1", f, t.line, t.col, "partial_cmp".into()));
        }
        if t.text == "f64" && next == ":" && text_at(toks, ix + 2) == ":" {
            let m = text_at(toks, ix + 3);
            if m == "max" || m == "min" {
                out.push(mk("D1", f, t.line, t.col, format!("f64::{m}")));
            }
        }

        // D2: wall-clock and machine-shape probes outside live modules.
        if d2_applies && !in_test {
            if t.text == "Instant"
                && next == ":"
                && text_at(toks, ix + 2) == ":"
                && text_at(toks, ix + 3) == "now"
            {
                out.push(mk("D2", f, t.line, t.col, "Instant::now".into()));
            }
            if t.text == "SystemTime" {
                out.push(mk("D2", f, t.line, t.col, "SystemTime".into()));
            }
            if t.text == "available_parallelism" {
                out.push(mk("D2", f, t.line, t.col, "available_parallelism".into()));
            }
        }

        // D3: OS entropy anywhere; hash-order containers outside tests
        // (iteration order must never feed an artifact or canonical key).
        if t.text == "thread_rng" || t.text == "from_entropy" {
            out.push(mk("D3", f, t.line, t.col, t.text.clone()));
        }
        if (t.text == "HashMap" || t.text == "HashSet") && !in_test {
            out.push(mk("D3", f, t.line, t.col, t.text.clone()));
        }

        // D4: named-error discipline in library code.
        if d4_applies && !in_test {
            if (t.text == "unwrap" || t.text == "expect") && prev == "." && next == "(" {
                out.push(mk("D4", f, t.line, t.col, t.text.clone()));
            }
            if t.text == "panic" && next == "!" {
                out.push(mk("D4", f, t.line, t.col, "panic!".into()));
            }
        }
    }
    out
}

/// Parse the integer literal of a `const SCHEMA_VERSION: … = <n>;` item, if
/// the file declares one.
fn schema_version_literal(f: &SourceFile) -> Option<(i64, u32, u32)> {
    let toks = &f.toks;
    for ix in 0..toks.len() {
        if toks[ix].text != "const" || text_at(toks, ix + 1) != "SCHEMA_VERSION" {
            continue;
        }
        // The numeric literal sits within the next few tokens (`: i64 = 1 ;`).
        for j in ix + 2..(ix + 8).min(toks.len()) {
            let t = &toks[j];
            if t.kind == TokKind::Num {
                let digits: String =
                    t.text.chars().take_while(|c| c.is_ascii_digit()).collect();
                if let Ok(v) = digits.parse::<i64>() {
                    return Some((v, t.line, t.col));
                }
            }
            if t.text == ";" {
                break;
            }
        }
    }
    None
}

/// Does the file define a validator entry point (`fn validate_file` /
/// `fn validate_json`)? Returns the definition site.
fn validator_def(f: &SourceFile) -> Option<(u32, u32)> {
    let toks = &f.toks;
    for ix in 0..toks.len() {
        if toks[ix].text == "fn" {
            let nm = text_at(toks, ix + 1);
            if nm == "validate_file" || nm == "validate_json" {
                let t = &toks[ix];
                return Some((t.line, t.col));
            }
        }
    }
    None
}

/// Locate a string literal token equal to `needle` in `f` (for anchoring
/// registry findings at the offending entry).
fn find_str_literal(f: &SourceFile, needle: &str) -> (u32, u32) {
    f.toks
        .iter()
        .find(|t| t.kind == TokKind::Str && t.text == needle)
        .map(|t| (t.line, t.col))
        .unwrap_or((1, 1))
}

/// D5: schema discipline. Every file that declares a `SCHEMA_VERSION` or a
/// validator must be registered; registered versions must match both the
/// source literal and the live constant; stale registry entries are flagged.
pub fn schema_discipline(
    files: &[SourceFile],
    registry: &[SchemaEntry],
    registry_file: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let reg_src = files.iter().find(|f| f.rel == registry_file);
    for f in files {
        if f.rel == registry_file {
            continue;
        }
        let ver = schema_version_literal(f);
        let val = validator_def(f);
        let (line, col) = match (ver, val) {
            (Some((_, l, c)), _) => (l, c),
            (None, Some((l, c))) => (l, c),
            (None, None) => continue,
        };
        match registry.iter().find(|e| e.file == f.rel) {
            None => out.push(mk(
                "D5",
                f,
                line,
                col,
                "schema site not registered".into(),
            )),
            Some(e) => {
                if let Some((v, vl, vc)) = ver {
                    if v != e.version {
                        out.push(mk(
                            "D5",
                            f,
                            vl,
                            vc,
                            format!(
                                "SCHEMA_VERSION is {v} but lint::schemas registers v{}",
                                e.version
                            ),
                        ));
                    }
                }
            }
        }
    }
    for e in registry {
        let target = files.iter().find(|f| f.rel == e.file);
        let live = target
            .map(|f| schema_version_literal(f).is_some() || validator_def(f).is_some())
            .unwrap_or(false);
        if !live {
            if let Some(rf) = reg_src {
                let (l, c) = find_str_literal(rf, e.file);
                out.push(mk(
                    "D5",
                    rf,
                    l,
                    c,
                    format!("stale registry entry: {} has no schema site", e.file),
                ));
            }
        }
        if e.version != e.current {
            if let Some(rf) = reg_src {
                let (l, c) = find_str_literal(rf, e.artifact);
                out.push(mk(
                    "D5",
                    rf,
                    l,
                    c,
                    format!(
                        "{}: registered v{} but the crate emits v{}",
                        e.artifact, e.version, e.current
                    ),
                ));
            }
        }
    }
    out
}

/// D6 (part 1): every counter variant must be bumped by live (non-test)
/// code somewhere outside the defining file.
pub fn counter_coverage(
    files: &[SourceFile],
    variants: &[String],
    counters_file: &str,
) -> Vec<Finding> {
    let mut used: Vec<bool> = vec![false; variants.len()];
    for f in files {
        if f.rel == counters_file {
            continue;
        }
        let toks = &f.toks;
        for ix in 0..toks.len() {
            let t = &toks[ix];
            if t.kind != TokKind::Ident
                || t.text != "Counter"
                || text_at(toks, ix + 1) != ":"
                || text_at(toks, ix + 2) != ":"
                || f.in_test(t.line)
            {
                continue;
            }
            let v = text_at(toks, ix + 3);
            if let Some(k) = variants.iter().position(|x| x == v) {
                used[k] = true;
            }
        }
    }
    let mut out = Vec::new();
    let def = files.iter().find(|f| f.rel == counters_file);
    for (k, v) in variants.iter().enumerate() {
        if used[k] {
            continue;
        }
        let (file, line, col, snippet) = match def {
            Some(f) => {
                let (l, c) = f
                    .toks
                    .iter()
                    .find(|t| t.kind == TokKind::Ident && t.text == *v)
                    .map(|t| (t.line, t.col))
                    .unwrap_or((1, 1));
                (f.rel.clone(), l, c, f.snippet(l))
            }
            None => (counters_file.to_string(), 1, 1, String::new()),
        };
        out.push(Finding {
            rule: "D6".into(),
            file,
            line,
            col,
            what: format!("counter {v} is never bumped by live code"),
            snippet,
            hint: hint("D6").to_string(),
        });
    }
    out
}

/// D6 (part 2): every `emit("<sub>", "<kind>", …)` call with two literal
/// arguments must name a registered event kind. Non-literal kinds (e.g.
/// `action.name()`) and the generic `"span"` kind are out of scope here —
/// the summarizer handles spans structurally.
pub fn event_kinds(files: &[SourceFile], known: &[(&str, &str)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        let toks = &f.toks;
        for ix in 0..toks.len() {
            let t = &toks[ix];
            if t.kind != TokKind::Ident || t.text != "emit" || text_at(toks, ix + 1) != "(" {
                continue;
            }
            let (sub, kind) = match (tok_at(toks, ix + 2), tok_at(toks, ix + 4)) {
                (Some(s), Some(k))
                    if s.kind == TokKind::Str
                        && k.kind == TokKind::Str
                        && text_at(toks, ix + 3) == "," =>
                {
                    (s, k)
                }
                _ => continue,
            };
            if f.in_test(t.line) || kind.text == "span" {
                continue;
            }
            let pair = (sub.text.as_str(), kind.text.as_str());
            if !known.iter().any(|k| *k == pair) {
                out.push(mk(
                    "D6",
                    f,
                    kind.line,
                    kind.col,
                    format!("event kind {}/{} not in obs::KNOWN_KINDS", pair.0, pair.1),
                ));
            }
        }
    }
    out
}
