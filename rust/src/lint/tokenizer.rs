//! A minimal, line/column-aware Rust tokenizer for the `lint` analyzer.
//!
//! This is deliberately *not* a parser: the determinism rules (D1-D6) only
//! need a token stream that is safe against comments, string literals, raw
//! strings, char literals, and lifetimes, so that e.g. the word
//! "partial_cmp" inside a doc comment or an error message never fires a
//! rule. It handles:
//!
//! - line (`//`) and nested block (`/* .. /* .. */ .. */`) comments,
//! - regular strings with escapes, raw strings `r"…"` / `r#"…"#` and the
//!   byte variants `b"…"` / `br#"…"#`,
//! - char literals vs lifetimes (`'x'` vs `'static`),
//! - identifiers, numeric literals, and single-byte punctuation
//!   (`::` is reported as two `:` tokens).
//!
//! The tokenizer never panics: it works on raw bytes and decodes token text
//! lossily, and columns count bytes (the tree is ASCII in practice).

/// Token classes the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// String literal (text is the raw body, escapes untouched).
    Str,
    /// Numeric literal (suffix included, e.g. `1.5f64`).
    Num,
    /// Single punctuation byte.
    Punct,
    /// Lifetime such as `'a` (quote included in the text).
    Lifetime,
}

/// One token with its 1-based source position.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A `//` comment captured for suppression parsing (text includes `//`).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor<'_> {
    fn adv(&mut self, k: usize) {
        for _ in 0..k {
            if self.i >= self.b.len() {
                break;
            }
            if self.b[self.i] == b'\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
            self.i += 1;
        }
    }

    fn peek(&self, off: usize) -> u8 {
        *self.b.get(self.i + off).unwrap_or(&0)
    }

    fn starts(&self, s: &[u8]) -> bool {
        self.b.len() >= self.i + s.len() && &self.b[self.i..self.i + s.len()] == s
    }
}

fn find_sub(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() || from > hay.len() - needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&k| &hay[k..k + needle.len()] == needle)
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn lossy(bytes: &[u8]) -> String {
    String::from_utf8_lossy(bytes).into_owned()
}

/// Tokenize `text`, returning the token stream and every line comment.
pub fn tokenize(text: &str) -> (Vec<Tok>, Vec<Comment>) {
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let b = text.as_bytes();
    let n = b.len();
    let mut cur = Cursor { b, i: 0, line: 1, col: 1 };

    while cur.i < n {
        let c = b[cur.i];
        if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
            cur.adv(1);
            continue;
        }
        // Line comment.
        if cur.starts(b"//") {
            let j = find_sub(b, b"\n", cur.i).unwrap_or(n);
            comments.push(Comment { line: cur.line, text: lossy(&b[cur.i..j]) });
            cur.adv(j - cur.i);
            continue;
        }
        // Block comment (nested).
        if cur.starts(b"/*") {
            let mut depth = 1usize;
            let mut j = cur.i + 2;
            while j < n && depth > 0 {
                if b[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if b[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            cur.adv(j - cur.i);
            continue;
        }
        // Raw string (r"…", r#"…"#) and byte variants; the prefix must lead
        // straight into `#` or `"` or we fall through to the ident branch.
        if c == b'r' || c == b'b' {
            let mut k = cur.i;
            while k < n && (b[k] == b'r' || b[k] == b'b') {
                k += 1;
            }
            let pref = &b[cur.i..k];
            if pref.len() <= 2
                && pref.contains(&b'r')
                && k < n
                && (b[k] == b'#' || b[k] == b'"')
            {
                let mut hashes = 0usize;
                while k < n && b[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == b'"' {
                    let mut close = vec![b'"'];
                    close.resize(hashes + 1, b'#');
                    let j = find_sub(b, &close, k + 1).unwrap_or(n);
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: lossy(&b[k + 1..j.min(n)]),
                        line: cur.line,
                        col: cur.col,
                    });
                    cur.adv((j + close.len()).min(n) - cur.i);
                    continue;
                }
            }
        }
        // Regular (or byte) string.
        if c == b'"' {
            let (line, col) = (cur.line, cur.col);
            let mut j = cur.i + 1;
            let mut body = Vec::new();
            while j < n {
                if b[j] == b'\\' {
                    body.extend_from_slice(&b[j..n.min(j + 2)]);
                    j += 2;
                } else if b[j] == b'"' {
                    break;
                } else {
                    body.push(b[j]);
                    j += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Str, text: lossy(&body), line, col });
            cur.adv((j + 1).min(n) - cur.i);
            continue;
        }
        // Char literal or lifetime.
        if c == b'\'' {
            let c1 = cur.peek(1);
            let c2 = cur.peek(2);
            if is_ident_cont(c1) && c1 != b'\\' && c2 != b'\'' {
                // Lifetime: 'a, 'static (no closing quote right after).
                let mut j = cur.i + 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: lossy(&b[cur.i..j]),
                    line: cur.line,
                    col: cur.col,
                });
                cur.adv(j - cur.i);
                continue;
            }
            // Char literal: 'x', '\n', '\'' — skipped entirely.
            let mut j = cur.i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'\'' {
                    break;
                } else {
                    j += 1;
                }
            }
            cur.adv((j + 1).min(n) - cur.i);
            continue;
        }
        // Identifier.
        if is_ident_start(c) {
            let mut j = cur.i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: lossy(&b[cur.i..j]),
                line: cur.line,
                col: cur.col,
            });
            cur.adv(j - cur.i);
            continue;
        }
        // Number (suffixes and `1..` over-consumption are fine for linting).
        if c.is_ascii_digit() {
            let mut j = cur.i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'.' || b[j] == b'_') {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: lossy(&b[cur.i..j]),
                line: cur.line,
                col: cur.col,
            });
            cur.adv(j - cur.i);
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: lossy(&b[cur.i..cur.i + 1]),
            line: cur.line,
            col: cur.col,
        });
        cur.adv(1);
    }
    (toks, comments)
}

/// Mark every line covered by a `#[cfg(test)]`-attributed item.
///
/// The attribute token pattern `# [ cfg ( test ) ]` is matched, then the
/// following item is delimited by brace matching (or the first `;` at
/// depth 0 for `mod tests;`-style declarations). Returns a 1-based mask
/// sized `nlines + 2` so rules can index by line directly.
pub fn test_region_mask(toks: &[Tok], nlines: usize) -> Vec<bool> {
    let mut mask = vec![false; nlines + 2];
    let pat = ["#", "[", "cfg", "(", "test", ")", "]"];
    let m = toks.len();
    for ix in 0..m.saturating_sub(pat.len() - 1) {
        let hit = pat
            .iter()
            .enumerate()
            .all(|(k, p)| toks.get(ix + k).map(|t| t.text == *p).unwrap_or(false));
        if !hit {
            continue;
        }
        let start_line = toks[ix].line as usize;
        let mut depth = 0i64;
        let mut end_line = nlines;
        let mut j = ix + pat.len();
        while j < m {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    ";" if depth == 0 => {
                        end_line = t.line as usize;
                        break;
                    }
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            end_line = t.line as usize;
                            break;
                        }
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        for ln in start_line..=end_line.min(nlines) {
            mask[ln] = true;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = r##"
            // partial_cmp in a comment
            /* nested /* unwrap */ block */
            let a = "partial_cmp inside a string";
            let b = r#"raw unwrap body"#;
            let c = 'x';
            let d: &'static str = "s";
            real_ident(a.total_cmp(&b));
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"partial_cmp".to_string()));
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(ids.contains(&"total_cmp".to_string()));
        assert!(ids.contains(&"real_ident".to_string()));
    }

    #[test]
    fn positions_are_line_col() {
        let (toks, comments) = tokenize("let x = 1;\n  foo();\n// tail\n");
        let foo = toks.iter().find(|t| t.text == "foo").unwrap();
        assert_eq!((foo.line, foo.col), (2, 3));
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].line, 3);
        assert_eq!(comments[0].text, "// tail");
    }

    #[test]
    fn raw_string_prefixes_do_not_eat_identifiers() {
        let ids = idents("let broke = rb_x; for r in 0..2 { br(r); }");
        assert!(ids.contains(&"rb_x".to_string()));
        assert!(ids.contains(&"br".to_string()));
        assert!(ids.contains(&"r".to_string()));
    }

    #[test]
    fn escaped_quote_in_string() {
        let (toks, _) = tokenize(r#"let s = "a\"b"; tail();"#);
        assert!(toks.iter().any(|t| t.text == "tail"));
        let s = toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, "a\\\"b");
    }

    #[test]
    fn cfg_test_regions_are_masked() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n  fn t() { y.unwrap(); }\n}\n";
        let (toks, _) = tokenize(src);
        let nlines = src.lines().count();
        let mask = test_region_mask(&toks, nlines);
        assert!(!mask[1]);
        assert!(mask[2] && mask[3] && mask[4] && mask[5]);
    }
}
