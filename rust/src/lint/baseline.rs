//! Grandfathered-findings baseline.
//!
//! The baseline is a checked-in JSON file (`rust/lint/baseline.json`)
//! listing finding keys the gate tolerates. It exists so the lint gate can
//! be zero-noise from day one even if a future rule lands before its last
//! violation is fixed; the shipped tree keeps it empty. Keys are
//! line-number-free (`rule|file|what`) so unrelated edits above a
//! grandfathered site don't churn the file; duplicate keys carry a count so
//! a *new* instance of an old violation still fails the gate.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::Finding;
use crate::util::json::Json;

/// Baseline file schema version.
pub const BASELINE_VERSION: i64 = 1;

/// Line-insensitive identity of a finding.
pub fn key(f: &Finding) -> String {
    format!("{}|{}|{}", f.rule, f.file, f.what)
}

/// Parsed baseline: finding key -> tolerated count.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    pub entries: BTreeMap<String, u64>,
}

impl Baseline {
    /// Build a baseline that grandfathers exactly `findings`.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut entries = BTreeMap::new();
        for f in findings {
            *entries.entry(key(f)).or_insert(0u64) += 1;
        }
        Baseline { entries }
    }

    /// Parse the JSON document produced by [`Baseline::to_json`].
    pub fn parse(text: &str) -> Result<Baseline> {
        let j = Json::parse(text).context("baseline is not valid JSON")?;
        ensure!(
            j.get("v").and_then(Json::as_i64) == Some(BASELINE_VERSION),
            "baseline schema version mismatch (want v{BASELINE_VERSION})"
        );
        let items = j
            .get("entries")
            .and_then(Json::as_array)
            .context("baseline has no entries array")?;
        let mut entries = BTreeMap::new();
        for it in items {
            let k = it
                .get("key")
                .and_then(Json::as_str)
                .context("baseline entry has no key")?;
            let n = it.get("count").and_then(Json::as_i64).unwrap_or(1).max(0) as u64;
            *entries.entry(k.to_string()).or_insert(0) += n;
        }
        Ok(Baseline { entries })
    }

    /// Load from disk; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<Baseline> {
        if !path.exists() {
            return Ok(Baseline::default());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading baseline {}", path.display()))?;
        Baseline::parse(&text)
    }

    /// Serialize (sorted, hence byte-stable for a given content).
    pub fn to_json(&self) -> Json {
        let items: Vec<Json> = self
            .entries
            .iter()
            .map(|(k, n)| {
                Json::obj(vec![
                    ("key", Json::from(k.as_str())),
                    ("count", Json::from(*n as i64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("v", Json::from(BASELINE_VERSION)),
            ("entries", Json::Array(items)),
        ])
    }

    /// Split findings into (kept, grandfathered-count). Each baseline entry
    /// absorbs at most `count` findings with its key.
    pub fn apply(&self, findings: Vec<Finding>) -> (Vec<Finding>, usize) {
        let mut budget = self.entries.clone();
        let mut kept = Vec::new();
        let mut absorbed = 0usize;
        for f in findings {
            match budget.get_mut(&key(&f)) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    absorbed += 1;
                }
                _ => kept.push(f),
            }
        }
        (kept, absorbed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(rule: &str, file: &str, line: u32, what: &str) -> Finding {
        Finding {
            rule: rule.into(),
            file: file.into(),
            line,
            col: 1,
            what: what.into(),
            snippet: String::new(),
            hint: String::new(),
        }
    }

    #[test]
    fn round_trip_and_apply() {
        let found = vec![f("D4", "a.rs", 10, "unwrap"), f("D4", "a.rs", 20, "unwrap")];
        let b = Baseline::from_findings(&found);
        let text = b.to_json().to_string();
        let b2 = Baseline::parse(&text).unwrap();
        assert_eq!(b, b2);

        // Same keys at drifted lines are absorbed…
        let later = vec![f("D4", "a.rs", 11, "unwrap"), f("D4", "a.rs", 21, "unwrap")];
        let (kept, absorbed) = b2.apply(later);
        assert_eq!((kept.len(), absorbed), (0, 2));

        // …but a third instance of the same violation is NOT.
        let grown = vec![
            f("D4", "a.rs", 11, "unwrap"),
            f("D4", "a.rs", 21, "unwrap"),
            f("D4", "a.rs", 31, "unwrap"),
        ];
        let (kept, absorbed) = b2.apply(grown);
        assert_eq!((kept.len(), absorbed), (1, 2));
    }

    #[test]
    fn missing_file_is_empty() {
        let b = Baseline::load(Path::new("/nonexistent/baseline.json")).unwrap();
        assert!(b.entries.is_empty());
    }
}
