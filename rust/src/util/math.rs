//! Branch-free math kernels the block samplers are built on.
//!
//! `f64::ln` lowers to a libm call, which blocks loop vectorization and
//! adds call overhead on the Monte-Carlo hot path (every Exp/SExp/Weibull
//! draw takes one logarithm). [`fast_ln`] is a pure-arithmetic
//! implementation — exponent extraction by bit manipulation plus an
//! atanh-series polynomial on the reduced mantissa — that LLVM can
//! inline and auto-vectorize over slices. Accuracy is ~2 ulp across the
//! full normal range (validated against `f64::ln` in the tests below),
//! far inside the tolerance of any statistical use in this crate.

/// Natural logarithm of a **positive normal** `f64` (the only inputs the
/// samplers produce: uniforms in `(0, 1]` and their transforms). Not
/// valid for zero, subnormals, infinities, or NaN — callers own that
/// contract. Accurate to ~2 ulp.
#[inline(always)]
pub fn fast_ln(x: f64) -> f64 {
    const LN_2: f64 = std::f64::consts::LN_2;
    // Decompose x = m · 2^e with m ∈ [1, 2), then renormalize to
    // m ∈ (√½, √2] so the series argument is small. The renormalization
    // is arithmetic (no branch) to keep the loop body vectorizable.
    let bits = x.to_bits();
    let e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    let m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000);
    let c = (m > std::f64::consts::SQRT_2) as u64 as f64;
    let m = m * (1.0 - 0.5 * c);
    let e = e as f64 + c;
    // ln(m) = 2·atanh(t) with t = (m−1)/(m+1); |t| ≤ 0.1716 so the odd
    // series truncated at t¹⁷ is exact to ~1e-16 relative.
    let t = (m - 1.0) / (m + 1.0);
    let t2 = t * t;
    let mut s = 1.0 / 17.0;
    s = s * t2 + 1.0 / 15.0;
    s = s * t2 + 1.0 / 13.0;
    s = s * t2 + 1.0 / 11.0;
    s = s * t2 + 1.0 / 9.0;
    s = s * t2 + 1.0 / 7.0;
    s = s * t2 + 1.0 / 5.0;
    s = s * t2 + 1.0 / 3.0;
    s = s * t2 + 1.0;
    e * LN_2 + 2.0 * t * s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_libm_on_uniforms() {
        let mut rng = Rng::new(9);
        for _ in 0..200_000 {
            let u = rng.f64_open0();
            let a = fast_ln(u);
            let b = u.ln();
            assert!(
                (a - b).abs() <= 1e-14 * b.abs().max(1.0),
                "u={u}: fast {a} vs libm {b}"
            );
        }
    }

    #[test]
    fn matches_libm_across_magnitudes() {
        for &x in &[
            f64::MIN_POSITIVE,
            1e-300,
            2f64.powi(-53),
            1e-10,
            0.5,
            std::f64::consts::SQRT_2,
            1.0,
            1.5,
            2.0,
            1e10,
            1e300,
        ] {
            let a = fast_ln(x);
            let b = x.ln();
            assert!(
                (a - b).abs() <= 1e-13 * b.abs().max(1.0),
                "x={x}: fast {a} vs libm {b}"
            );
        }
        assert_eq!(fast_ln(1.0), 0.0);
    }

    #[test]
    fn neg_log_of_unit_uniform_is_nonnegative() {
        // The sampler transform −ln(u), u ∈ (0, 1], must never go
        // negative (it feeds service times).
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            let u = rng.f64_open0();
            assert!(-fast_ln(u) >= 0.0, "u={u}");
        }
    }
}
