//! CSV and Markdown table emitters for experiment outputs.
//!
//! Every experiment driver produces one [`Table`] per paper figure/table,
//! written both as CSV (machine-readable, plotted elsewhere) and as a
//! Markdown table (embedded in EXPERIMENTS.md).

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-ordered table of string cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title (used as a Markdown heading).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row-major cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as CSV. (`write!` into a `String` is infallible, hence the
    /// discarded results.)
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for r in &self.rows {
            let escaped: Vec<String> = r.iter().map(|c| csv_escape(c)).collect();
            let _ = writeln!(out, "{}", escaped.join(","));
        }
        out
    }

    /// Render as a GitHub-flavored Markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ =
            writeln!(out, "|{}|", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    /// Write `<dir>/<stem>.csv` and `<dir>/<stem>.md`.
    pub fn write_to(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        Ok(())
    }

    /// Print the Markdown rendering to stdout.
    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

fn csv_escape(c: &str) -> String {
    if c.contains(',') || c.contains('"') || c.contains('\n') {
        format!("\"{}\"", c.replace('"', "\"\""))
    } else {
        c.to_string()
    }
}

/// Format an f64 with a fixed number of significant decimals for tables.
pub fn fmt_f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_markdown() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("a,b"));
        assert!(csv.contains("\"x,y\""));
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | x,y |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn write_files() {
        let dir = std::env::temp_dir().join("batchrep_table_test");
        let mut t = Table::new("T", &["x"]);
        t.row(vec!["1".into()]);
        t.write_to(&dir, "t").unwrap();
        assert!(dir.join("t.csv").exists());
        assert!(dir.join("t.md").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
