//! Small self-contained substrates: PRNG, statistics, harmonic numbers,
//! JSON, and table writers. These replace `rand`, `serde_json` and
//! friends, which are unavailable in the offline build environment.

pub mod harmonic;
pub mod json;
pub mod math;
pub mod rng;
pub mod stats;
pub mod table;

/// Monotonic wall-clock timer with ergonomic elapsed readings.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    /// Start a new timer.
    #[allow(clippy::disallowed_methods)]
    pub fn start() -> Self {
        // This is the crate's one sanctioned wall-clock primitive outside
        // the live/observability modules; results never depend on it.
        // lint:allow(D2): Timer is the explicit wall-clock primitive callers opt into
        Self { start: std::time::Instant::now() }
    }

    /// Seconds elapsed since `start`.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Nanoseconds elapsed since `start`.
    pub fn nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.nanos();
        let b = t.nanos();
        assert!(b >= a);
    }
}
