//! Streaming and batch statistics used by the simulator, the live
//! coordinator metrics, and the benchmark harness.

/// NaN-total maximum fold: `max` under [`f64::total_cmp`]. Identical to a
/// `fold(NEG_INFINITY, f64::max)` on finite inputs, but under the total
/// order a positive NaN sorts above +∞ and therefore *surfaces* as the
/// result instead of being silently swallowed the way `f64::max` does —
/// which is why the D1 lint rule bans the partial-order folds.
pub fn fold_max_total<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    xs.into_iter()
        .fold(f64::NEG_INFINITY, |a, b| if b.total_cmp(&a).is_gt() { b } else { a })
}

/// NaN-total minimum fold: the [`fold_max_total`] dual (a negative NaN
/// sorts below −∞ and surfaces).
pub fn fold_min_total<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    xs.into_iter()
        .fold(f64::INFINITY, |a, b| if b.total_cmp(&a).is_lt() { b } else { a })
}

/// Compensated (Kahan–Neumaier) running sum: adds f64 terms with an
/// error-compensation carry so long accumulations (e.g. busy
/// worker-seconds over thousands of events per trial) do not drift the
/// way a naive `+=` loop does. `sum()` folds the carry back in.
#[derive(Debug, Clone, Copy, Default)]
pub struct Kahan {
    sum: f64,
    carry: f64,
}

impl Kahan {
    /// Empty (zero) sum.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one term (Neumaier's branch: compensate whichever operand
    /// loses low-order bits).
    #[inline]
    pub fn add(&mut self, x: f64) {
        let t = self.sum + x;
        if self.sum.abs() >= x.abs() {
            self.carry += (self.sum - t) + x;
        } else {
            self.carry += (x - t) + self.sum;
        }
        self.sum = t;
    }

    /// The compensated total.
    #[inline]
    pub fn sum(&self) -> f64 {
        self.sum + self.carry
    }
}

/// Numerically stable streaming mean/variance (Welford), mergeable so
/// per-thread accumulators can be combined.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Merge another accumulator (Chan et al. parallel formula).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.stddev() / (self.n as f64).sqrt() }
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantile from a set of samples (kept in memory, sorted lazily).
/// Used where sample counts are modest (≤ a few million f64s).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    /// Empty sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// With pre-allocated capacity.
    pub fn with_capacity(n: usize) -> Self {
        Self { xs: Vec::with_capacity(n), sorted: false }
    }

    /// Add one sample.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when no samples recorded.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q-quantile (linear interpolation between order statistics),
    /// `q ∈ [0, 1]`. `None` on an empty set — the one empty-sample
    /// contract shared with [`LogHistogram::quantile`] and
    /// `RunMetrics::quantile_wall`. Panics only on `q` out of range
    /// (caller bug, not a data condition).
    pub fn quantile(&mut self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q));
        if self.xs.is_empty() {
            return None;
        }
        if !self.sorted {
            self.xs.sort_unstable_by(f64::total_cmp);
            self.sorted = true;
        }
        let n = self.xs.len();
        if n == 1 {
            return Some(self.xs[0]);
        }
        let pos = q * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        Some(self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac)
    }

    /// Median (p50); `None` on an empty set.
    pub fn median(&mut self) -> Option<f64> {
        self.quantile(0.5)
    }

    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Unbiased variance of the samples.
    pub fn variance(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
    }

    /// Borrow the raw samples.
    pub fn raw(&self) -> &[f64] {
        &self.xs
    }
}

/// Fixed-layout log-spaced histogram for latency-like positive values.
/// Bucket `i` covers `[base·r^i, base·r^(i+1))`; O(1) insert, percentile
/// estimation from bucket boundaries (worst-case relative error = `r−1`).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    base: f64,
    log_r: f64,
    counts: Vec<u64>,
    underflow: u64,
    total: u64,
}

impl LogHistogram {
    /// `base`: lowest representable value; `r`: bucket growth ratio
    /// (e.g. 1.1 ⇒ ≤10% relative error); `buckets`: number of buckets.
    pub fn new(base: f64, r: f64, buckets: usize) -> Self {
        assert!(base > 0.0 && r > 1.0 && buckets > 0);
        Self { base, log_r: r.ln(), counts: vec![0; buckets], underflow: 0, total: 0 }
    }

    /// Sensible default for seconds-scale latencies: 1 µs … ~52 min at 5%.
    pub fn for_latency() -> Self {
        Self::new(1e-6, 1.05, 450)
    }

    /// Record a value.
    #[inline]
    pub fn record(&mut self, x: f64) {
        self.total += 1;
        if x < self.base {
            self.underflow += 1;
            return;
        }
        let idx = ((x / self.base).ln() / self.log_r) as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    /// Total recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate q-quantile from bucket upper bounds; `None` when
    /// nothing has been recorded (same empty contract as
    /// [`Samples::quantile`]).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q));
        if self.total == 0 {
            return None;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return Some(self.base);
        }
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(self.base * ((i as f64 + 1.0) * self.log_r).exp());
            }
        }
        Some(self.base * (self.counts.len() as f64 * self.log_r).exp())
    }

    /// Merge another histogram with identical layout.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        assert!((self.base - other.base).abs() < 1e-18);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn total_folds_match_partial_on_finite_and_surface_nan() {
        let xs = [3.0, -1.5, 7.25, 0.0];
        assert_eq!(fold_max_total(xs.iter().cloned()), 7.25);
        assert_eq!(fold_min_total(xs.iter().cloned()), -1.5);
        // Empty inputs keep the fold identities.
        assert_eq!(fold_max_total(std::iter::empty()), f64::NEG_INFINITY);
        assert_eq!(fold_min_total(std::iter::empty()), f64::INFINITY);
        // A NaN poisons the result instead of being swallowed — the
        // whole point of banning the partial-order folds (rule D1).
        assert!(fold_max_total([1.0, f64::NAN, 2.0].iter().cloned()).is_nan());
        assert!(fold_min_total([1.0, -f64::NAN, 2.0].iter().cloned()).is_nan());
    }

    #[test]
    fn kahan_recovers_cancelled_low_order_bits() {
        // Naive summation loses the 1.0 entirely; Kahan keeps it.
        let mut k = Kahan::new();
        for x in [1e16, 1.0, -1e16] {
            k.add(x);
        }
        assert_eq!(k.sum(), 1.0);
        // Neumaier branch: the incoming term can also be the big one.
        let mut k = Kahan::new();
        for x in [1.0, 1e16, 1.0, -1e16] {
            k.add(x);
        }
        assert_eq!(k.sum(), 2.0);
    }

    #[test]
    fn kahan_tracks_long_accumulations_exactly() {
        // 10^6 × 0.1 drifts in naive f64 accumulation; the compensated
        // sum stays within one ulp of the true value.
        let mut k = Kahan::new();
        for _ in 0..1_000_000 {
            k.add(0.1);
        }
        assert!((k.sum() - 100_000.0).abs() < 1e-9, "kahan {}", k.sum());
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // naive unbiased variance = 4.571428...
        let m = 5.0;
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..1000).map(|_| r.f64() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..400] {
            a.push(x);
        }
        for &x in &xs[400..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn samples_quantiles() {
        let mut s = Samples::new();
        assert_eq!(s.quantile(0.5), None, "empty set has no quantiles");
        assert_eq!(s.median(), None);
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.quantile(0.0).unwrap() - 1.0).abs() < 1e-12);
        assert!((s.quantile(1.0).unwrap() - 100.0).abs() < 1e-12);
        assert!((s.median().unwrap() - 50.5).abs() < 1e-12);
        assert!((s.quantile(0.25).unwrap() - 25.75).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantile_accuracy() {
        let mut h = LogHistogram::new(1e-3, 1.05, 400);
        assert_eq!(h.quantile(0.5), None, "empty histogram has no quantiles");
        let mut r = Rng::new(2);
        let mut s = Samples::new();
        for _ in 0..100_000 {
            // exponential with mean 1
            let x = -r.f64_open0().ln();
            h.record(x);
            s.push(x);
        }
        for q in [0.5, 0.9, 0.99] {
            let exact = s.quantile(q).unwrap();
            let approx = h.quantile(q).unwrap();
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.08, "q={q} exact={exact} approx={approx}");
        }
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new(1e-3, 1.1, 100);
        let mut b = LogHistogram::new(1e-3, 1.1, 100);
        a.record(0.5);
        b.record(2.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
