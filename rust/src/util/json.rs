//! Minimal JSON parser/emitter (RFC 8259 subset, no external deps).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`) and for
//! machine-readable experiment results. Supports the full JSON value
//! grammar; numbers are parsed as `f64` (the manifest only carries small
//! integers and strings, well within f64 precision).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As integer (number that round-trips through i64).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 2f64.powi(53) => Some(*x as i64),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Array(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Object(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (manifest is ASCII).
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape character")),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Array(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Array(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Object(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Object(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\n"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"artifacts":[{"batch":128,"dim":64,"file":"grad_b128_d64.hlo.txt","kernel":"grad"}],"version":1}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\tü".into());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn errors_carry_offset() {
        let e = Json::parse("[1, 2").unwrap_err();
        assert!(e.offset >= 5, "{e}");
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[] trailing").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
        assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }
}
