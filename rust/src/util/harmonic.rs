//! Harmonic numbers and generalized harmonic numbers.
//!
//! The paper's closed forms are built from `H_B = Σ_{i=1..B} 1/i`
//! (expected maximum of B i.i.d. Exp(1)) and
//! `H⁽²⁾_B = Σ_{i=1..B} 1/i²` (its variance).

/// `H_n = Σ_{i=1..n} 1/i`. `H_0 = 0`.
///
/// Values up to [`HARMONIC_MEMO_MAX`] come from a lazily built prefix
/// table (O(1) after first use — sweep drivers call this in loops);
/// larger values fall back to direct summation, then the asymptotic
/// expansion above [`HARMONIC_TABLE_MAX`].
pub fn harmonic(n: u64) -> f64 {
    if n <= HARMONIC_MEMO_MAX {
        return harmonic_memo()[n as usize];
    }
    if n <= HARMONIC_TABLE_MAX {
        return harmonic_exact(n);
    }
    // Asymptotic expansion: ln n + γ + 1/2n − 1/12n² + 1/120n⁴.
    let nf = n as f64;
    nf.ln() + EULER_GAMMA + 0.5 / nf - 1.0 / (12.0 * nf * nf)
        + 1.0 / (120.0 * nf.powi(4))
}

/// `H⁽²⁾_n = Σ_{i=1..n} 1/i²`. `H⁽²⁾_0 = 0`. Memoized like [`harmonic`].
pub fn harmonic2(n: u64) -> f64 {
    if n <= HARMONIC_MEMO_MAX {
        return harmonic2_memo()[n as usize];
    }
    if n <= HARMONIC_TABLE_MAX {
        let mut s = 0.0;
        for i in 1..=n {
            let x = i as f64;
            s += 1.0 / (x * x);
        }
        return s;
    }
    // ζ(2) − 1/n + 1/2n² − 1/6n³.
    let nf = n as f64;
    std::f64::consts::PI * std::f64::consts::PI / 6.0 - 1.0 / nf + 0.5 / (nf * nf)
        - 1.0 / (6.0 * nf * nf * nf)
}

/// Largest index served by the O(1) prefix tables. Covers every worker
/// count the experiments sweep with a 64 KiB-per-table footprint.
pub const HARMONIC_MEMO_MAX: u64 = 8192;

fn harmonic_memo() -> &'static [f64] {
    static TABLE: std::sync::OnceLock<Vec<f64>> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| prefix_table(|i| 1.0 / i as f64))
}

fn harmonic2_memo() -> &'static [f64] {
    static TABLE: std::sync::OnceLock<Vec<f64>> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| prefix_table(|i| 1.0 / (i as f64 * i as f64)))
}

/// Kahan-compensated prefix sums of `term(1..=HARMONIC_MEMO_MAX)`, so
/// table entries are at least as accurate as the reverse-order direct
/// sums they replace.
fn prefix_table(term: impl Fn(u64) -> f64) -> Vec<f64> {
    let mut table = Vec::with_capacity(HARMONIC_MEMO_MAX as usize + 1);
    table.push(0.0);
    let (mut sum, mut comp) = (0.0f64, 0.0f64);
    for i in 1..=HARMONIC_MEMO_MAX {
        let y = term(i) - comp;
        let t = sum + y;
        comp = (t - sum) - y;
        sum = t;
        table.push(sum);
    }
    table
}

/// Generalized `H⁽ᵐ⁾_n = Σ_{i=1..n} 1/iᵐ` computed directly.
pub fn harmonic_gen(n: u64, m: f64) -> f64 {
    (1..=n).map(|i| (i as f64).powf(-m)).sum()
}

/// Partial harmonic sum `Σ_{i=a..b} 1/i = H_b − H_{a−1}` (inclusive).
/// Appears in the expected max of order statistics of subsets.
pub fn harmonic_range(a: u64, b: u64) -> f64 {
    assert!(a >= 1 && a <= b);
    harmonic(b) - harmonic(a - 1)
}

const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;
const HARMONIC_TABLE_MAX: u64 = 1 << 16;

fn harmonic_exact(n: u64) -> f64 {
    // Sum small-to-large is fine at this magnitude; sum backwards for
    // slightly better rounding.
    let mut s = 0.0;
    for i in (1..=n).rev() {
        s += 1.0 / i as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_exact() {
        assert_eq!(harmonic(0), 0.0);
        assert!((harmonic(1) - 1.0).abs() < 1e-15);
        assert!((harmonic(2) - 1.5).abs() < 1e-15);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-14);
    }

    #[test]
    fn asymptotic_matches_exact_at_boundary() {
        // Compare direct summation with the expansion just above the
        // table cutoff.
        let n = HARMONIC_TABLE_MAX + 1;
        let direct: f64 = (1..=n).rev().map(|i| 1.0 / i as f64).sum();
        assert!((harmonic(n) - direct).abs() < 1e-10);
    }

    #[test]
    fn harmonic2_limits() {
        assert!((harmonic2(1) - 1.0).abs() < 1e-15);
        // ζ(2) limit
        let z2 = std::f64::consts::PI * std::f64::consts::PI / 6.0;
        assert!((harmonic2(1_000_000) - z2).abs() < 2e-6);
    }

    #[test]
    fn harmonic2_asymptotic_matches_exact() {
        let n = HARMONIC_TABLE_MAX + 1;
        let direct: f64 = (1..=n).map(|i| 1.0 / (i as f64 * i as f64)).sum();
        assert!((harmonic2(n) - direct).abs() < 1e-10);
    }

    #[test]
    fn memo_table_matches_direct_summation() {
        // Table values and the direct-sum path must agree at, around,
        // and above the memo boundary.
        for n in [1u64, 7, 100, HARMONIC_MEMO_MAX - 1, HARMONIC_MEMO_MAX] {
            let direct: f64 = (1..=n).rev().map(|i| 1.0 / i as f64).sum();
            assert!((harmonic(n) - direct).abs() < 1e-11, "H_{n}");
            let direct2: f64 = (1..=n).rev().map(|i| 1.0 / (i as f64 * i as f64)).sum();
            assert!((harmonic2(n) - direct2).abs() < 1e-12, "H2_{n}");
        }
        let n = HARMONIC_MEMO_MAX + 1;
        let direct: f64 = (1..=n).rev().map(|i| 1.0 / i as f64).sum();
        assert!((harmonic(n) - direct).abs() < 1e-11, "just above the memo boundary");
    }

    #[test]
    fn range_identity() {
        for (a, b) in [(1, 10), (3, 17), (5, 5)] {
            let direct: f64 = (a..=b).map(|i| 1.0 / i as f64).sum();
            assert!((harmonic_range(a, b) - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn monotone_increasing() {
        let mut prev = 0.0;
        for n in 1..200 {
            let h = harmonic(n);
            assert!(h > prev);
            prev = h;
        }
    }
}
