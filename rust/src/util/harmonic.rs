//! Harmonic numbers and generalized harmonic numbers.
//!
//! The paper's closed forms are built from `H_B = Σ_{i=1..B} 1/i`
//! (expected maximum of B i.i.d. Exp(1)) and
//! `H⁽²⁾_B = Σ_{i=1..B} 1/i²` (its variance).

/// `H_n = Σ_{i=1..n} 1/i`. `H_0 = 0`.
pub fn harmonic(n: u64) -> f64 {
    if n <= HARMONIC_TABLE_MAX {
        return harmonic_exact(n);
    }
    // Asymptotic expansion: ln n + γ + 1/2n − 1/12n² + 1/120n⁴.
    let nf = n as f64;
    nf.ln() + EULER_GAMMA + 0.5 / nf - 1.0 / (12.0 * nf * nf)
        + 1.0 / (120.0 * nf.powi(4))
}

/// `H⁽²⁾_n = Σ_{i=1..n} 1/i²`. `H⁽²⁾_0 = 0`.
pub fn harmonic2(n: u64) -> f64 {
    if n <= HARMONIC_TABLE_MAX {
        let mut s = 0.0;
        for i in 1..=n {
            let x = i as f64;
            s += 1.0 / (x * x);
        }
        return s;
    }
    // ζ(2) − 1/n + 1/2n² − 1/6n³.
    let nf = n as f64;
    std::f64::consts::PI * std::f64::consts::PI / 6.0 - 1.0 / nf + 0.5 / (nf * nf)
        - 1.0 / (6.0 * nf * nf * nf)
}

/// Generalized `H⁽ᵐ⁾_n = Σ_{i=1..n} 1/iᵐ` computed directly.
pub fn harmonic_gen(n: u64, m: f64) -> f64 {
    (1..=n).map(|i| (i as f64).powf(-m)).sum()
}

/// Partial harmonic sum `Σ_{i=a..b} 1/i = H_b − H_{a−1}` (inclusive).
/// Appears in the expected max of order statistics of subsets.
pub fn harmonic_range(a: u64, b: u64) -> f64 {
    assert!(a >= 1 && a <= b);
    harmonic(b) - harmonic(a - 1)
}

const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;
const HARMONIC_TABLE_MAX: u64 = 1 << 16;

fn harmonic_exact(n: u64) -> f64 {
    // Sum small-to-large is fine at this magnitude; sum backwards for
    // slightly better rounding.
    let mut s = 0.0;
    for i in (1..=n).rev() {
        s += 1.0 / i as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_exact() {
        assert_eq!(harmonic(0), 0.0);
        assert!((harmonic(1) - 1.0).abs() < 1e-15);
        assert!((harmonic(2) - 1.5).abs() < 1e-15);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-14);
    }

    #[test]
    fn asymptotic_matches_exact_at_boundary() {
        // Compare direct summation with the expansion just above the
        // table cutoff.
        let n = HARMONIC_TABLE_MAX + 1;
        let direct: f64 = (1..=n).rev().map(|i| 1.0 / i as f64).sum();
        assert!((harmonic(n) - direct).abs() < 1e-10);
    }

    #[test]
    fn harmonic2_limits() {
        assert!((harmonic2(1) - 1.0).abs() < 1e-15);
        // ζ(2) limit
        let z2 = std::f64::consts::PI * std::f64::consts::PI / 6.0;
        assert!((harmonic2(1_000_000) - z2).abs() < 2e-6);
    }

    #[test]
    fn harmonic2_asymptotic_matches_exact() {
        let n = HARMONIC_TABLE_MAX + 1;
        let direct: f64 = (1..=n).map(|i| 1.0 / (i as f64 * i as f64)).sum();
        assert!((harmonic2(n) - direct).abs() < 1e-10);
    }

    #[test]
    fn range_identity() {
        for (a, b) in [(1, 10), (3, 17), (5, 5)] {
            let direct: f64 = (a..=b).map(|i| 1.0 / i as f64).sum();
            assert!((harmonic_range(a, b) - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn monotone_increasing() {
        let mut prev = 0.0;
        for n in 1..200 {
            let h = harmonic(n);
            assert!(h > prev);
            prev = h;
        }
    }
}
