//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256++` core seeded through SplitMix64, the standard
//! construction recommended by the xoshiro authors. The crate needs
//! reproducible streams (experiments are seeded, traces replay
//! deterministically) and independent substreams for parallel workers —
//! both provided here without external dependencies.

/// FNV-1a over a byte stream: the crate's stable non-cryptographic
/// content hash (canonical-key seeds, trace-content keys). Not for
/// adversarial input.
#[inline]
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 step — used for seeding and for cheap stateless mixing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is the one invalid state; seed 0 cannot produce
        // it through SplitMix64, but guard anyway.
        if s == [0, 0, 0, 0] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Derive an independent substream (e.g. one per worker thread).
    /// Uses the jump-free "seed from output" construction: hash the
    /// current state with the stream index through SplitMix64.
    pub fn substream(&self, index: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ index.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `(0, 1]` — safe as the argument of `ln()`.
    #[inline]
    pub fn f64_open0(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Fill `out` with uniforms in `(0, 1]` — the block form of
    /// [`Rng::f64_open0`], consuming exactly the same stream (one
    /// `next_u64` per element, in order). The generator recurrence is
    /// serial, but a dedicated fill loop keeps the state in registers
    /// and lets the subsequent transform pass vectorize.
    #[inline]
    pub fn fill_f64_open0(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.f64_open0();
        }
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-predictable; plenty fast for trace generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open0();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn int_in_inclusive_bounds() {
        let mut r = Rng::new(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..2000 {
            let v = r.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fill_matches_scalar_stream() {
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        let mut block = [0.0f64; 257];
        a.fill_f64_open0(&mut block);
        for x in &block {
            assert_eq!(*x, b.f64_open0());
        }
        // The two generators remain in lockstep afterwards.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn substreams_are_independent_and_deterministic() {
        let root = Rng::new(42);
        let mut a1 = root.substream(1);
        let mut a2 = root.substream(1);
        let mut b = root.substream(2);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }
}
