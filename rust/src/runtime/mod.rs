//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! `make artifacts` (python, build-time) lowers the L2 jax jobs to
//! `artifacts/*.hlo.txt` plus `manifest.json`; this module parses the
//! manifest ([`Manifest`]), compiles artifacts on a CPU PJRT client
//! ([`Engine`]), and exposes typed entry points for the two compute
//! jobs ([`Engine::grad`], [`Engine::mapsum`]). HLO **text** is the
//! interchange format — the crate's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids) but its text
//! parser reassigns ids cleanly.
//!
//! Thread-model: `xla::PjRtLoadedExecutable` is not `Send`, so each
//! worker thread owns its own [`Engine`] (client + compiled
//! executables). Compilation happens once per thread at startup, never
//! on the request path.
//!
//! Offline builds resolve the `xla` package to the vendored no-op stub
//! (`rust/vendor/xla-stub`), so `--features pjrt` *compiles*
//! everywhere; at runtime the stub fails from `PjRtClient::cpu` with a
//! clear message rather than faking results. Point the Cargo
//! dependency at the real bindings to execute artifacts.

use crate::util::json::Json;
#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact entry from `manifest.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Job kind: `grad` or `mapsum`.
    pub kernel: String,
    /// Batch rows this variant was lowered for.
    pub rows: usize,
    /// Feature dimension.
    pub dim: usize,
    /// HLO text filename (relative to the artifact dir).
    pub file: String,
    /// Number of tuple outputs.
    pub n_outputs: usize,
}

impl ArtifactSpec {
    /// Cache key.
    pub fn key(&self) -> String {
        format!("{}_r{}_d{}", self.kernel, self.rows, self.dim)
    }
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// All artifacts.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        let json = Json::parse(&text)?;
        let version = json
            .get("version")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("manifest missing version"))?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let arr = json
            .get("artifacts")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_s = |k: &str| {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing '{k}'"))
            };
            let get_i = |k: &str| {
                a.get(k)
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing '{k}'"))
            };
            artifacts.push(ArtifactSpec {
                kernel: get_s("kernel")?,
                rows: get_i("rows")? as usize,
                dim: get_i("dim")? as usize,
                file: get_s("file")?,
                n_outputs: get_i("outputs")? as usize,
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "manifest lists no artifacts");
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find the artifact for `(kernel, rows, dim)`.
    pub fn find(&self, kernel: &str, rows: usize, dim: usize) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kernel == kernel && a.rows == rows && a.dim == dim)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kernel={kernel} rows={rows} dim={dim}; \
                     available: {:?}",
                    self.artifacts.iter().map(ArtifactSpec::key).collect::<Vec<_>>()
                )
            })
    }

    /// Row variants available for a kernel/dim (used by the coordinator
    /// to choose shard sizes).
    pub fn rows_for(&self, kernel: &str, dim: usize) -> Vec<usize> {
        let mut rows: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kernel == kernel && a.dim == dim)
            .map(|a| a.rows)
            .collect();
        rows.sort_unstable();
        rows
    }
}

/// Result of one gradient-job execution.
#[derive(Debug, Clone, PartialEq)]
pub struct GradOut {
    /// Gradient sum `Xᵀ(Xw − y)`, length `dim`.
    pub grad: Vec<f32>,
    /// Loss sum `½‖Xw − y‖²`.
    pub loss: f32,
}

/// A per-thread PJRT engine: one CPU client plus compiled executables.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

// The PJRT client/executable handles are opaque FFI types without
// `Debug`; show the manifest and what has been compiled so far.
#[cfg(feature = "pjrt")]
impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("manifest", &self.manifest)
            .field("cached", &self.cache.keys().collect::<Vec<_>>())
            .finish_non_exhaustive()
    }
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create an engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: BTreeMap::new() })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for
    /// `(kernel, rows, dim)`.
    pub fn prepare(&mut self, kernel: &str, rows: usize, dim: usize) -> anyhow::Result<()> {
        let spec = self.manifest.find(kernel, rows, dim)?.clone();
        if self.cache.contains_key(&spec.key()) {
            return Ok(());
        }
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(spec.key(), exe);
        Ok(())
    }

    fn executable(
        &mut self,
        kernel: &str,
        rows: usize,
        dim: usize,
    ) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        let key = format!("{kernel}_r{rows}_d{dim}");
        if !self.cache.contains_key(&key) {
            self.prepare(kernel, rows, dim)?;
        }
        self.cache
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("prepare() did not cache executable '{key}'"))
    }

    /// Execute the gradient job: `x` is `rows×dim` row-major, `y` has
    /// `rows` entries, `w` has `dim` entries.
    pub fn grad(
        &mut self,
        rows: usize,
        dim: usize,
        x: &[f32],
        y: &[f32],
        w: &[f32],
    ) -> anyhow::Result<GradOut> {
        anyhow::ensure!(x.len() == rows * dim, "x has {} elems, want {}", x.len(), rows * dim);
        anyhow::ensure!(y.len() == rows && w.len() == dim, "y/w shape mismatch");
        let exe = self.executable("grad", rows, dim)?;
        let lx = xla::Literal::vec1(x).reshape(&[rows as i64, dim as i64])?;
        let ly = xla::Literal::vec1(y);
        let lw = xla::Literal::vec1(w);
        let result = exe.execute::<xla::Literal>(&[lx, ly, lw])?[0][0].to_literal_sync()?;
        let (g, loss) = result.to_tuple2()?;
        Ok(GradOut { grad: g.to_vec::<f32>()?, loss: loss.get_first_element::<f32>()? })
    }

    /// Execute the map-sum job.
    pub fn mapsum(
        &mut self,
        rows: usize,
        dim: usize,
        x: &[f32],
        a: &[f32],
        b: &[f32],
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(x.len() == rows * dim, "x shape mismatch");
        anyhow::ensure!(a.len() == dim && b.len() == dim, "a/b shape mismatch");
        let exe = self.executable("mapsum", rows, dim)?;
        let lx = xla::Literal::vec1(x).reshape(&[rows as i64, dim as i64])?;
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let result = exe.execute::<xla::Literal>(&[lx, la, lb])?[0][0].to_literal_sync()?;
        // Single-output jobs lower with a bare (untupled) entry root;
        // accept both forms.
        let scalar = match result.shape()? {
            xla::Shape::Tuple(_) => result.to_tuple1()?,
            _ => result,
        };
        Ok(scalar.get_first_element::<f32>()?)
    }
}

/// Stub engine used when the crate is built without the `pjrt` feature
/// (the offline default — the `xla` crate is unavailable there).
/// Construction always fails with instructions; the mock compute
/// backend and every analytic/simulation path remain fully functional.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always errors: rebuild with `--features pjrt` (requires the
    /// vendored `xla` crate) to execute AOT artifacts.
    pub fn new(artifact_dir: &Path) -> anyhow::Result<Engine> {
        // Surface the clearer "no artifacts" diagnosis first.
        let _ = Manifest::load(artifact_dir)?;
        anyhow::bail!(
            "batchrep was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the xla crate) or use the mock backend"
        )
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Unavailable without the `pjrt` feature.
    pub fn prepare(&mut self, _kernel: &str, _rows: usize, _dim: usize) -> anyhow::Result<()> {
        anyhow::bail!("PJRT execution requires the `pjrt` feature")
    }

    /// Unavailable without the `pjrt` feature.
    pub fn grad(
        &mut self,
        _rows: usize,
        _dim: usize,
        _x: &[f32],
        _y: &[f32],
        _w: &[f32],
    ) -> anyhow::Result<GradOut> {
        anyhow::bail!("PJRT execution requires the `pjrt` feature")
    }

    /// Unavailable without the `pjrt` feature.
    pub fn mapsum(
        &mut self,
        _rows: usize,
        _dim: usize,
        _x: &[f32],
        _a: &[f32],
        _b: &[f32],
    ) -> anyhow::Result<f32> {
        anyhow::bail!("PJRT execution requires the `pjrt` feature")
    }
}

/// Locate the artifact directory: `$BATCHREP_ARTIFACTS`, else
/// `artifacts/` (with a manifest) walking up from the current directory.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("BATCHREP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("batchrep_rt_manifest");
        write_manifest(
            &dir,
            r#"{"version":1,"artifacts":[
                {"kernel":"grad","rows":8,"dim":4,"file":"grad_r8_d4.hlo.txt",
                 "inputs":[["8,4","f32"],["8","f32"],["4","f32"]],"outputs":2}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("grad", 8, 4).unwrap();
        assert_eq!(a.n_outputs, 2);
        assert_eq!(m.rows_for("grad", 4), vec![8]);
        assert!(m.find("grad", 16, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_errors() {
        let dir = std::env::temp_dir().join("batchrep_rt_manifest_bad");
        write_manifest(&dir, r#"{"version":9,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, r#"{"version":1,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    // PJRT execution tests live in rust/tests/runtime_integration.rs;
    // they need `make artifacts` and skip with a notice when absent.
}
