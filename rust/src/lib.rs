//! # batchrep
//!
//! A reproduction of *"Data Replication for Reducing Computing Time in
//! Distributed Systems with Stragglers"* (Behrouzi-Far & Soljanin, 2019)
//! as a deployable master–worker framework.
//!
//! The paper studies **System1**: `N` workers, a dataset cut into `B`
//! equal batches (`B | N`), each batch replicated on `g = N/B` workers.
//! A job completes when *every* batch has been finished by at least one
//! of its replicas; the master aggregates the earliest replica results.
//! The library provides, as first-class components:
//!
//! * [`assignment`] — the paper's batch→worker assignment policies
//!   (balanced disjoint, overlapping, random, skewed) with invariant
//!   validation;
//! * [`batching`] — the two-stage sample→batch→worker data distribution;
//! * [`analysis`] — closed-form expectation/variance of the completion
//!   time for Exponential and Shifted-Exponential service (paper
//!   Theorems 2–4, Eq. 4) and the Theorem-3 optimizer for `B*`;
//! * [`des`] — a discrete-event simulator of System1 with replica
//!   cancellation, for policies/distributions with no closed form;
//! * [`coordinator`] + [`worker`] + [`runtime`] — a *live* System1:
//!   real worker threads executing AOT-compiled JAX/Pallas compute jobs
//!   through PJRT (the `xla` crate), with injected straggler service
//!   times and first-completion-wins cancellation;
//! * [`dist`] — service-time distributions and the size-dependent batch
//!   service model (Gardner et al.) the paper builds on;
//! * [`experiments`] — drivers that regenerate every figure/table.
//!
//! Substrates built in-crate (offline environment): PRNG, statistics,
//! JSON, TOML-subset config, property-testing ([`testkit`]) and
//! micro-benchmarking ([`benchkit`]).
//!
//! ## Quickstart
//!
//! ```
//! use batchrep::analysis::{completion_time_stats, optimum_b};
//! use batchrep::dist::ServiceSpec;
//!
//! // N = 24 workers, Shifted-Exponential per-sample service.
//! let spec = ServiceSpec::shifted_exp(1.0, 0.2);
//! let stats_b4 = completion_time_stats(24, 4, &spec).unwrap();
//! assert!(stats_b4.mean > 0.0);
//! // Theorem 3: the optimum number of batches for this (mu, delta).
//! let b_star = optimum_b(24, &spec);
//! assert!(24 % b_star == 0);
//! ```

pub mod analysis;
pub mod assignment;
pub mod batching;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod des;
pub mod dist;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod testkit;
pub mod trace;
pub mod util;
pub mod worker;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
