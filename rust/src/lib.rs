//! # batchrep
//!
//! A reproduction of *"Data Replication for Reducing Computing Time in
//! Distributed Systems with Stragglers"* (Behrouzi-Far & Soljanin, 2019)
//! as a deployable master–worker framework.
//!
//! The paper studies **System1**: `N` workers, a dataset cut into `B`
//! equal batches (`B | N`), each batch replicated on `g = N/B` workers.
//! A job completes when *every* batch has been finished by at least one
//! of its replicas; the master aggregates the earliest replica results.
//!
//! ## The `Scenario → Evaluator` API
//!
//! The crate's central abstraction lives in [`evaluator`]: a validated,
//! fully self-describing [`des::Scenario`] (layout + assignment +
//! service law + replication policy + redundancy mode + RNG seed) is
//! consumed by any [`evaluator::Evaluator`] backend, and every backend
//! returns the same [`evaluator::CompletionStats`]:
//!
//! * [`evaluator::AnalyticEvaluator`] — exact closed forms (paper
//!   Theorems 2–4, Eq. 4; Exponential/Shifted-Exponential only);
//! * [`evaluator::MonteCarloEvaluator`] — the direct completion-time
//!   sampler (block-sampled RNG kernel, zero-allocation trials,
//!   auto-threaded by default, bit-deterministic per seed for any
//!   thread count;
//!   see `PERF.md` and the `bench-mc` harness for measured trials/s);
//! * [`evaluator::DesEvaluator`] — the event engine with cancellation,
//!   speculative relaunch, failure injection, and cost accounting;
//! * [`evaluator::LiveEvaluator`] — the real coordinator + worker
//!   threads with injected stragglers.
//!
//! Swapping backends is a one-line change; [`evaluator::cross_check`]
//! asserts two backends agree on one scenario (the paper's Fig. 2
//! theory-vs-simulation validation as an API call), and
//! [`evaluator::sweep`] is the generic single-backend driver.
//!
//! ## The Study layer
//!
//! One scenario is rarely the question — the paper's results are
//! *families* of scenarios. The [`study`] module is the second layer of
//! the public API: a declarative [`study::StudySpec`] (axes over policy
//! × redundancy × k-of-B × worker speeds × service spec × backend, plus
//! trial budgets) compiles into a deduplicated
//! [`study::ExecutionPlan`] — identical `(scenario, backend, trials)`
//! cells are evaluated once and fanned out, analytic cells share one
//! memo, and every Monte-Carlo/DES cell's logical shards run on one
//! shared worker pool (bit-deterministic per seed for any thread
//! count). Execution streams [`study::CellResult`]s and collects a
//! versioned, schema-validated [`study::StudyReport`] artifact (JSON +
//! CSV). The [`experiments`] drivers and the `batchrep study`/`batchrep
//! evaluate` subcommands are built on it.
//!
//! Supporting layers:
//!
//! * [`assignment`] — batch→worker assignment policies with invariant
//!   validation; [`batching`] — the sample→batch data layouts;
//! * [`analysis`] — the raw closed forms (Eq. 4, the Theorem-3
//!   optimizer for `B*`, quantiles, costs, inclusion–exclusion for
//!   unbalanced degrees);
//! * [`des`] — the Monte-Carlo sampler and the discrete-event engine;
//! * [`coordinator`] + [`worker`] + [`runtime`] — the *live* System1:
//!   real worker threads executing AOT-compiled JAX/Pallas compute jobs
//!   through PJRT (behind the `pjrt` cargo feature; the pure-Rust mock
//!   backend always works), with injected straggler service times and
//!   first-completion-wins cancellation;
//! * [`dist`] — service-time distributions and the size-dependent batch
//!   service model (Gardner et al.) the paper builds on;
//! * [`control`] — the adaptive layer: every backend above assumes the
//!   service parameters are known; `control` estimates them online from
//!   censored per-replica telemetry, plans redundancy under a
//!   declarative objective, detects drift (CUSUM), and measures regret
//!   vs the oracle plan in a closed loop (`batchrep control`);
//! * [`experiments`] — drivers that regenerate every figure/table;
//! * [`obs`] — the unified observability layer: an explicitly-installed
//!   JSON-lines event sink (`--events <path>` on the CLI), wall-clock
//!   spans, and a typed counters registry, all no-op by default so
//!   bit-determinism and hot-path cost are untouched (`batchrep obs
//!   summarize` renders the log).
//!
//! Substrates built in-crate (offline environment): PRNG, statistics,
//! JSON, TOML-subset config, property-testing ([`testkit`]) and
//! micro-benchmarking ([`benchkit`]).
//!
//! The [`conformance`] subsystem sweeps randomly generated scenarios
//! (policy × redundancy × k-of-B × worker speeds × failure injection ×
//! service spec) through every applicable backend pair with
//! stderr-scaled z-bound tolerances — `batchrep conformance --fast` is
//! the CI gate; failures replay deterministically from their printed
//! seed.
//!
//! ## Quickstart
//!
//! ```
//! use batchrep::des::Scenario;
//! use batchrep::dist::{BatchService, ServiceSpec};
//! use batchrep::evaluator::{
//!     cross_check, AnalyticEvaluator, Evaluator, MonteCarloEvaluator, ReplicationPolicy,
//! };
//!
//! // N = 24 workers, B = 4 balanced disjoint batches, SExp service.
//! let service = BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.2));
//! let scn = Scenario::from_policy(ReplicationPolicy::BalancedDisjoint, 24, 4, service, 42)
//!     .unwrap();
//!
//! // Exact closed form (Theorem 3 territory) ...
//! let exact = AnalyticEvaluator.evaluate(&scn).unwrap();
//! assert!(exact.mean > 0.0);
//! // ... and simulation — same scenario, one-line backend swap.
//! let mc = MonteCarloEvaluator { trials: 20_000, threads: 1 };
//! let sim = mc.evaluate(&scn).unwrap();
//! assert!((sim.mean - exact.mean).abs() < 0.1 * exact.mean);
//! // Or as a single validated call:
//! cross_check(&AnalyticEvaluator, &mc, &scn).unwrap();
//! ```
//!
//! Crate-wide hygiene is enforced mechanically: the [`lint`] module
//! (`batchrep lint`, part of `./ci.sh`) checks the determinism
//! invariants D1–D6 described in the README's "Static analysis" section.

// The crate uses no unsafe; make that a compile-time guarantee.
#![forbid(unsafe_code)]
// Every public type prints something useful in test failures and logs.
#![deny(missing_debug_implementations)]
// clippy.toml backs the lint module's D2/D3 bans with disallowed-methods;
// that lint is allow-by-default, so opt in here (plain rustc accepts and
// ignores tool lints, so this is free for non-clippy builds).
#![warn(clippy::disallowed_methods)]

pub mod analysis;
pub mod assignment;
pub mod batching;
pub mod benchkit;
pub mod config;
pub mod conformance;
pub mod control;
pub mod coordinator;
pub mod des;
pub mod dist;
pub mod evaluator;
pub mod experiments;
pub mod fault;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod study;
pub mod testkit;
pub mod trace;
pub mod util;
pub mod worker;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
