//! The unified `Scenario → Evaluator` layer: one abstraction over
//! closed-form analysis, Monte-Carlo sampling, discrete-event
//! simulation, and the live master–worker runtime.
//!
//! The paper's claims live in four places with historically incompatible
//! entry points; this module makes them interchangeable **backends**
//! behind a single trait. Every backend consumes the same validated
//! [`Scenario`] (which carries its [`ReplicationPolicy`], redundancy
//! mode, and RNG seed, so it is fully self-describing) and returns the
//! same [`CompletionStats`]:
//!
//! * [`AnalyticEvaluator`] — Theorems 2–4 closed forms (exact;
//!   Exponential/Shifted-Exponential, size-scaled, upfront only).
//!   Balanced assignments use the harmonic-number forms; unbalanced
//!   equal-size assignments use inclusion–exclusion over the maximum of
//!   non-identical exponentials.
//! * [`MonteCarloEvaluator`] — block-sampled trial batches over the
//!   direct completion-time sampler (zero-allocation scratch,
//!   multi-threaded by default, deterministic per seed for any thread
//!   count).
//! * [`DesEvaluator`] — the full event engine: replica cancellation,
//!   speculative relaunch, failure injection, k-of-B partial
//!   aggregation, and busy/wasted worker-second cost accounting
//!   (flat-event-queue trial loop, multi-threaded by default,
//!   deterministic per seed for any thread count).
//! * [`LiveEvaluator`] — the real coordinator + worker threads with
//!   injected stragglers (mock or PJRT compute backend).
//!
//! [`cross_check`] runs two backends on one scenario and asserts their
//! moments agree within tolerance — the paper's own Fig. 2 validation
//! (theory vs simulation) as a reusable API call. [`sweep`] is the
//! generic driver the experiments layer is built on: evaluate a
//! scenario family over a list of batch counts with any backend.

use crate::assignment::{Assignment, Policy};
use crate::batching::DataLayout;
use crate::config::SystemConfig;
use crate::coordinator::{Backend, Coordinator};
use crate::des::engine::{simulate_many_parallel, EngineConfig, EngineSummary, Redundancy};
use crate::des::{montecarlo, Scenario};
use crate::dist::{BatchModel, BatchService};
use crate::util::harmonic::{harmonic, harmonic2};
use crate::util::rng::Rng;
use crate::util::stats::{Samples, Welford};
use crate::worker::JobSpec;
use std::sync::Arc;

/// The machine's available parallelism (1 when it cannot be
/// determined) — the thread count the `Default` simulation backends
/// pick. This is the one sanctioned machine-shape probe: it only ever
/// picks how many threads chew the fixed 64-shard plan, never what the
/// shards compute, so results stay bit-identical across machines.
#[allow(clippy::disallowed_methods)]
pub fn auto_threads() -> usize {
    // lint:allow(D2): thread-count selection affects speed only, never results (fixed shard plan)
    std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1)
}

/// Quantiles every evaluator reports (when it can produce them).
pub const QUANTILES: [f64; 4] = [0.5, 0.9, 0.99, 0.999];

/// Expected redundancy bill of one job, in worker-seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostStats {
    /// Mean busy worker-seconds (all work actually performed).
    pub busy: f64,
    /// Mean worker-seconds spent on replicas that did not win their
    /// batch (cancelled or redundant) — the price of diversity.
    pub wasted: f64,
}

/// Wall-clock overhead of the live runtime, measured against the
/// injected (simulated-service) time — the [`CostStats`] extension that
/// lets study reports track how much of a live round is dispatch,
/// channel traffic, and aggregation rather than modeled service.
/// Reported only by [`LiveEvaluator`] (`None` everywhere else).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadStats {
    /// Mean wall-clock seconds from round start until the last task of
    /// the round was handed to its worker channel (dispatch + sampling).
    pub dispatch_s: f64,
    /// Mean wall-clock round completion, seconds.
    pub wall_s: f64,
    /// Mean injected (simulated-service) completion, seconds.
    pub injected_s: f64,
}

impl OverheadStats {
    /// Mean wall-clock seconds not explained by injected service time
    /// (dispatch + channel + aggregation overhead).
    pub fn overhead_s(&self) -> f64 {
        self.wall_s - self.injected_s
    }

    /// Overhead as a fraction of the wall-clock round (0 when no wall
    /// time was recorded).
    pub fn overhead_frac(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.overhead_s() / self.wall_s
        }
    }
}

/// Completion-time statistics in the common currency all evaluators
/// speak.
#[derive(Debug, Clone)]
pub struct CompletionStats {
    /// Expected job completion time.
    pub mean: f64,
    /// Variance of the completion time.
    pub variance: f64,
    /// `(q, t_q)` pairs at [`QUANTILES`], ascending in `q`; empty when
    /// the backend cannot produce quantiles.
    pub quantiles: Vec<(f64, f64)>,
    /// Redundancy cost; `None` when the backend does not account cost.
    pub cost: Option<CostStats>,
    /// Standard error of `mean` (0 for exact backends).
    pub sem: f64,
    /// Trials/rounds behind the estimate (0 = closed form).
    pub samples: u64,
    /// Live-runtime wall-clock overhead; `None` for every backend whose
    /// time axis is purely simulated.
    pub overhead: Option<OverheadStats>,
}

impl CompletionStats {
    /// Standard deviation of the completion time.
    pub fn stddev(&self) -> f64 {
        self.variance.sqrt()
    }

    /// 95% confidence half-width of the mean (0 for exact backends).
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem
    }

    /// Look up a reported quantile.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        self.quantiles.iter().find(|(qq, _)| (qq - q).abs() < 1e-9).map(|&(_, t)| t)
    }
}

/// A completion-time evaluation backend.
pub trait Evaluator {
    /// Stable backend identifier (tables, error messages).
    fn name(&self) -> &'static str;

    /// Evaluate a scenario, consuming its policy/redundancy/seed.
    fn evaluate(&self, scn: &Scenario) -> anyhow::Result<CompletionStats>;
}

// ---------------------------------------------------------------------
// Replication policy
// ---------------------------------------------------------------------

/// How the data layout and the batch→worker assignment are built — the
/// paper's policy space plus the overlapping comparison class, unified
/// so a scenario can describe itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicationPolicy {
    /// The paper's optimum: disjoint batches, equal replication degrees.
    BalancedDisjoint,
    /// Balanced degrees, uniformly random batch→worker grouping
    /// (completion-time–equivalent to balanced disjoint under i.i.d.
    /// service).
    RandomBalanced,
    /// Maximally skewed replication degrees (Theorem 1's strawman).
    SkewedUnbalanced,
    /// Storage-equal overlapping comparison: `N` cyclic windows of
    /// `N/B` units each, one per worker.
    OverlappingCyclic,
    /// One batch replicated everywhere (`B = 1`).
    FullDiversity,
    /// One worker per batch (`B = N`, no redundancy).
    FullParallelism,
    /// Layout/assignment supplied directly via [`Scenario::new`].
    Custom,
}

impl ReplicationPolicy {
    /// Every policy with a generic construction (excludes `Custom`).
    pub fn all() -> &'static [ReplicationPolicy] {
        &[
            ReplicationPolicy::BalancedDisjoint,
            ReplicationPolicy::RandomBalanced,
            ReplicationPolicy::SkewedUnbalanced,
            ReplicationPolicy::OverlappingCyclic,
            ReplicationPolicy::FullDiversity,
            ReplicationPolicy::FullParallelism,
        ]
    }

    /// Table/config identifier.
    pub fn name(&self) -> &'static str {
        match self {
            ReplicationPolicy::BalancedDisjoint => "balanced_disjoint",
            ReplicationPolicy::RandomBalanced => "random_balanced",
            ReplicationPolicy::SkewedUnbalanced => "skewed_unbalanced",
            ReplicationPolicy::OverlappingCyclic => "overlapping_cyclic",
            ReplicationPolicy::FullDiversity => "full_diversity",
            ReplicationPolicy::FullParallelism => "full_parallelism",
            ReplicationPolicy::Custom => "custom",
        }
    }

    /// Parse from config string.
    pub fn parse(s: &str) -> anyhow::Result<ReplicationPolicy> {
        Ok(match s {
            "balanced_disjoint" => ReplicationPolicy::BalancedDisjoint,
            "random_balanced" => ReplicationPolicy::RandomBalanced,
            "skewed_unbalanced" => ReplicationPolicy::SkewedUnbalanced,
            "overlapping_cyclic" => ReplicationPolicy::OverlappingCyclic,
            "full_diversity" => ReplicationPolicy::FullDiversity,
            "full_parallelism" => ReplicationPolicy::FullParallelism,
            _ => anyhow::bail!(
                "unknown replication policy '{s}' (accepted: balanced_disjoint, \
                 random_balanced, skewed_unbalanced, overlapping_cyclic, \
                 full_diversity, full_parallelism)"
            ),
        })
    }

    /// Build the `(layout, assignment)` pair for `n_batches` batches on
    /// `n_workers` workers (`U = N` units, the paper's normalization).
    pub fn build(
        &self,
        n_workers: usize,
        n_batches: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<(DataLayout, Assignment)> {
        let policy = match self {
            ReplicationPolicy::BalancedDisjoint => Policy::BalancedDisjoint,
            ReplicationPolicy::RandomBalanced => Policy::RandomBalanced,
            ReplicationPolicy::SkewedUnbalanced => Policy::SkewedUnbalanced,
            ReplicationPolicy::FullDiversity => Policy::FullDiversity,
            ReplicationPolicy::FullParallelism => Policy::FullParallelism,
            ReplicationPolicy::OverlappingCyclic => {
                anyhow::ensure!(
                    n_batches >= 1 && n_workers % n_batches == 0,
                    "overlapping layout needs B | N (got N={n_workers}, B={n_batches})"
                );
                let assignment = crate::assignment::balanced(n_workers, n_workers)?;
                let layout =
                    crate::batching::overlapping(n_workers, n_workers, n_workers / n_batches)?;
                return Ok((layout, assignment));
            }
            ReplicationPolicy::Custom => {
                anyhow::bail!("Custom policy has no generic construction; use Scenario::new")
            }
        };
        let assignment = policy.assign(n_workers, n_batches, rng)?;
        let layout = crate::batching::disjoint(n_workers, assignment.n_batches)?;
        Ok((layout, assignment))
    }
}

// ---------------------------------------------------------------------
// Analytic backend
// ---------------------------------------------------------------------

/// Closed forms (paper Theorems 2–4 / Eq. 4) — requires Exponential or
/// Shifted-Exponential per-unit service, the size-scaled batch model,
/// disjoint layouts, and upfront replication. Heterogeneous
/// `worker_speeds` are supported for full completion:
/// **exact** per-worker-rate order statistics under Exponential
/// service, a **two-sided bound** under Shifted-Exponential
/// ([`crate::analysis::hetero_completion_bounds`]) — the bounded result
/// reports the interval midpoint as `mean` and encodes the half-width
/// as `sem = half_width / 4`, so [`cross_check`]'s 4σ window spans the
/// whole interval. Errors otherwise, naming the offending `Scenario`
/// field and value: the caller should fall back to a simulation
/// backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticEvaluator;

impl Evaluator for AnalyticEvaluator {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn evaluate(&self, scn: &Scenario) -> anyhow::Result<CompletionStats> {
        anyhow::ensure!(
            !scn.layout.is_overlapping,
            "analytic evaluator requires a disjoint layout; Scenario::layout is an \
             overlapping cyclic layout ({} units across {} windows)",
            scn.layout.n_units,
            scn.layout.n_batches()
        );
        anyhow::ensure!(
            scn.redundancy == Redundancy::Upfront,
            "analytic evaluator models upfront replication only; Scenario::redundancy = \
             {:?} is unsupported (use DesEvaluator for speculative redundancy)",
            scn.redundancy
        );
        anyhow::ensure!(
            scn.service.model == BatchModel::SizeScaled,
            "closed forms hold for the size-scaled batch model only; \
             Scenario::service.model = {}",
            scn.service.model.name()
        );
        let (mu, delta) = scn.service.spec.exp_family().ok_or_else(|| {
            anyhow::anyhow!(
                "closed forms cover Exponential/Shifted-Exponential service only; \
                 Scenario::service.spec = {}",
                scn.service.spec.name()
            )
        })?;
        if let Some(m) = scn.verify_m {
            anyhow::ensure!(
                scn.worker_speeds.is_none(),
                "analytic evaluator cannot combine Scenario::verify_m = Some({m}) with \
                 heterogeneous Scenario::worker_speeds; use the montecarlo or des backend"
            );
            let b = scn.assignment.n_batches;
            anyhow::ensure!(
                scn.assignment.is_balanced(),
                "closed-form m-of-g verification needs a balanced assignment; \
                 Scenario::verify_m = Some({m}) with an unbalanced Scenario::assignment \
                 (degrees {:?})",
                (0..b).map(|i| scn.assignment.replication(i)).collect::<Vec<_>>()
            );
            anyhow::ensure!(
                scn.layout.n_units == scn.assignment.n_workers,
                "closed-form m-of-g verification uses the paper normalization U = N; \
                 Scenario::layout.n_units = {} with {} workers",
                scn.layout.n_units,
                scn.assignment.n_workers
            );
            let n = scn.assignment.n_workers as u64;
            let k = scn.k_of_b.unwrap_or(b) as u64;
            // m-th order statistic per batch composed with k-of-B
            // (analysis::verified_completion_stats, N <= 32). The cost
            // closed form assumes every batch verifies, so partial
            // aggregation reports completion only.
            let st = crate::analysis::verified_completion_stats(
                n,
                b as u64,
                m as u64,
                k,
                &scn.service.spec,
            )?;
            let cost = if k == b as u64 {
                let (busy, wasted) = crate::analysis::verified_cost_stats(
                    n,
                    b as u64,
                    m as u64,
                    &scn.service.spec,
                )?;
                Some(CostStats { busy, wasted })
            } else {
                None
            };
            return Ok(CompletionStats {
                mean: st.mean,
                variance: st.var,
                quantiles: Vec::new(),
                cost,
                sem: 0.0,
                samples: 0,
                overhead: None,
            });
        }
        if let Some(speeds) = &scn.worker_speeds {
            return self.evaluate_hetero(scn, speeds);
        }
        if let Some(k) = scn.k_of_b {
            let b = scn.assignment.n_batches;
            if k < b {
                // Partial aggregation: the k-th order statistic of the
                // B i.i.d. batch-min times (analysis::partial_completion_stats).
                // Quantiles and cancellation cost have no simple closed
                // form here; simulation backends report them.
                anyhow::ensure!(
                    scn.assignment.is_balanced(),
                    "closed-form k-of-B needs a balanced assignment; \
                     Scenario::k_of_b = Some({k}) with an unbalanced \
                     Scenario::assignment (degrees {:?})",
                    (0..b).map(|i| scn.assignment.replication(i)).collect::<Vec<_>>()
                );
                anyhow::ensure!(
                    scn.layout.n_units == scn.assignment.n_workers,
                    "closed-form k-of-B uses the paper normalization U = N; \
                     Scenario::layout.n_units = {} with {} workers",
                    scn.layout.n_units,
                    scn.assignment.n_workers
                );
                let st = crate::analysis::partial_completion_stats(
                    scn.assignment.n_workers as u64,
                    b as u64,
                    k as u64,
                    &scn.service.spec,
                )?;
                return Ok(CompletionStats {
                    mean: st.mean,
                    variance: st.var,
                    quantiles: Vec::new(),
                    cost: None,
                    sem: 0.0,
                    samples: 0,
                    overhead: None,
                });
            }
            // k = B waits for every batch: the full-completion closed
            // forms below apply unchanged.
        }
        let b = scn.assignment.n_batches;
        let s = scn.layout.batch_units() as f64;
        let shift = s * delta;

        // Cost under cancellation: every replica of batch i runs until
        // the batch's earliest replica finishes at E[min_i] = s∆ + s/(gᵢµ).
        let mut busy = 0.0;
        let mut wasted = 0.0;
        for i in 0..b {
            let g = scn.assignment.replication(i) as f64;
            let e_min = shift + s / (g * mu);
            busy += g * e_min;
            wasted += (g - 1.0) * e_min;
        }

        let (mean, variance, quantiles) = if scn.assignment.is_balanced() {
            // Earliest replica of each batch ~ s∆ + Exp(gµ/s); the max of
            // B i.i.d. such gives the harmonic forms (g = s recovers Eq. 4).
            let g = scn.assignment.replication(0) as f64;
            let rate = g * mu / s;
            let bu = b as u64;
            let (mean, variance) = if scn.layout.n_units == scn.assignment.n_workers {
                // Paper normalization (U = N ⇒ g = s ⇒ rate = µ):
                // delegate to the memoized closed form shared with the
                // analysis sweeps, so `paper_sweep` over dense grids is
                // served from the cache.
                let st = crate::analysis::completion_time_stats(
                    scn.assignment.n_workers as u64,
                    bu,
                    &scn.service.spec,
                )?;
                (st.mean, st.var)
            } else {
                (shift + harmonic(bu) / rate, harmonic2(bu) / (rate * rate))
            };
            let quantiles = QUANTILES
                .iter()
                .map(|&q| (q, shift - (1.0 - q.powf(1.0 / b as f64)).ln() / rate))
                .collect();
            (mean, variance, quantiles)
        } else {
            // Unbalanced equal-size batches: inclusion–exclusion over the
            // max of independent non-identical exponentials.
            anyhow::ensure!(
                b <= 20,
                "inclusion–exclusion closed form limited to B <= 20; unbalanced \
                 Scenario::assignment has n_batches = {b}"
            );
            let rates: Vec<f64> = (0..b)
                .map(|i| scn.assignment.replication(i) as f64 * mu / s)
                .collect();
            let base = crate::analysis::max_of_exponentials_stats(&rates);
            let quantiles = QUANTILES
                .iter()
                .map(|&q| (q, quantile_bisect(&rates, shift, q)))
                .collect();
            (shift + base.mean, base.var, quantiles)
        };

        Ok(CompletionStats {
            mean,
            variance,
            quantiles,
            cost: Some(CostStats { busy, wasted }),
            sem: 0.0,
            samples: 0,
            overhead: None,
        })
    }
}

impl AnalyticEvaluator {
    /// Heterogeneous-speed leg: exact for Exponential service, a
    /// midpoint-plus-interval encoding of the Shifted-Exponential bound
    /// (`sem = half_width / 4`, so a 4σ window spans the interval; the
    /// conformance matrix reads the interval itself via
    /// [`crate::analysis::hetero_completion_bounds`]). Partial
    /// aggregation below `k = B` has no heterogeneous closed form.
    fn evaluate_hetero(
        &self,
        scn: &Scenario,
        speeds: &[f64],
    ) -> anyhow::Result<CompletionStats> {
        let b = scn.assignment.n_batches;
        if let Some(k) = scn.k_of_b {
            anyhow::ensure!(
                k >= b,
                "analytic evaluator cannot combine Scenario::worker_speeds \
                 ({} factors in [{:.3}, {:.3}]) with partial aggregation \
                 Scenario::k_of_b = Some({k}) < B = {b}; use the montecarlo or des \
                 backend",
                speeds.len(),
                crate::util::stats::fold_min_total(speeds.iter().cloned()),
                crate::util::stats::fold_max_total(speeds.iter().cloned())
            );
        }
        let bounds = crate::analysis::hetero_completion_bounds(
            &scn.assignment,
            &scn.service.spec,
            scn.layout.n_units as u64,
            speeds,
        )?;
        Ok(CompletionStats {
            mean: bounds.mid_mean(),
            variance: bounds.lower.var,
            quantiles: Vec::new(),
            cost: None,
            sem: bounds.half_width() / 4.0,
            samples: 0,
            overhead: None,
        })
    }
}

/// Invert `P(T ≤ t) = Π_i (1 − e^{−λᵢ(t−shift)})` by bisection.
fn quantile_bisect(rates: &[f64], shift: f64, q: f64) -> f64 {
    let cdf = |t: f64| -> f64 {
        rates.iter().map(|&l| 1.0 - (-l * (t - shift)).exp()).product()
    };
    let mut hi = 1.0;
    while cdf(shift + hi) < q {
        hi *= 2.0;
        if hi > 1e12 {
            break;
        }
    }
    let (mut lo, mut hi) = (0.0, hi);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if cdf(shift + mid) < q {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    shift + 0.5 * (lo + hi)
}

// ---------------------------------------------------------------------
// Monte-Carlo backend
// ---------------------------------------------------------------------

/// Direct completion-time sampler: block-samples every worker's batch
/// service time (vectorizable `fill_batch_times` kernel, zero-allocation
/// [`montecarlo::TrialScratch`]) and reduces (per-batch min, global max /
/// coverage). Trials always run through the fixed logical-shard plan,
/// so results are bit-deterministic for a fixed `(scenario, seed)` pair
/// **for any thread count** — `threads` (all cores under `Default`)
/// only changes wall-clock time.
#[derive(Debug, Clone, Copy)]
pub struct MonteCarloEvaluator {
    /// Number of independent trials.
    pub trials: u64,
    /// Worker threads (1 = sequential; `Default` = all cores).
    pub threads: usize,
}

impl MonteCarloEvaluator {
    /// The thread count `Default` picks (alias of [`auto_threads`]).
    pub fn auto_threads() -> usize {
        auto_threads()
    }
}

impl Default for MonteCarloEvaluator {
    fn default() -> Self {
        Self { trials: 100_000, threads: Self::auto_threads() }
    }
}

impl Evaluator for MonteCarloEvaluator {
    fn name(&self) -> &'static str {
        "montecarlo"
    }

    fn evaluate(&self, scn: &Scenario) -> anyhow::Result<CompletionStats> {
        anyhow::ensure!(self.trials >= 1, "need at least one trial");
        anyhow::ensure!(
            scn.redundancy == Redundancy::Upfront,
            "monte-carlo evaluator models upfront replication only; use DesEvaluator \
             for speculative redundancy"
        );
        let mc = montecarlo::run_trials_parallel(scn, self.trials, scn.seed, self.threads);
        Ok(stats_from_mc(mc))
    }
}

/// Assemble [`CompletionStats`] from a Monte-Carlo summary — the single
/// definition shared by [`MonteCarloEvaluator`] and the study pool
/// ([`crate::study`]), so their results are identical by construction.
/// Quantiles sort the summary's own retained samples in place — no
/// per-call clone of the sample buffer.
pub(crate) fn stats_from_mc(mut mc: montecarlo::McSummary) -> CompletionStats {
    let quantiles = quantiles_from(&mut mc.samples);
    CompletionStats {
        mean: mc.welford.mean(),
        variance: mc.welford.variance(),
        quantiles,
        cost: None,
        sem: mc.welford.sem(),
        samples: mc.welford.count(),
        overhead: None,
    }
}

/// Assemble [`CompletionStats`] from an engine summary — the single
/// definition shared by [`DesEvaluator`] and the study pool
/// ([`crate::study`]), so their results are identical by construction.
pub(crate) fn stats_from_des(mut sum: EngineSummary) -> CompletionStats {
    CompletionStats {
        mean: sum.completion.mean(),
        variance: sum.completion.variance(),
        quantiles: quantiles_from(&mut sum.samples),
        cost: Some(CostStats { busy: sum.busy.mean(), wasted: sum.wasted.mean() }),
        sem: sum.completion.sem(),
        samples: sum.completion.count(),
        overhead: None,
    }
}

// ---------------------------------------------------------------------
// Discrete-event backend
// ---------------------------------------------------------------------

/// Full event engine: models the mechanics the closed forms abstract
/// away — replica cancellation, the scenario's redundancy mode
/// (upfront or speculative), optional failure injection, k-of-B partial
/// aggregation — and accounts busy/wasted worker-seconds, reported as
/// [`CostStats`]. Trials always run through the fixed logical-shard
/// plan (flat event queue + block-sampled launch waves per shard), so
/// results are bit-deterministic for a fixed `(scenario, seed)` pair
/// **for any thread count** — `threads` (all cores under `Default`)
/// only changes wall-clock time.
#[derive(Debug, Clone, Copy)]
pub struct DesEvaluator {
    /// Number of simulated jobs.
    pub trials: u64,
    /// Worker threads (1 = sequential; `Default` = all cores).
    pub threads: usize,
    /// Cancel sibling replicas when a batch completes.
    pub cancellation: bool,
    /// Per-replica crash probability (0 = reliable cluster).
    pub fail_prob: f64,
    /// Stall-detection timeout as a multiple of the mean batch service.
    pub relaunch_timeout_factor: f64,
}

impl Default for DesEvaluator {
    fn default() -> Self {
        Self {
            trials: 20_000,
            threads: auto_threads(),
            cancellation: true,
            fail_prob: 0.0,
            relaunch_timeout_factor: 3.0,
        }
    }
}

impl Evaluator for DesEvaluator {
    fn name(&self) -> &'static str {
        "des"
    }

    fn evaluate(&self, scn: &Scenario) -> anyhow::Result<CompletionStats> {
        anyhow::ensure!(self.trials >= 1, "need at least one trial");
        if let Some(m) = scn.verify_m {
            anyhow::ensure!(
                self.fail_prob == 0.0,
                "des evaluator cannot combine Scenario::verify_m = Some({m}) with crash \
                 injection fail_prob = {}; corruption-under-crash studies run through \
                 the fault-round loop (simulate_fault_rounds / `batchrep chaos`)",
                self.fail_prob
            );
            anyhow::ensure!(
                scn.redundancy == Redundancy::Upfront,
                "des evaluator models m-of-g verification for upfront replication only; \
                 Scenario::verify_m = Some({m}) with Scenario::redundancy = {:?}",
                scn.redundancy
            );
        }
        let cfg = EngineConfig {
            cancellation: self.cancellation,
            redundancy: scn.redundancy,
            fail_prob: self.fail_prob,
            relaunch_timeout_factor: self.relaunch_timeout_factor,
            ..EngineConfig::default()
        };
        let sum = simulate_many_parallel(scn, &cfg, self.trials, scn.seed, self.threads);
        Ok(stats_from_des(sum))
    }
}

// ---------------------------------------------------------------------
// Live backend
// ---------------------------------------------------------------------

/// The real System1: coordinator + worker threads executing compute
/// jobs with injected straggler delays and first-replica-wins
/// cancellation. `Scenario::k_of_b` is consumed live: the round
/// completes at the k-th finished batch and the coordinator cancels the
/// rest. Completion is measured in injected service units (wall time
/// divided by `time_scale`), so the numbers are directly comparable to
/// the other backends.
#[derive(Debug, Clone)]
pub struct LiveEvaluator {
    /// Job rounds to run (each round is one sample).
    pub rounds: u64,
    /// Compute backend worker threads construct.
    pub backend: Backend,
    /// Wall-clock seconds per unit of injected service time.
    pub time_scale: f64,
    /// Dataset rows (clamped up to the worker count).
    pub n_samples: usize,
    /// Model feature dimension.
    pub dim: usize,
    /// Cancel sibling replicas when a batch completes.
    pub cancellation: bool,
    /// Artifact directory for the PJRT backend; `None` = the crate's
    /// default lookup (`$BATCHREP_ARTIFACTS`, then walking up).
    pub artifacts_dir: Option<String>,
}

impl Default for LiveEvaluator {
    fn default() -> Self {
        Self {
            rounds: 30,
            backend: Backend::Mock,
            time_scale: 0.002,
            n_samples: 64,
            dim: 4,
            cancellation: true,
            artifacts_dir: None,
        }
    }
}

impl Evaluator for LiveEvaluator {
    fn name(&self) -> &'static str {
        "live"
    }

    fn evaluate(&self, scn: &Scenario) -> anyhow::Result<CompletionStats> {
        anyhow::ensure!(self.rounds >= 1, "need at least one round");
        anyhow::ensure!(
            scn.redundancy == Redundancy::Upfront,
            "live evaluator models upfront replication only; Scenario::redundancy = {:?}",
            scn.redundancy
        );
        let mut cfg = SystemConfig {
            time_scale: self.time_scale,
            n_samples: self.n_samples.max(scn.n_workers()),
            dim: self.dim,
            cancellation: self.cancellation,
            ..SystemConfig::default()
        };
        cfg.artifacts_dir = self.artifacts_dir.clone().unwrap_or_else(|| {
            crate::runtime::default_artifact_dir().to_string_lossy().to_string()
        });
        let mut coord = Coordinator::from_scenario(scn, cfg, self.backend)?;
        let w = Arc::new(vec![0.0f32; self.dim]);
        let mut run = || -> anyhow::Result<()> {
            for _ in 0..self.rounds {
                coord.run_round(JobSpec::Grad { w: w.clone() })?;
            }
            Ok(())
        };
        let outcome = run();
        let mut welford = Welford::new();
        let mut samples = Samples::with_capacity(coord.metrics.len());
        let mut dispatch = Welford::new();
        let mut wall = Welford::new();
        let mut injected = Welford::new();
        for rec in coord.metrics.records() {
            let units = rec.injected_s / self.time_scale;
            welford.push(units);
            samples.push(units);
            dispatch.push(rec.dispatch_s);
            wall.push(rec.completion_s);
            injected.push(rec.injected_s);
        }
        coord.shutdown();
        outcome?;
        anyhow::ensure!(welford.count() > 0, "live run produced no completed rounds");
        Ok(CompletionStats {
            mean: welford.mean(),
            variance: welford.variance(),
            quantiles: quantiles_from(&mut samples),
            cost: None,
            sem: welford.sem(),
            samples: welford.count(),
            overhead: Some(OverheadStats {
                dispatch_s: dispatch.mean(),
                wall_s: wall.mean(),
                injected_s: injected.mean(),
            }),
        })
    }
}

pub(crate) fn quantiles_from(samples: &mut Samples) -> Vec<(f64, f64)> {
    QUANTILES.iter().filter_map(|&q| samples.quantile(q).map(|v| (q, v))).collect()
}

// ---------------------------------------------------------------------
// Cross-backend validation and generic sweeps
// ---------------------------------------------------------------------

/// Result of a successful [`cross_check`].
#[derive(Debug, Clone)]
pub struct CrossCheck {
    /// First backend's statistics.
    pub a: CompletionStats,
    /// Second backend's statistics.
    pub b: CompletionStats,
    /// `|a.mean − b.mean|`.
    pub mean_diff: f64,
    /// The tolerance the difference was held to.
    pub tolerance: f64,
}

/// Evaluate `scn` under two backends and require their moments to
/// agree: means within `max(4·SE_combined, 0.5% relative)` and, when
/// both estimates are well-resolved, variances within 20% relative —
/// the paper's Fig. 2 theory-vs-simulation validation as an API call.
pub fn cross_check(
    a: &dyn Evaluator,
    b: &dyn Evaluator,
    scn: &Scenario,
) -> anyhow::Result<CrossCheck> {
    let sa = a.evaluate(scn)?;
    let sb = b.evaluate(scn)?;
    cross_check_stats(a.name(), b.name(), sa, sb)
}

/// The statistics half of [`cross_check`]: validate two
/// already-computed estimates of one scenario against each other. Lets
/// callers that obtained their stats elsewhere (e.g. from a deduplicated
/// [`crate::study`] report, where each cell is evaluated once and fanned
/// out) run the same gate without re-evaluating.
pub fn cross_check_stats(
    a_name: &str,
    b_name: &str,
    sa: CompletionStats,
    sb: CompletionStats,
) -> anyhow::Result<CrossCheck> {
    let sem = (sa.sem * sa.sem + sb.sem * sb.sem).sqrt();
    let tolerance = (4.0 * sem).max(0.005 * sa.mean.abs().max(sb.mean.abs()));
    let mean_diff = (sa.mean - sb.mean).abs();
    anyhow::ensure!(
        mean_diff <= tolerance,
        "{a_name} and {b_name} disagree on E[T]: {:.6} vs {:.6} (diff {:.6} > tol {:.6})",
        sa.mean,
        sb.mean,
        mean_diff,
        tolerance
    );
    let resolved = |s: &CompletionStats| s.samples == 0 || s.samples >= 10_000;
    if sa.variance > 0.0 && sb.variance > 0.0 && resolved(&sa) && resolved(&sb) {
        let rel = (sa.variance - sb.variance).abs() / sa.variance.max(sb.variance);
        anyhow::ensure!(
            rel < 0.2,
            "{a_name} and {b_name} disagree on Var[T]: {:.6} vs {:.6}",
            sa.variance,
            sb.variance
        );
    }
    Ok(CrossCheck { a: sa, b: sb, mean_diff, tolerance })
}

/// One point of an evaluator sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Batch count of this point.
    pub b: usize,
    /// The backend's statistics at this point.
    pub stats: CompletionStats,
}

/// Generic sweep driver: evaluate the scenario `make(b)` at every `b`
/// with one backend. The experiment drivers are thin wrappers over
/// this.
pub fn sweep<F>(
    b_values: &[usize],
    ev: &dyn Evaluator,
    mut make: F,
) -> anyhow::Result<Vec<SweepPoint>>
where
    F: FnMut(usize) -> anyhow::Result<Scenario>,
{
    b_values
        .iter()
        .map(|&b| Ok(SweepPoint { b, stats: ev.evaluate(&make(b)?)? }))
        .collect()
}

/// Sweep the paper's canonical balanced-disjoint scenario family over
/// every feasible batch count of `n`.
pub fn paper_sweep(
    n: usize,
    ev: &dyn Evaluator,
    service: &BatchService,
    seed: u64,
) -> anyhow::Result<Vec<SweepPoint>> {
    let bs = crate::assignment::feasible_batch_counts(n);
    sweep(&bs, ev, |b| {
        Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            n,
            b,
            service.clone(),
            seed.wrapping_add(b as u64),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::dist::ServiceSpec;
    use crate::testkit;

    fn paper_scn(n: usize, b: usize, spec: ServiceSpec, seed: u64) -> Scenario {
        Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            n,
            b,
            BatchService::paper(spec),
            seed,
        )
        .unwrap()
    }

    #[test]
    fn analytic_matches_closed_forms() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = paper_scn(24, 6, spec.clone(), 1);
        let st = AnalyticEvaluator.evaluate(&scn).unwrap();
        let cf = analysis::completion_time_stats(24, 6, &spec).unwrap();
        assert!((st.mean - cf.mean).abs() < 1e-12);
        assert!((st.variance - cf.var).abs() < 1e-12);
        for &q in &[0.5, 0.99] {
            let tq = analysis::completion_time_quantile(24, 6, &spec, q).unwrap();
            assert!((st.quantile(q).unwrap() - tq).abs() < 1e-12, "q={q}");
        }
        let cost = st.cost.unwrap();
        let expect = analysis::expected_cost(24, 6, &spec).unwrap();
        assert!((cost.busy - expect).abs() < 1e-9);
        assert!(cost.wasted < cost.busy);
        assert_eq!(st.samples, 0);
        assert_eq!(st.sem, 0.0);
    }

    #[test]
    fn analytic_handles_unbalanced_assignments() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.3);
        let layout = crate::batching::disjoint(12, 4).unwrap();
        let assignment = crate::assignment::skewed(12, 4).unwrap();
        let scn =
            Scenario::new(layout, assignment.clone(), BatchService::paper(spec.clone())).unwrap();
        let st = AnalyticEvaluator.evaluate(&scn).unwrap();
        let via_ie = analysis::assignment_stats(&assignment, &spec, 12).unwrap();
        assert!((st.mean - via_ie.mean).abs() < 1e-9);
        assert!((st.variance - via_ie.var).abs() < 1e-9);
        // Quantiles invert the product-form CDF: median above shift,
        // p999 above p50.
        let p50 = st.quantile(0.5).unwrap();
        let p999 = st.quantile(0.999).unwrap();
        assert!(p50 > 0.9 && p999 > p50, "p50={p50} p999={p999}");
    }

    #[test]
    fn analytic_rejects_out_of_scope_scenarios() {
        let spec = ServiceSpec::pareto(0.5, 2.2);
        let scn = paper_scn(8, 2, spec, 1);
        assert!(AnalyticEvaluator.evaluate(&scn).is_err());
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let spec_scn = paper_scn(8, 2, spec.clone(), 1)
            .with_redundancy(Redundancy::Speculative { deadline_factor: 1.5 });
        assert!(AnalyticEvaluator.evaluate(&spec_scn).is_err());
        let overlap = Scenario::from_policy(
            ReplicationPolicy::OverlappingCyclic,
            8,
            2,
            BatchService::paper(spec),
            1,
        )
        .unwrap();
        assert!(AnalyticEvaluator.evaluate(&overlap).is_err());
    }

    // NOTE: the four-backends-one-scenario and Fig. 2 cross-check
    // acceptance tests live in tests/evaluator_api.rs (public-API
    // surface); they are intentionally not duplicated here.

    #[test]
    fn default_mc_is_multithreaded_and_deterministic() {
        // The default backend shards across all cores, yet two runs of
        // the same (scenario, seed, threads) triple are bit-identical,
        // and both Exp and SExp still cross-check against the closed
        // forms.
        assert!(MonteCarloEvaluator::default().threads >= 1);
        assert_eq!(MonteCarloEvaluator::default().threads, MonteCarloEvaluator::auto_threads());
        let ev = MonteCarloEvaluator { trials: 200_000, ..MonteCarloEvaluator::default() };
        let sexp_scn = paper_scn(24, 4, ServiceSpec::shifted_exp(1.0, 0.2), 5);
        let a = ev.evaluate(&sexp_scn).unwrap();
        let b = ev.evaluate(&sexp_scn).unwrap();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.variance.to_bits(), b.variance.to_bits());
        assert_eq!(a.sem.to_bits(), b.sem.to_bits());
        assert_eq!(a.quantiles, b.quantiles);
        assert_eq!(a.samples, 200_000);
        cross_check(&AnalyticEvaluator, &ev, &sexp_scn).unwrap();
        let exp_scn = paper_scn(24, 4, ServiceSpec::exp(1.3), 6);
        cross_check(&AnalyticEvaluator, &ev, &exp_scn).unwrap();
    }

    #[test]
    fn paper_sweep_is_served_from_the_analytic_memo() {
        // Acceptance gate: sweeping a ≥ 50-point ∆µ grid twice must not
        // recompute any closed form on the second pass (counters are
        // thread-local, so this arithmetic is exact).
        let grid: Vec<f64> = (0..55).map(|i| 0.017 + 0.037 * i as f64).collect();
        let run_grid = |grid: &[f64]| {
            for &dm in grid {
                let service = BatchService::paper(ServiceSpec::shifted_exp(1.0, dm));
                let pts = paper_sweep(36, &AnalyticEvaluator, &service, 1).unwrap();
                assert_eq!(pts.len(), crate::assignment::feasible_batch_counts(36).len());
            }
        };
        let (_, m0) = analysis::ct_cache_counters();
        run_grid(&grid);
        let (_, m1) = analysis::ct_cache_counters();
        assert!(m1 > m0, "first pass must populate the memo");
        run_grid(&grid);
        let (_, m2) = analysis::ct_cache_counters();
        assert_eq!(m2, m1, "second pass must be all cache hits");
    }

    #[test]
    fn cross_check_rejects_disagreement() {
        struct Wrong;
        impl Evaluator for Wrong {
            fn name(&self) -> &'static str {
                "wrong"
            }
            fn evaluate(&self, _scn: &Scenario) -> anyhow::Result<CompletionStats> {
                Ok(CompletionStats {
                    mean: 1e6,
                    variance: 1.0,
                    quantiles: Vec::new(),
                    cost: None,
                    sem: 0.0,
                    samples: 0,
                    overhead: None,
                })
            }
        }
        let scn = paper_scn(8, 2, ServiceSpec::shifted_exp(1.0, 0.2), 3);
        assert!(cross_check(&AnalyticEvaluator, &Wrong, &scn).is_err());
    }

    #[test]
    fn des_cost_matches_analytic_cost() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = paper_scn(12, 3, spec, 17);
        let exact = AnalyticEvaluator.evaluate(&scn).unwrap().cost.unwrap();
        let sim = DesEvaluator { trials: 40_000, ..DesEvaluator::default() }
            .evaluate(&scn)
            .unwrap()
            .cost
            .unwrap();
        assert!(
            (sim.busy - exact.busy).abs() / exact.busy < 0.03,
            "busy: sim {} vs exact {}",
            sim.busy,
            exact.busy
        );
        assert!(
            (sim.wasted - exact.wasted).abs() / exact.wasted.max(1e-9) < 0.05,
            "wasted: sim {} vs exact {}",
            sim.wasted,
            exact.wasted
        );
    }

    #[test]
    fn des_cross_checks_against_analytic_on_fig2_scale() {
        // The acceptance gate: the event engine (upfront, cancellation
        // on, no failures) agrees with the exact closed form on E[T]
        // within Monte-Carlo error on the fig2-scale scenario.
        let scn = paper_scn(24, 4, ServiceSpec::shifted_exp(1.0, 0.2), 42);
        let des = DesEvaluator { trials: 150_000, threads: 2, ..DesEvaluator::default() };
        let ck = cross_check(&AnalyticEvaluator, &des, &scn).unwrap();
        assert!(ck.mean_diff <= ck.tolerance);
        assert_eq!(ck.b.samples, 150_000);
        // Quantiles land on the closed-form inverse CDF too.
        let (pa, pd) = (ck.a.quantile(0.5).unwrap(), ck.b.quantile(0.5).unwrap());
        assert!((pa - pd).abs() / pa < 0.02, "p50 analytic {pa} vs des {pd}");
    }

    #[test]
    fn des_evaluator_default_is_parallel_and_deterministic() {
        // The default backend shards across all cores, yet two runs of
        // the same (scenario, seed, threads) triple are bit-identical.
        assert_eq!(DesEvaluator::default().threads, auto_threads());
        let scn = paper_scn(12, 3, ServiceSpec::shifted_exp(1.0, 0.2), 7);
        let ev = DesEvaluator { trials: 30_000, threads: 4, ..DesEvaluator::default() };
        let a = ev.evaluate(&scn).unwrap();
        let b = ev.evaluate(&scn).unwrap();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.variance.to_bits(), b.variance.to_bits());
        assert_eq!(a.sem.to_bits(), b.sem.to_bits());
        assert_eq!(a.quantiles, b.quantiles);
        let (ca, cb) = (a.cost.unwrap(), b.cost.unwrap());
        assert_eq!(ca.busy.to_bits(), cb.busy.to_bits());
        assert_eq!(ca.wasted.to_bits(), cb.wasted.to_bits());
        // And the sharded run agrees with a sequential one statistically.
        let seq = DesEvaluator { trials: 30_000, threads: 1, ..DesEvaluator::default() }
            .evaluate(&scn)
            .unwrap();
        assert!(
            (a.mean - seq.mean).abs() < 4.0 * (a.sem + seq.sem).max(1e-3),
            "parallel {} vs sequential {}",
            a.mean,
            seq.mean
        );
    }

    #[test]
    fn k_of_b_is_consumed_by_every_capable_backend() {
        // The partial-aggregation scenario field routes through the
        // analytic closed form, the MC sampler, and the DES engine; the
        // live backend refuses rather than silently mis-evaluating.
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = paper_scn(24, 6, spec.clone(), 9).with_k_of_b(3).unwrap();
        let exact = AnalyticEvaluator.evaluate(&scn).unwrap();
        let cf = analysis::partial_completion_stats(24, 6, 3, &spec).unwrap();
        assert!((exact.mean - cf.mean).abs() < 1e-12);
        assert!((exact.variance - cf.var).abs() < 1e-12);
        assert!(exact.cost.is_none() && exact.quantiles.is_empty());
        let mc = MonteCarloEvaluator { trials: 100_000, threads: 2 }.evaluate(&scn).unwrap();
        assert!(
            (mc.mean - exact.mean).abs() < 4.0 * mc.sem.max(1e-3),
            "mc {} vs exact {}",
            mc.mean,
            exact.mean
        );
        let des = DesEvaluator { trials: 60_000, threads: 2, ..DesEvaluator::default() }
            .evaluate(&scn)
            .unwrap();
        assert!(
            (des.mean - exact.mean).abs() < 4.0 * des.sem.max(1e-3),
            "des {} vs exact {}",
            des.mean,
            exact.mean
        );
        // Partial aggregation leaves the unneeded batches' replicas as
        // pure redundancy cost, which only the engine accounts.
        assert!(des.cost.unwrap().wasted > 0.0);
        // k = B routes through the ordinary closed form (quantiles and
        // cost included) and matches the unrestricted scenario exactly.
        let full = paper_scn(24, 6, spec.clone(), 9);
        let kfull = paper_scn(24, 6, spec, 9).with_k_of_b(6).unwrap();
        let a = AnalyticEvaluator.evaluate(&full).unwrap();
        let b = AnalyticEvaluator.evaluate(&kfull).unwrap();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert!(b.cost.is_some() && !b.quantiles.is_empty());
    }

    #[test]
    fn live_backend_consumes_k_of_b() {
        // The live coordinator completes a round at the k-th finished
        // batch; its injected completion must track the k-of-B closed
        // form, and waiting for fewer batches must be measurably faster.
        let spec = ServiceSpec::shifted_exp(2.0, 0.1);
        let live = LiveEvaluator {
            rounds: 30,
            time_scale: 0.01,
            n_samples: 32,
            ..LiveEvaluator::default()
        };
        let scn_k = paper_scn(8, 4, spec.clone(), 31).with_k_of_b(2).unwrap();
        let st_k = live.evaluate(&scn_k).unwrap();
        // The live backend is the one evaluator that reports wall-clock
        // overhead: dispatch is part of the wall round, the wall round
        // is at least the injected service it slept through.
        let ov = st_k.overhead.expect("live backend reports OverheadStats");
        assert!(ov.dispatch_s >= 0.0 && ov.dispatch_s <= ov.wall_s, "{ov:?}");
        assert!(ov.wall_s >= ov.injected_s, "{ov:?}");
        assert!(ov.overhead_s() >= 0.0 && ov.overhead_frac() < 1.0, "{ov:?}");
        let cf_k = analysis::partial_completion_stats(8, 4, 2, &spec).unwrap();
        assert!(
            (st_k.mean - cf_k.mean).abs() < (5.0 * st_k.sem).max(0.2 * cf_k.mean),
            "live k-of-B {} vs closed form {}",
            st_k.mean,
            cf_k.mean
        );
        let st_full = live.evaluate(&paper_scn(8, 4, spec, 31)).unwrap();
        assert!(
            st_k.mean < st_full.mean,
            "k=2 of 4 must beat full completion: {} !< {}",
            st_k.mean,
            st_full.mean
        );
    }

    #[test]
    fn analytic_accepts_worker_speeds() {
        // Exponential: exact per-worker-rate order statistics, zero sem.
        let n = 12usize;
        let speeds: Vec<f64> = (0..n).map(|w| 0.7 + 0.1 * w as f64).collect();
        let exp_scn = paper_scn(n, 3, ServiceSpec::exp(1.1), 3)
            .with_speeds(speeds.clone())
            .unwrap();
        let st = AnalyticEvaluator.evaluate(&exp_scn).unwrap();
        let bounds = analysis::hetero_completion_bounds(
            &exp_scn.assignment,
            &exp_scn.service.spec,
            n as u64,
            &speeds,
        )
        .unwrap();
        assert!(bounds.exact);
        assert_eq!(st.mean.to_bits(), bounds.mid_mean().to_bits());
        assert_eq!(st.sem, 0.0);
        // Shifted-Exponential: midpoint + sem-encoded interval, and the
        // stock cross_check accepts the MC backend inside the bound.
        let sexp_scn = paper_scn(n, 3, ServiceSpec::shifted_exp(1.0, 0.4), 3)
            .with_speeds(speeds)
            .unwrap();
        let st = AnalyticEvaluator.evaluate(&sexp_scn).unwrap();
        assert!(st.sem > 0.0, "bounded result must carry its half-width");
        let mc = MonteCarloEvaluator { trials: 80_000, threads: 2 };
        cross_check(&AnalyticEvaluator, &mc, &sexp_scn).unwrap();
    }

    #[test]
    fn analytic_rejections_name_the_offending_field() {
        let err = |scn: &Scenario| {
            AnalyticEvaluator.evaluate(scn).unwrap_err().to_string()
        };
        // Unsupported service family names the spec.
        let msg = err(&paper_scn(8, 2, ServiceSpec::pareto(0.5, 2.2), 1));
        assert!(msg.contains("Scenario::service.spec"), "{msg}");
        assert!(msg.contains("pareto:0.5,2.2"), "{msg}");
        // Unsupported redundancy names the mode and its parameter.
        let spec_scn = paper_scn(8, 2, ServiceSpec::exp(1.0), 1)
            .with_redundancy(Redundancy::Speculative { deadline_factor: 1.5 });
        let msg = err(&spec_scn);
        assert!(msg.contains("Scenario::redundancy"), "{msg}");
        assert!(msg.contains("Speculative"), "{msg}");
        assert!(msg.contains("1.5"), "{msg}");
        // worker_speeds × partial aggregation names both fields.
        let hetero_partial = paper_scn(8, 4, ServiceSpec::exp(1.0), 1)
            .with_speeds(vec![1.25; 8])
            .unwrap()
            .with_k_of_b(2)
            .unwrap();
        let msg = err(&hetero_partial);
        assert!(msg.contains("Scenario::worker_speeds"), "{msg}");
        assert!(msg.contains("Scenario::k_of_b = Some(2)"), "{msg}");
        assert!(msg.contains("1.250"), "{msg}");
    }

    #[test]
    fn analytic_verify_m_matches_closed_forms_and_simulation() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = paper_scn(12, 4, spec.clone(), 3).with_verify_m(2).unwrap();
        let st = AnalyticEvaluator.evaluate(&scn).unwrap();
        let cf = analysis::verified_completion_stats(12, 4, 2, 4, &spec).unwrap();
        assert_eq!(st.mean.to_bits(), cf.mean.to_bits());
        assert_eq!(st.variance.to_bits(), cf.var.to_bits());
        let cost = st.cost.unwrap();
        let (busy, wasted) = analysis::verified_cost_stats(12, 4, 2, &spec).unwrap();
        assert_eq!(cost.busy.to_bits(), busy.to_bits());
        assert_eq!(cost.wasted.to_bits(), wasted.to_bits());
        assert_eq!((st.samples, st.sem), (0, 0.0));
        // Waiting for the 2nd vote costs latency over first-replica-wins.
        let base = AnalyticEvaluator.evaluate(&paper_scn(12, 4, spec.clone(), 3)).unwrap();
        assert!(st.mean > base.mean, "verified {} !> unverified {}", st.mean, base.mean);
        // The simulation backends consume the same scenario and agree.
        let mc = MonteCarloEvaluator { trials: 60_000, threads: 2 }.evaluate(&scn).unwrap();
        assert!(
            (mc.mean - st.mean).abs() < 4.0 * mc.sem.max(1e-3),
            "mc {} vs exact {}",
            mc.mean,
            st.mean
        );
        let des = DesEvaluator { trials: 60_000, threads: 2, ..DesEvaluator::default() }
            .evaluate(&scn)
            .unwrap();
        assert!(
            (des.mean - st.mean).abs() < 4.0 * des.sem.max(1e-3),
            "des {} vs exact {}",
            des.mean,
            st.mean
        );
        // k-of-B composes with m-of-g: the k-th verified batch ends the
        // job, faster than full verification, priced without cost.
        let scn_k =
            paper_scn(12, 4, spec.clone(), 3).with_verify_m(2).unwrap().with_k_of_b(3).unwrap();
        let st_k = AnalyticEvaluator.evaluate(&scn_k).unwrap();
        let cf_k = analysis::verified_completion_stats(12, 4, 2, 3, &spec).unwrap();
        assert_eq!(st_k.mean.to_bits(), cf_k.mean.to_bits());
        assert!(st_k.cost.is_none());
        assert!(st_k.mean < st.mean);
        let mc_k = MonteCarloEvaluator { trials: 60_000, threads: 2 }.evaluate(&scn_k).unwrap();
        assert!(
            (mc_k.mean - st_k.mean).abs() < 4.0 * mc_k.sem.max(1e-3),
            "mc k-of-B {} vs exact {}",
            mc_k.mean,
            st_k.mean
        );
    }

    #[test]
    fn verify_m_refusals_name_the_offending_fields() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let scn = paper_scn(12, 4, spec.clone(), 3).with_verify_m(2).unwrap();
        // DES refuses verification combined with crash injection.
        let ev = DesEvaluator { fail_prob: 0.1, ..DesEvaluator::default() };
        let msg = ev.evaluate(&scn).unwrap_err().to_string();
        assert!(msg.contains("Scenario::verify_m"), "{msg}");
        assert!(msg.contains("fail_prob"), "{msg}");
        // Analytic refuses heterogeneous speeds under verification.
        let hetero = paper_scn(12, 4, spec.clone(), 3)
            .with_speeds(vec![1.0; 12])
            .unwrap()
            .with_verify_m(2)
            .unwrap();
        let msg = AnalyticEvaluator.evaluate(&hetero).unwrap_err().to_string();
        assert!(msg.contains("Scenario::verify_m"), "{msg}");
        assert!(msg.contains("worker_speeds"), "{msg}");
        // The verified closed form is limited to N <= 32.
        let big = paper_scn(36, 6, spec, 3).with_verify_m(2).unwrap();
        let msg = AnalyticEvaluator.evaluate(&big).unwrap_err().to_string();
        assert!(msg.contains("32"), "{msg}");
    }

    #[test]
    fn des_models_speculative_redundancy_from_the_scenario() {
        let spec = ServiceSpec::shifted_exp(1.0, 0.2);
        let upfront = paper_scn(12, 3, spec.clone(), 5);
        let reactive = paper_scn(12, 3, spec, 5)
            .with_redundancy(Redundancy::Speculative { deadline_factor: 1.5 });
        let ev = DesEvaluator { trials: 20_000, ..DesEvaluator::default() };
        let up = ev.evaluate(&upfront).unwrap();
        let re = ev.evaluate(&reactive).unwrap();
        assert!(re.mean > up.mean, "reactive {} !> upfront {}", re.mean, up.mean);
        assert!(
            re.cost.unwrap().busy < up.cost.unwrap().busy,
            "reactive must be cheaper"
        );
    }

    #[test]
    fn sweep_reproduces_theorem2_monotonicity() {
        let service = BatchService::paper(ServiceSpec::exp(1.0));
        let points = paper_sweep(12, &AnalyticEvaluator, &service, 1).unwrap();
        assert_eq!(points.len(), crate::assignment::feasible_batch_counts(12).len());
        for w in points.windows(2) {
            assert!(w[1].stats.mean > w[0].stats.mean, "Theorem 2: E[T] increasing in B");
        }
    }

    #[test]
    fn prop_analytic_and_montecarlo_agree() {
        // For random (N, B | N, exp-family spec) the two backends'
        // means agree within 3 standard errors (with a 1% relative
        // floor so near-deterministic cases are not over-tight).
        testkit::check("evaluator-analytic-vs-mc", 20, |g| {
            let n = *g.pick(&[4usize, 8, 12, 24]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let spec = if g.coin(0.5) {
                ServiceSpec::exp(g.f64_in(0.5, 2.0))
            } else {
                ServiceSpec::shifted_exp(g.f64_in(0.5, 2.0), g.f64_in(0.0, 1.0))
            };
            let seed = g.u64_in(0, 1 << 40);
            let scn = paper_scn(n, b, spec, seed);
            let exact = AnalyticEvaluator.evaluate(&scn).unwrap();
            let mc = MonteCarloEvaluator { trials: 60_000, threads: 1 }
                .evaluate(&scn)
                .unwrap();
            let tol = (3.0 * mc.sem).max(0.01 * exact.mean);
            assert!(
                (exact.mean - mc.mean).abs() <= tol,
                "N={n} B={b}: analytic {} vs mc {} (tol {tol})",
                exact.mean,
                mc.mean
            );
        });
    }

    #[test]
    fn prop_policies_build_valid_scenarios() {
        testkit::check("replication-policy-build", 100, |g| {
            let n = *g.pick(&[4usize, 8, 12, 24]);
            let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
            let b = *g.pick(&divisors);
            let policy = *g.pick(ReplicationPolicy::all());
            let mut rng = g.rng();
            let (layout, assignment) = policy.build(n, b, &mut rng).unwrap();
            layout.validate().unwrap();
            assignment.validate().unwrap();
            assert_eq!(layout.n_batches(), assignment.n_batches);
        });
    }

    #[test]
    fn policy_names_round_trip() {
        for p in ReplicationPolicy::all() {
            assert_eq!(ReplicationPolicy::parse(p.name()).unwrap(), *p);
        }
        assert!(ReplicationPolicy::parse("custom").is_err());
        // Unknown policies name the value and list what is accepted.
        let msg = ReplicationPolicy::parse("nope").unwrap_err().to_string();
        assert!(msg.contains("'nope'"), "{msg}");
        for p in ReplicationPolicy::all() {
            assert!(msg.contains(p.name()), "accepted list missing {}: {msg}", p.name());
        }
    }
}
