//! Minimal property-based testing framework (a `proptest` stand-in for
//! the offline environment).
//!
//! A property is a closure over a [`Gen`]; [`check`] runs it over many
//! random cases and, on failure, replays with the failing seed while
//! shrinking every integer drawn toward its lower bound, reporting the
//! smallest still-failing case it finds.
//!
//! ```
//! use batchrep::testkit;
//! testkit::check("reverse-twice-id", 200, |g| {
//!     let n = g.usize_in(0, 50);
//!     let v: Vec<i64> = (0..n).map(|_| g.i64_in(-5, 5)).collect();
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use crate::util::rng::Rng;
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Case generator handed to properties. Records integer draws so that the
/// shrinker can replay them with smaller values.
#[derive(Debug)]
pub struct Gen {
    rng: Rng,
    /// Recorded (value, lo) pairs for every bounded integer draw.
    draws: RefCell<Vec<(i64, i64)>>,
    /// When replaying under shrink: overrides for draw indices.
    overrides: Vec<Option<i64>>,
    cursor: RefCell<usize>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            draws: RefCell::new(Vec::new()),
            overrides: Vec::new(),
            cursor: RefCell::new(0),
        }
    }

    fn with_overrides(seed: u64, overrides: Vec<Option<i64>>) -> Self {
        let mut g = Self::new(seed);
        g.overrides = overrides;
        g
    }

    fn record(&self, lo: i64, hi: i64, sampled: i64) -> i64 {
        let idx = *self.cursor.borrow();
        *self.cursor.borrow_mut() += 1;
        // Clamp overrides to the *live* bounds of this replay: earlier
        // shrunk draws can tighten later draws' ranges (e.g. a smaller
        // N shrinks the divisor list a later pick indexes), and an
        // unclamped stale override would panic inside generation and
        // corrupt the minimal-case report.
        let v = match self.overrides.get(idx).copied().flatten() {
            Some(o) => o.clamp(lo, hi),
            None => sampled,
        };
        self.draws.borrow_mut().push((v, lo));
        v
    }

    /// Integer in inclusive `[lo, hi]`, shrinkable toward `lo`.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        let sampled = self.rng.int_in(lo, hi);
        self.record(lo, hi, sampled)
    }

    /// `usize` in inclusive `[lo, hi]`, shrinkable toward `lo`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.i64_in(lo as i64, hi as i64) as usize
    }

    /// `u64` in inclusive `[lo, hi]`, shrinkable toward `lo`.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.i64_in(lo as i64, hi as i64) as u64
    }

    /// Uniform float in `[lo, hi)` (not shrunk).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_in(lo, hi)
    }

    /// Biased coin (not shrunk).
    pub fn coin(&mut self, p: f64) -> bool {
        self.rng.coin(p)
    }

    /// Pick one element of a slice (index is shrunk toward 0).
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize_in(0, xs.len() - 1);
        &xs[i]
    }

    /// Fresh RNG seeded from this case (for bulk data).
    pub fn rng(&mut self) -> Rng {
        Rng::new(self.rng.next_u64())
    }
}

/// Run `cases` random cases of `prop`. On failure, shrink integer draws
/// and panic with the smallest failing case's diagnostics.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    check_with(name, cases, None, prop)
}

/// [`check`] with an explicit base seed (the conformance harness plumbs
/// its `--seed` through here). Precedence: `base_seed` argument >
/// `BATCHREP_PROP_SEED` env override > the name hash, so a failure's
/// printed seed reproduces the identical case sequence either way.
pub fn check_with<F>(name: &str, cases: u64, base_seed: Option<u64>, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    // Deterministic per-property seed: hash the name (the shared
    // FNV-1a — same constants as always, so replay seeds are stable).
    let h = crate::util::rng::fnv1a(name.bytes());
    // Allow override for reproducing failures.
    let base = base_seed
        .or_else(|| {
            std::env::var("BATCHREP_PROP_SEED").ok().and_then(|s| s.parse().ok())
        })
        .unwrap_or(h);

    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let draws = g.draws.borrow().clone();
            let (min_draws, msg) = shrink(seed, &draws, &prop, payload_msg(&*payload));
            panic!(
                "property '{name}' failed (seed={seed}, case={case})\n  \
                 minimal draws: {min_draws:?}\n  failure: {msg}\n  \
                 reproduce with BATCHREP_PROP_SEED={seed}"
            );
        }
    }
}

/// Best-effort text of a caught panic payload (shared with the
/// conformance harness's matrix runner).
pub(crate) fn payload_msg(payload: &dyn std::any::Any) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Greedy per-draw shrink: repeatedly try to lower each recorded integer
/// draw (binary search toward its lower bound), keeping changes that
/// still fail. Returns the minimal failing draw vector and its message.
fn shrink<F>(
    seed: u64,
    original: &[(i64, i64)],
    prop: &F,
    first_msg: String,
) -> (Vec<i64>, String)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let mut best: Vec<Option<i64>> = original.iter().map(|&(v, _)| Some(v)).collect();
    let lows: Vec<i64> = original.iter().map(|&(_, lo)| lo).collect();
    let mut best_msg = first_msg;

    let fails = |ovr: &Vec<Option<i64>>| -> Option<String> {
        let mut g = Gen::with_overrides(seed, ovr.clone());
        match catch_unwind(AssertUnwindSafe(|| prop(&mut g))) {
            Ok(()) => None,
            Err(p) => Some(payload_msg(&*p)),
        }
    };

    // Per-draw binary search for the smallest still-failing value
    // (exact for monotone failure regions, a good heuristic otherwise).
    let mut budget = 600usize;
    for i in 0..best.len() {
        let cur = match best[i] {
            Some(v) => v,
            None => continue,
        };
        let lo = lows[i];
        let mut lo_bound = lo; // candidates in [lo_bound, hi_fail)
        let mut hi_fail = cur; // known-failing value
        while lo_bound < hi_fail && budget > 0 {
            let cand = lo_bound + (hi_fail - lo_bound) / 2;
            if cand == hi_fail {
                break;
            }
            budget -= 1;
            let mut trial = best.clone();
            trial[i] = Some(cand);
            if let Some(m) = fails(&trial) {
                hi_fail = cand;
                best = trial;
                best_msg = m;
            } else {
                lo_bound = cand + 1;
            }
        }
    }
    (best.iter().map(|v| v.unwrap_or(0)).collect(), best_msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 100, |g| {
            let a = g.i64_in(-1000, 1000);
            let b = g.i64_in(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("find-large", 200, |g| {
                let n = g.i64_in(0, 1000);
                assert!(n < 500, "n too large: {n}");
            })
        }));
        let msg = payload_msg(&*r.unwrap_err());
        assert!(msg.contains("find-large"), "{msg}");
        // The shrinker binary-searches to the exact failure boundary.
        assert!(msg.contains("minimal draws: [500]"), "{msg}");
        assert!(msg.contains("n too large: 500"), "{msg}");
    }

    #[test]
    fn shrink_reaches_boundary() {
        // Directly exercise shrink(): property fails iff first draw >= 500.
        let prop = |g: &mut Gen| {
            let n = g.i64_in(0, 1000);
            assert!(n < 500);
        };
        // Find a failing seed.
        let mut seed = 1;
        loop {
            let mut g = Gen::new(seed);
            if catch_unwind(AssertUnwindSafe(|| prop(&mut g))).is_err() {
                let draws = g.draws.borrow().clone();
                let (min_draws, _) = shrink(seed, &draws, &prop, String::new());
                // Binary search finds the exact boundary of the
                // monotone failure region [500, 1000].
                assert_eq!(min_draws[0], 500);
                break;
            }
            seed += 1;
        }
    }

    #[test]
    fn shrink_clamps_dependent_draw_overrides() {
        // The second draw's range depends on the first: when the
        // shrinker lowers n, the stale index override for the pick must
        // clamp into the new range instead of panicking inside
        // generation and hijacking the minimal-case report.
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("dependent-draws", 300, |g| {
                let n = g.usize_in(1, 50);
                let xs: Vec<usize> = (0..n).collect();
                let x = *g.pick(&xs);
                assert!(n < 20, "planted: n={n} x={x}");
            })
        }));
        let msg = payload_msg(&*r.unwrap_err());
        assert!(msg.contains("planted: n=20"), "must shrink to the boundary: {msg}");
        assert!(!msg.contains("index out of bounds"), "{msg}");
    }

    #[test]
    fn explicit_base_seed_reproduces_the_reported_failure() {
        // check_with(seed) must replay the exact case sequence: the
        // failing seed printed by one run, fed back as the base seed,
        // reproduces the same minimal case in case 0 position.
        let prop = |g: &mut Gen| {
            let n = g.i64_in(0, 1000);
            assert!(n < 700, "too big: {n}");
        };
        let first = catch_unwind(AssertUnwindSafe(|| check("seeded-repro", 300, prop)));
        let msg = payload_msg(&*first.unwrap_err());
        let seed: u64 = msg
            .split("seed=")
            .nth(1)
            .and_then(|s| s.split(|c: char| !c.is_ascii_digit()).next())
            .and_then(|s| s.parse().ok())
            .expect("failure message must carry a replay seed");
        let again = catch_unwind(AssertUnwindSafe(|| {
            check_with("seeded-repro", 1, Some(seed), prop)
        }));
        let msg2 = payload_msg(&*again.unwrap_err());
        assert!(msg2.contains("minimal draws: [700]"), "{msg2}");
        assert!(msg2.contains("case=0"), "{msg2}");
    }

    #[test]
    fn shrinker_reports_the_smallest_planted_n() {
        // A planted invariant over a scenario-shaped draw: "N < 17".
        // Whatever N the random case trips on, the shrinker must walk it
        // down to the exact boundary and report the minimal failing N —
        // the guarantee the conformance generator's failures rely on.
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("planted-min-n", 400, |g| {
                let n = g.usize_in(2, 64);
                // Unrelated draws must not confuse the per-draw shrink.
                let _b = g.usize_in(1, n);
                let _seed = g.u64_in(0, 1 << 40);
                assert!(n < 17, "planted invariant violated at N={n}");
            })
        }));
        let msg = payload_msg(&*r.unwrap_err());
        assert!(
            msg.contains("planted invariant violated at N=17"),
            "must re-report at the minimal case: {msg}"
        );
        assert!(msg.contains("reproduce with BATCHREP_PROP_SEED="), "{msg}");
    }

    #[test]
    fn shrinker_minimizes_interacting_draws_to_the_boundary() {
        // Two interacting draws, failure region a + b >= 100: greedy
        // per-draw binary search lands exactly on the boundary sum.
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("planted-sum", 200, |g| {
                let a = g.i64_in(0, 100);
                let b = g.i64_in(0, 100);
                assert!(a + b < 100, "sum {}", a + b);
            })
        }));
        let msg = payload_msg(&*r.unwrap_err());
        // The minimal draws line holds the two shrunk values; their sum
        // is exactly the boundary.
        let draws: Vec<i64> = msg
            .split("minimal draws: [")
            .nth(1)
            .and_then(|s| s.split(']').next())
            .map(|s| {
                s.split(',')
                    .filter_map(|t| t.trim().parse().ok())
                    .collect()
            })
            .unwrap_or_default();
        assert_eq!(draws.len(), 2, "{msg}");
        assert_eq!(draws[0] + draws[1], 100, "not minimal: {msg}");
        assert!(msg.contains("sum 100"), "{msg}");
    }

    #[test]
    fn gen_bounds_respected() {
        check("bounds", 300, |g| {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
            let y = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&y));
        });
    }
}
