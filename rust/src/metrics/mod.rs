//! Runtime metrics for the live coordinator: per-job completion records,
//! latency histograms, and report generation.

use crate::util::stats::{LogHistogram, Samples, Welford};
use crate::util::table::{fmt_f, Table};

/// Record of one completed job (one round of the distributed compute).
#[derive(Debug, Clone, Copy)]
pub struct JobRecord {
    /// Job (round) index.
    pub job_id: u64,
    /// Wall-clock completion time, seconds.
    pub completion_s: f64,
    /// Injected (simulated-service) completion time, seconds.
    pub injected_s: f64,
    /// Wall-clock seconds from round start until the last task of the
    /// round was handed to its worker channel (sampling + dispatch) —
    /// one component of the wall-vs-injected overhead the
    /// `LiveEvaluator` surfaces as `OverheadStats`.
    pub dispatch_s: f64,
    /// Number of replica tasks dispatched.
    pub dispatched: u64,
    /// Replica results that arrived after their batch was already
    /// complete (redundant deliveries).
    pub redundant: u64,
    /// Replica tasks cancelled before finishing.
    pub cancelled: u64,
}

/// Run-wide totals of fault and recovery events (the sum of every
/// round's [`crate::coordinator::RoundEvents`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTotals {
    /// Worker crashes observed (hand-armed or plan-scheduled).
    pub crashes: u64,
    /// Dead workers respawned.
    pub respawns: u64,
    /// Speculative deadline relaunches dispatched.
    pub relaunches: u64,
    /// Degraded-mode re-plans plus detected-but-unrecoverable vote
    /// rounds.
    pub degradations: u64,
    /// Tasks dropped before dispatch by the fault plan.
    pub dropped: u64,
    /// Replicas dispatched with a corruption injection.
    pub corrupted: u64,
    /// Replicas flagged by the m-of-g vote.
    pub flagged: u64,
    /// Worker quarantines (strike budget exhausted).
    pub quarantined: u64,
}

impl FaultTotals {
    /// Whether any fault-related event occurred during the run.
    pub fn any(&self) -> bool {
        self.crashes
            + self.respawns
            + self.relaunches
            + self.degradations
            + self.dropped
            + self.corrupted
            + self.flagged
            + self.quarantined
            > 0
    }
}

/// Aggregated metrics over a run.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    records: Vec<JobRecord>,
    wall: Welford,
    injected: Welford,
    // Incrementally maintained copy of the wall-clock samples: quantile
    // queries sort lazily (and only re-sort after new pushes) instead of
    // rebuilding + re-sorting a fresh Samples on every call.
    wall_samples: Samples,
    hist: LogHistogram,
    faults: FaultTotals,
}

impl Default for RunMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl RunMetrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self {
            records: Vec::new(),
            wall: Welford::new(),
            injected: Welford::new(),
            wall_samples: Samples::new(),
            hist: LogHistogram::for_latency(),
            faults: FaultTotals::default(),
        }
    }

    /// Fold one round's fault/recovery event counters into the run
    /// totals.
    pub fn note_fault_events(&mut self, e: &crate::coordinator::RoundEvents) {
        self.faults.crashes += e.crashes;
        self.faults.respawns += e.respawns;
        self.faults.relaunches += e.relaunches;
        self.faults.degradations += e.degradations;
        self.faults.dropped += e.dropped;
        self.faults.corrupted += e.corrupted;
        self.faults.flagged += e.flagged;
        self.faults.quarantined += e.quarantined;
    }

    /// Run-wide fault/recovery totals.
    pub fn fault_totals(&self) -> FaultTotals {
        self.faults
    }

    /// Record a completed job.
    pub fn push(&mut self, rec: JobRecord) {
        self.wall.push(rec.completion_s);
        self.injected.push(rec.injected_s);
        self.wall_samples.push(rec.completion_s);
        self.hist.record(rec.completion_s);
        self.records.push(rec);
    }

    /// Number of recorded jobs.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing is recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Mean wall-clock completion.
    pub fn mean_wall(&self) -> f64 {
        self.wall.mean()
    }

    /// Mean injected completion.
    pub fn mean_injected(&self) -> f64 {
        self.injected.mean()
    }

    /// Wall-clock completion variance.
    pub fn var_wall(&self) -> f64 {
        self.wall.variance()
    }

    /// Exact wall-clock quantile over all recorded jobs; `None` when no
    /// job has been recorded (the same empty-sample contract as
    /// [`Samples::quantile`] / [`LogHistogram::quantile`] — an empty run
    /// has no p99, and `0.0` used to masquerade as one). Sorts lazily:
    /// repeated queries on unchanged records are O(1) after the first.
    pub fn quantile_wall(&mut self, q: f64) -> Option<f64> {
        self.wall_samples.quantile(q)
    }

    /// Approximate quantile from the streaming histogram (O(1) memory
    /// path used when records are dropped); `None` when empty.
    pub fn quantile_hist(&self, q: f64) -> Option<f64> {
        self.hist.quantile(q)
    }

    /// Access all records.
    pub fn records(&self) -> &[JobRecord] {
        &self.records
    }

    /// Dispatch/cancel/redundancy totals.
    pub fn totals(&self) -> (u64, u64, u64) {
        let mut d = 0;
        let mut r = 0;
        let mut c = 0;
        for rec in &self.records {
            d += rec.dispatched;
            r += rec.redundant;
            c += rec.cancelled;
        }
        (d, r, c)
    }

    /// Summary table for reports. `&mut` because quantiles sort the
    /// sample cache lazily; on an empty run the quantile rows render as
    /// `-` rather than a fabricated `0.0`.
    pub fn summary_table(&mut self, title: &str) -> Table {
        let p50 = self.quantile_wall(0.5);
        let p99 = self.quantile_wall(0.99);
        let fmt_q = |v: Option<f64>| v.map(|x| fmt_f(x, 6)).unwrap_or_else(|| "-".into());
        let mut t = Table::new(title, &["metric", "value"]);
        let (d, r, c) = self.totals();
        t.row(vec!["jobs".into(), self.len().to_string()]);
        t.row(vec!["mean wall completion (s)".into(), fmt_f(self.mean_wall(), 6)]);
        t.row(vec!["std wall completion (s)".into(), fmt_f(self.wall.stddev(), 6)]);
        t.row(vec!["p50 wall (s)".into(), fmt_q(p50)]);
        t.row(vec!["p99 wall (s)".into(), fmt_q(p99)]);
        t.row(vec!["mean injected completion (s)".into(), fmt_f(self.mean_injected(), 6)]);
        t.row(vec!["tasks dispatched".into(), d.to_string()]);
        t.row(vec!["redundant arrivals".into(), r.to_string()]);
        t.row(vec!["tasks cancelled".into(), c.to_string()]);
        if self.faults.any() {
            let f = &self.faults;
            t.row(vec!["worker crashes".into(), f.crashes.to_string()]);
            t.row(vec!["worker respawns".into(), f.respawns.to_string()]);
            t.row(vec!["deadline relaunches".into(), f.relaunches.to_string()]);
            t.row(vec!["degraded re-plans".into(), f.degradations.to_string()]);
            t.row(vec!["tasks dropped".into(), f.dropped.to_string()]);
            t.row(vec!["corrupt results injected".into(), f.corrupted.to_string()]);
            t.row(vec!["replicas flagged by vote".into(), f.flagged.to_string()]);
            t.row(vec!["workers quarantined".into(), f.quarantined.to_string()]);
        }
        t
    }

    /// Per-job CSV table (for plotting loss/latency curves).
    pub fn records_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["job", "wall_s", "injected_s", "dispatched", "redundant", "cancelled"],
        );
        for r in &self.records {
            t.row(vec![
                r.job_id.to_string(),
                fmt_f(r.completion_s, 6),
                fmt_f(r.injected_s, 6),
                r.dispatched.to_string(),
                r.redundant.to_string(),
                r.cancelled.to_string(),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, wall: f64) -> JobRecord {
        JobRecord {
            job_id: id,
            completion_s: wall,
            injected_s: wall * 0.9,
            dispatch_s: wall * 0.01,
            dispatched: 8,
            redundant: 1,
            cancelled: 3,
        }
    }

    #[test]
    fn aggregates() {
        let mut m = RunMetrics::new();
        for i in 0..10 {
            m.push(rec(i, 1.0 + i as f64 * 0.1));
        }
        assert_eq!(m.len(), 10);
        assert!((m.mean_wall() - 1.45).abs() < 1e-12);
        let (d, r, c) = m.totals();
        assert_eq!((d, r, c), (80, 10, 30));
        assert!(m.quantile_wall(1.0).unwrap() >= m.quantile_wall(0.5).unwrap());
    }

    #[test]
    fn empty_metrics_have_no_quantiles() {
        let mut m = RunMetrics::new();
        assert_eq!(m.quantile_wall(0.5), None);
        assert_eq!(m.quantile_wall(0.99), None);
        assert_eq!(m.quantile_hist(0.5), None);
        // The report renders "-" for the missing quantiles, not 0.0.
        let md = m.summary_table("empty").to_markdown();
        assert!(md.contains("p50 wall"));
        assert!(md.contains("| -"), "empty quantiles render as '-': {md}");
    }

    #[test]
    fn quantile_wall_tracks_records_pushed_after_a_query() {
        // The lazily-sorted cache must absorb pushes that happen after
        // a quantile call (the sort is invalidated, not frozen).
        let mut m = RunMetrics::new();
        m.push(rec(0, 1.0));
        assert_eq!(m.quantile_wall(1.0), Some(1.0));
        m.push(rec(1, 3.0));
        assert_eq!(m.quantile_wall(1.0), Some(3.0));
        assert_eq!(m.quantile_wall(0.0), Some(1.0));
    }

    #[test]
    fn tables_render() {
        let mut m = RunMetrics::new();
        m.push(rec(0, 0.5));
        let t = m.summary_table("run");
        assert!(t.to_markdown().contains("mean wall completion"));
        let rt = m.records_table("jobs");
        assert_eq!(rt.rows.len(), 1);
    }

    #[test]
    fn fault_totals_accumulate_and_render() {
        let mut m = RunMetrics::new();
        m.push(rec(0, 0.5));
        assert!(!m.fault_totals().any());
        assert!(!m.summary_table("run").to_markdown().contains("deadline relaunches"));
        let e = crate::coordinator::RoundEvents {
            crashes: 1,
            respawns: 1,
            relaunches: 2,
            degradations: 0,
            dropped: 3,
            corrupted: 2,
            flagged: 1,
            quarantined: 1,
        };
        m.note_fault_events(&e);
        m.note_fault_events(&e);
        // A third, differently-shaped round folds in on top.
        let e2 = crate::coordinator::RoundEvents {
            crashes: 0,
            respawns: 0,
            relaunches: 1,
            degradations: 2,
            dropped: 0,
            corrupted: 0,
            flagged: 3,
            quarantined: 0,
        };
        m.note_fault_events(&e2);
        let f = m.fault_totals();
        assert_eq!((f.crashes, f.respawns, f.relaunches, f.dropped), (2, 2, 5, 6));
        assert_eq!((f.corrupted, f.flagged, f.quarantined), (4, 5, 2));
        assert_eq!(f.degradations, 2);
        let md = m.summary_table("run").to_markdown();
        assert!(md.contains("deadline relaunches"));
        assert!(md.contains("workers quarantined"));
    }

    #[test]
    fn hist_quantile_close_to_exact() {
        let mut m = RunMetrics::new();
        for i in 1..=1000 {
            m.push(rec(i, i as f64 / 100.0));
        }
        let exact = m.quantile_wall(0.9).unwrap();
        let approx = m.quantile_hist(0.9).unwrap();
        assert!((approx - exact).abs() / exact < 0.1, "{approx} vs {exact}");
    }

    #[test]
    fn hist_and_wall_quantiles_agree_on_large_samples() {
        // Heavy-ish tail (shifted exponential, the paper's service law)
        // across the histogram's full resolution band: every quantile
        // must agree within the LogHistogram bucket-ratio error bound.
        let mut m = RunMetrics::new();
        let mut r = crate::util::rng::Rng::new(9);
        for i in 0..5000 {
            let x = 0.05 - r.f64_open0().ln();
            m.push(rec(i, x));
        }
        for q in [0.1, 0.5, 0.9, 0.99] {
            let exact = m.quantile_wall(q).unwrap();
            let approx = m.quantile_hist(q).unwrap();
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.08, "q={q}: hist {approx} vs exact {exact} (rel {rel})");
        }
    }
}
