//! E8 — ablations of the paper's modeling choices:
//!
//! 1. **Batch service model**: size-scaled (the paper/Gardner model) vs
//!    decoupled slowdown vs per-sample-sum — how much of the
//!    diversity–parallelism geometry survives each change.
//! 2. **Cancellation**: completion time is unchanged; the *cost* (busy
//!    and wasted worker-seconds) is what redundancy spends.
//! 3. **Upfront replication vs speculative relaunch** (reactive
//!    MapReduce-style baseline): latency vs cost frontier — expressed
//!    purely through the scenario's redundancy mode, same backend.
//! 4. **Heterogeneous workers**: a mixed-speed cluster under the same
//!    policies.

use super::ExpContext;
use crate::assignment::feasible_batch_counts;
use crate::des::engine::Redundancy;
use crate::des::Scenario;
use crate::dist::{BatchModel, BatchService, ServiceSpec};
use crate::evaluator::{DesEvaluator, Evaluator, ReplicationPolicy};
use crate::util::rng::Rng;
use crate::util::table::{fmt_f, Table};

/// Workers for the ablations.
pub const N: usize = 12;

fn balanced_scn(b: usize, service: BatchService, seed: u64) -> anyhow::Result<Scenario> {
    Scenario::from_policy(ReplicationPolicy::BalancedDisjoint, N, b, service, seed)
}

/// Run E8.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let sexp = ServiceSpec::shifted_exp(1.0, 0.2);
    let mc = ctx.mc();
    let des = ctx.des();

    // --- 1. batch service model ablation ---
    let mut t1 = Table::new(
        "Ablation — batch service model (SExp(1,0.2), N=12): E[T] vs B",
        &["model", "B", "E[T] sim", "Var sim"],
    );
    for model in [BatchModel::SizeScaled, BatchModel::DecoupledSlowdown, BatchModel::PerSampleSum]
    {
        for &b in &feasible_batch_counts(N) {
            let scn = balanced_scn(
                b,
                BatchService { spec: sexp.clone(), model },
                ctx.seed + b as u64,
            )?;
            let st = mc.evaluate(&scn)?;
            t1.row(vec![
                model.name().to_string(),
                b.to_string(),
                fmt_f(st.mean, 4),
                fmt_f(st.variance, 4),
            ]);
        }
    }
    ctx.emit("ablation_batch_model", &t1)?;

    // --- 2. cancellation cost ---
    let mut t2 = Table::new(
        "Ablation — cancellation (SExp(1,0.2), N=12): completion unchanged, cost reduced",
        &["B", "cancel", "E[T]", "busy (worker-s)", "wasted (worker-s)"],
    );
    for &b in &feasible_batch_counts(N) {
        for cancel in [true, false] {
            let scn = balanced_scn(b, BatchService::paper(sexp.clone()), ctx.seed + b as u64)?;
            let ev = DesEvaluator { cancellation: cancel, ..des };
            let st = ev.evaluate(&scn)?;
            let cost = st.cost.expect("des backend reports cost");
            t2.row(vec![
                b.to_string(),
                cancel.to_string(),
                fmt_f(st.mean, 4),
                fmt_f(cost.busy, 4),
                fmt_f(cost.wasted, 4),
            ]);
        }
    }
    ctx.emit("ablation_cancellation", &t2)?;

    // --- 3. upfront vs speculative ---
    // One scenario family; only the redundancy mode changes. The same
    // DesEvaluator consumes both — the trade-off is in the scenario,
    // not in backend-specific wiring.
    let mut t3 = Table::new(
        "Ablation — upfront replication vs speculative relaunch (B=3, N=12)",
        &["strategy", "E[T]", "p99", "busy", "wasted"],
    );
    let base = balanced_scn(3, BatchService::paper(sexp.clone()), ctx.seed)?;
    let upfront = des.evaluate(&base)?;
    let up_cost = upfront.cost.expect("des backend reports cost");
    t3.row(vec![
        "upfront".into(),
        fmt_f(upfront.mean, 4),
        fmt_f(upfront.quantile(0.99).unwrap(), 4),
        fmt_f(up_cost.busy, 4),
        fmt_f(up_cost.wasted, 4),
    ]);
    for df in [1.0, 1.5, 2.0, 3.0] {
        let scn = base
            .clone()
            .with_redundancy(Redundancy::Speculative { deadline_factor: df });
        let st = des.evaluate(&scn)?;
        let cost = st.cost.expect("des backend reports cost");
        t3.row(vec![
            format!("speculative x{df}"),
            fmt_f(st.mean, 4),
            fmt_f(st.quantile(0.99).unwrap(), 4),
            fmt_f(cost.busy, 4),
            fmt_f(cost.wasted, 4),
        ]);
    }
    ctx.emit("ablation_speculative", &t3)?;

    // --- 4. heterogeneous workers ---
    let mut t4 = Table::new(
        "Ablation — heterogeneous cluster (25% of workers 3x slower): E[T] vs B",
        &["B", "E[T] homogeneous", "E[T] heterogeneous", "hetero/homo"],
    );
    let mut rng = Rng::new(ctx.seed ^ 0x4E7);
    let mut speeds = vec![1.0; N];
    for s in speeds.iter_mut().take(N / 4) {
        *s = 3.0;
    }
    rng.shuffle(&mut speeds);
    for &b in &feasible_batch_counts(N) {
        let seed = ctx.seed + 7 + b as u64;
        let homo = balanced_scn(b, BatchService::paper(sexp.clone()), seed)?;
        let hetero = balanced_scn(b, BatchService::paper(sexp.clone()), seed)?
            .with_speeds(speeds.clone())?;
        let mh = mc.evaluate(&homo)?;
        let mx = mc.evaluate(&hetero)?;
        t4.row(vec![
            b.to_string(),
            fmt_f(mh.mean, 4),
            fmt_f(mx.mean, 4),
            fmt_f(mx.mean / mh.mean, 3),
        ]);
    }
    ctx.emit("ablation_heterogeneous", &t4)?;

    Ok(vec![t1, t2, t3, t4])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_invariants() {
        let dir = std::env::temp_dir().join("batchrep_ablations_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 10_000, seed: 2 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // Per-sample-sum must show *flatter* diversity benefit than
        // size-scaled at B=1 (min of sums vs min of scaled draws).
        let t1 = &tables[0];
        let get = |model: &str, b: &str| -> f64 {
            t1.rows
                .iter()
                .find(|r| r[0] == model && r[1] == b)
                .unwrap()[2]
                .parse()
                .unwrap()
        };
        // Full diversity with per-sample-sum is still >= size-scaled's
        // (variance reduction by averaging weakens the min gain).
        assert!(get("per_sample_sum", "1") >= get("size_scaled", "1") * 0.9);

        // Cancellation never increases cost.
        let t2 = &tables[1];
        for pair in t2.rows.chunks(2) {
            let with: f64 = pair[0][3].parse().unwrap();
            let without: f64 = pair[1][3].parse().unwrap();
            assert!(with <= without * 1.01, "{pair:?}");
        }

        // Speculative waits before helping: slower but cheaper than
        // upfront for every deadline factor.
        let t3 = &tables[2];
        let up_mean: f64 = t3.rows[0][1].parse().unwrap();
        let up_busy: f64 = t3.rows[0][3].parse().unwrap();
        for r in &t3.rows[1..] {
            let mean: f64 = r[1].parse().unwrap();
            let busy: f64 = r[3].parse().unwrap();
            assert!(mean > up_mean, "{r:?}");
            assert!(busy < up_busy, "{r:?}");
        }

        // Heterogeneous slower than homogeneous everywhere.
        for r in &tables[3].rows {
            let ratio: f64 = r[3].parse().unwrap();
            assert!(ratio >= 0.99, "{r:?}");
        }
    }
}
