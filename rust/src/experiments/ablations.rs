//! E8 — ablations of the paper's modeling choices:
//!
//! 1. **Batch service model**: size-scaled (the paper/Gardner model) vs
//!    decoupled slowdown vs per-sample-sum — how much of the
//!    diversity–parallelism geometry survives each change. A service
//!    axis (same spec, three models) in one study.
//! 2. **Cancellation**: completion time is unchanged; the *cost* (busy
//!    and wasted worker-seconds) is what redundancy spends. Two studies
//!    differing only in the planner-level `des_cancellation` knob.
//! 3. **Upfront replication vs speculative relaunch** (reactive
//!    MapReduce-style baseline): latency vs cost frontier — a
//!    redundancy axis, same backend.
//! 4. **Heterogeneous workers**: a speed axis (homogeneous vs a
//!    shuffled mixed-speed cluster) under the same policies.

use super::ExpContext;
use crate::assignment::feasible_batch_counts;
use crate::dist::{BatchModel, BatchService, ServiceSpec};
use crate::study::{BackendSel, BatchAxis, RedundancyAxis, SpeedAxis, StudySpec};
use crate::util::rng::Rng;
use crate::util::table::{fmt_f, Table};

/// Workers for the ablations.
pub const N: usize = 12;

/// Run E8.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let sexp = ServiceSpec::shifted_exp(1.0, 0.2);
    let models = [BatchModel::SizeScaled, BatchModel::DecoupledSlowdown, BatchModel::PerSampleSum];

    // --- 1. batch service model ablation ---
    let mut t1 = Table::new(
        "Ablation — batch service model (SExp(1,0.2), N=12): E[T] vs B",
        &["model", "B", "E[T] sim", "Var sim"],
    );
    let t1_report = ctx.study(StudySpec {
        n_workers: vec![N],
        services: models
            .iter()
            .map(|&model| BatchService { spec: sexp.clone(), model })
            .collect(),
        ..ctx.spec("ablation-batch-model")
    })?;
    for (mi, model) in models.iter().enumerate() {
        for &b in &feasible_batch_counts(N) {
            let st = t1_report.stats_where(&|c| c.service_idx == mi && c.b == b)?;
            t1.row(vec![
                model.name().to_string(),
                b.to_string(),
                fmt_f(st.mean, 4),
                fmt_f(st.variance, 4),
            ]);
        }
    }
    ctx.emit("ablation_batch_model", &t1)?;

    // --- 2. cancellation cost ---
    // Cancellation is an engine knob, not a scenario field: the same
    // grid is compiled twice, differing only in `des_cancellation`.
    let mut t2 = Table::new(
        "Ablation — cancellation (SExp(1,0.2), N=12): completion unchanged, cost reduced",
        &["B", "cancel", "E[T]", "busy (worker-s)", "wasted (worker-s)"],
    );
    let cancel_grid = |cancel: bool| StudySpec {
        n_workers: vec![N],
        services: vec![BatchService::paper(sexp.clone())],
        backends: vec![BackendSel::Des],
        des_cancellation: cancel,
        ..ctx.spec(if cancel { "ablation-cancel-on" } else { "ablation-cancel-off" })
    };
    let with_cancel = ctx.study(cancel_grid(true))?;
    let without_cancel = ctx.study(cancel_grid(false))?;
    for &b in &feasible_batch_counts(N) {
        for (cancel, report) in [(true, &with_cancel), (false, &without_cancel)] {
            let st = report.stats_where(&|c| c.b == b)?;
            let cost = st
                .cost
                .ok_or_else(|| anyhow::anyhow!("des backend reports cost"))?;
            t2.row(vec![
                b.to_string(),
                cancel.to_string(),
                fmt_f(st.mean, 4),
                fmt_f(cost.busy, 4),
                fmt_f(cost.wasted, 4),
            ]);
        }
    }
    ctx.emit("ablation_cancellation", &t2)?;

    // --- 3. upfront vs speculative ---
    // One scenario family; only the redundancy axis varies. The same
    // DES backend consumes every mode — the trade-off is in the
    // scenario, not in backend-specific wiring.
    let deadline_factors = [1.0, 1.5, 2.0, 3.0];
    let mut t3 = Table::new(
        "Ablation — upfront replication vs speculative relaunch (B=3, N=12)",
        &["strategy", "E[T]", "p99", "busy", "wasted"],
    );
    let t3_report = ctx.study(StudySpec {
        n_workers: vec![N],
        batches: BatchAxis::Explicit(vec![3]),
        services: vec![BatchService::paper(sexp.clone())],
        redundancy: std::iter::once(RedundancyAxis::Upfront)
            .chain(deadline_factors.iter().map(|&df| RedundancyAxis::Speculative(df)))
            .collect(),
        backends: vec![BackendSel::Des],
        ..ctx.spec("ablation-speculative")
    })?;
    for (ri, label) in std::iter::once("upfront".to_string())
        .chain(deadline_factors.iter().map(|df| format!("speculative x{df}")))
        .enumerate()
    {
        let st = t3_report.stats_where(&|c| c.redundancy_idx == ri)?;
        let cost = st
                .cost
                .ok_or_else(|| anyhow::anyhow!("des backend reports cost"))?;
        t3.row(vec![
            label,
            fmt_f(st.mean, 4),
            st.quantile(0.99).map(|v| fmt_f(v, 4)).unwrap_or_else(|| "-".into()),
            fmt_f(cost.busy, 4),
            fmt_f(cost.wasted, 4),
        ]);
    }
    ctx.emit("ablation_speculative", &t3)?;

    // --- 4. heterogeneous workers ---
    let mut t4 = Table::new(
        "Ablation — heterogeneous cluster (25% of workers 3x slower): E[T] vs B",
        &["B", "E[T] homogeneous", "E[T] heterogeneous", "hetero/homo"],
    );
    let mut rng = Rng::new(ctx.seed ^ 0x4E7);
    let mut speeds = vec![1.0; N];
    for s in speeds.iter_mut().take(N / 4) {
        *s = 3.0;
    }
    rng.shuffle(&mut speeds);
    let t4_report = ctx.study(StudySpec {
        n_workers: vec![N],
        services: vec![BatchService::paper(sexp)],
        speeds: vec![SpeedAxis::Homogeneous, SpeedAxis::Explicit(speeds)],
        ..ctx.spec("ablation-heterogeneous")
    })?;
    for &b in &feasible_batch_counts(N) {
        let mh = t4_report.stats_where(&|c| c.b == b && c.speeds_idx == 0)?;
        let mx = t4_report.stats_where(&|c| c.b == b && c.speeds_idx == 1)?;
        t4.row(vec![
            b.to_string(),
            fmt_f(mh.mean, 4),
            fmt_f(mx.mean, 4),
            fmt_f(mx.mean / mh.mean, 3),
        ]);
    }
    ctx.emit("ablation_heterogeneous", &t4)?;

    Ok(vec![t1, t2, t3, t4])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_invariants() {
        let dir = std::env::temp_dir().join("batchrep_ablations_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 10_000, seed: 2 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // Per-sample-sum must show *flatter* diversity benefit than
        // size-scaled at B=1 (min of sums vs min of scaled draws).
        let t1 = &tables[0];
        let get = |model: &str, b: &str| -> f64 {
            t1.rows
                .iter()
                .find(|r| r[0] == model && r[1] == b)
                .unwrap()[2]
                .parse()
                .unwrap()
        };
        // Full diversity with per-sample-sum is still >= size-scaled's
        // (variance reduction by averaging weakens the min gain).
        assert!(get("per_sample_sum", "1") >= get("size_scaled", "1") * 0.9);

        // Cancellation never increases cost.
        let t2 = &tables[1];
        for pair in t2.rows.chunks(2) {
            let with: f64 = pair[0][3].parse().unwrap();
            let without: f64 = pair[1][3].parse().unwrap();
            assert!(with <= without * 1.01, "{pair:?}");
        }

        // Speculative waits before helping: slower but cheaper than
        // upfront for every deadline factor.
        let t3 = &tables[2];
        let up_mean: f64 = t3.rows[0][1].parse().unwrap();
        let up_busy: f64 = t3.rows[0][3].parse().unwrap();
        for r in &t3.rows[1..] {
            let mean: f64 = r[1].parse().unwrap();
            let busy: f64 = r[3].parse().unwrap();
            assert!(mean > up_mean, "{r:?}");
            assert!(busy < up_busy, "{r:?}");
        }

        // Heterogeneous slower than homogeneous everywhere.
        for r in &tables[3].rows {
            let ratio: f64 = r[3].parse().unwrap();
            assert!(ratio >= 0.99, "{r:?}");
        }
    }
}
