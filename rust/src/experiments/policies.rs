//! E2 — Theorem 1 / Corollary 1: assignment-policy comparison.
//!
//! Balanced disjoint batches must minimize expected completion time
//! among all policies for stochastically decreasing-and-convex service
//! (Exp, SExp). We compare every [`ReplicationPolicy`] — including the
//! storage-equal *overlapping* layout — under the paper's distributions
//! and two heavy-tailed robustness cases where the theorem's hypothesis
//! fails. One study: a policy axis × a distribution axis × the
//! `{montecarlo, analytic}` backend axis; the closed form fills its
//! column wherever it applies and its refusal is rendered as "-"
//! everywhere else.

use super::ExpContext;
use crate::dist::{BatchService, ServiceSpec};
use crate::evaluator::ReplicationPolicy;
use crate::study::{BackendSel, BatchAxis};
use crate::util::table::{fmt_f, Table};

/// Workers.
pub const N: usize = 12;
/// Batches for the policy comparison.
pub const B: usize = 4;

/// Run E2.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let dists: Vec<(&str, ServiceSpec, bool)> = vec![
        ("exp(1)", ServiceSpec::exp(1.0), true),
        ("sexp(1,0.2)", ServiceSpec::shifted_exp(1.0, 0.2), true),
        ("pareto(0.5,2.2)", ServiceSpec::pareto(0.5, 2.2), false),
        ("weibull(0.6,1)", ServiceSpec::weibull(0.6, 1.0), false),
    ];

    let mut t = Table::new(
        &format!(
            "Theorem 1 — assignment policies, N={N}, B={B} \
             (E[T]; balanced disjoint should win under dec-convex service)"
        ),
        &["distribution", "dec-convex", "policy", "E[T] sim", "ci95", "E[T] analytic"],
    );

    let spec = crate::study::StudySpec {
        n_workers: vec![N],
        batches: BatchAxis::Explicit(vec![B]),
        policies: ReplicationPolicy::all().to_vec(),
        services: dists.iter().map(|(_, s, _)| BatchService::paper(s.clone())).collect(),
        backends: vec![BackendSel::MonteCarlo, BackendSel::Analytic],
        ..ctx.spec("policies")
    };
    let report = ctx.study(spec)?;

    for (di, (dname, _, decconv)) in dists.iter().enumerate() {
        for policy in ReplicationPolicy::all() {
            let sim = report.stats_where(&|c| {
                c.service_idx == di && c.policy == *policy && c.backend == BackendSel::MonteCarlo
            })?;
            // Exact value wherever the closed forms apply (equal-size
            // disjoint batches + exp family); "-" otherwise (the
            // analytic cell is planned but refused).
            let analytic = report
                .try_stats_where(&|c| {
                    c.service_idx == di
                        && c.policy == *policy
                        && c.backend == BackendSel::Analytic
                })
                .map(|s| fmt_f(s.mean, 4))
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                dname.to_string(),
                decconv.to_string(),
                policy.name().to_string(),
                fmt_f(sim.mean, 4),
                fmt_f(sim.ci95(), 4),
                analytic,
            ]);
        }
    }

    ctx.emit("thm1_policies", &t)?;
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_disjoint_wins_under_dec_convex() {
        let dir = std::env::temp_dir().join("batchrep_policies_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 30_000, seed: 5 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let t = &tables[0];
        // Within each dec-convex distribution, balanced_disjoint must
        // beat random (tie ok: same law), skewed, and overlapping among
        // same-B policies. (Full diversity may beat everything for exp —
        // that is Theorem 2, a different claim.)
        for dname in ["exp(1)", "sexp(1,0.2)"] {
            let get = |pol: &str| -> f64 {
                t.rows
                    .iter()
                    .find(|r| r[0] == dname && r[2] == pol)
                    .unwrap()[3]
                    .parse()
                    .unwrap()
            };
            let bal = get("balanced_disjoint");
            assert!(bal <= get("skewed_unbalanced") * 1.01, "{dname}");
            assert!(bal <= get("overlapping_cyclic") * 1.02, "{dname}");
            assert!((bal - get("random_balanced")).abs() < 0.05 * bal, "{dname}");
        }
    }

    #[test]
    fn analytic_column_follows_closed_form_scope() {
        // Exp-family rows carry an exact value; heavy-tail rows render
        // the planned-but-refused analytic cell as "-".
        let dir = std::env::temp_dir().join("batchrep_policies_scope_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 4_000, seed: 6 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        for r in &tables[0].rows {
            let heavy = r[1] == "false";
            let overlapping = r[2] == "overlapping_cyclic";
            if heavy || overlapping {
                assert_eq!(r[5], "-", "{r:?}");
            } else {
                assert!(r[5].parse::<f64>().is_ok(), "{r:?}");
            }
        }
    }
}
