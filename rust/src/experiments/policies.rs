//! E2 — Theorem 1 / Corollary 1: assignment-policy comparison.
//!
//! Balanced disjoint batches must minimize expected completion time
//! among all policies for stochastically decreasing-and-convex service
//! (Exp, SExp). We compare every [`ReplicationPolicy`] — including the
//! storage-equal *overlapping* layout — under the paper's distributions
//! and two heavy-tailed robustness cases where the theorem's hypothesis
//! fails. One scenario family, two backends: Monte-Carlo for every
//! policy, the analytic evaluator wherever the closed forms apply.

use super::ExpContext;
use crate::des::Scenario;
use crate::dist::{BatchService, ServiceSpec};
use crate::evaluator::{AnalyticEvaluator, Evaluator, ReplicationPolicy};
use crate::util::table::{fmt_f, Table};

/// Workers.
pub const N: usize = 12;
/// Batches for the policy comparison.
pub const B: usize = 4;

/// Run E2.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let dists: Vec<(&str, ServiceSpec, bool)> = vec![
        ("exp(1)", ServiceSpec::exp(1.0), true),
        ("sexp(1,0.2)", ServiceSpec::shifted_exp(1.0, 0.2), true),
        ("pareto(0.5,2.2)", ServiceSpec::pareto(0.5, 2.2), false),
        ("weibull(0.6,1)", ServiceSpec::weibull(0.6, 1.0), false),
    ];

    let mut t = Table::new(
        &format!(
            "Theorem 1 — assignment policies, N={N}, B={B} \
             (E[T]; balanced disjoint should win under dec-convex service)"
        ),
        &["distribution", "dec-convex", "policy", "E[T] sim", "ci95", "E[T] analytic"],
    );

    let mc = ctx.mc();
    for (di, (dname, spec, decconv)) in dists.iter().enumerate() {
        for (pi, policy) in ReplicationPolicy::all().iter().enumerate() {
            let scn = Scenario::from_policy(
                *policy,
                N,
                B,
                BatchService::paper(spec.clone()),
                ctx.seed + 17 + di as u64 * 101 + pi as u64,
            )?;
            let sim = mc.evaluate(&scn)?;
            // Exact value wherever the closed forms apply (equal-size
            // disjoint batches + exp family); "-" otherwise.
            let analytic = AnalyticEvaluator
                .evaluate(&scn)
                .map(|s| fmt_f(s.mean, 4))
                .unwrap_or_else(|_| "-".into());
            t.row(vec![
                dname.to_string(),
                decconv.to_string(),
                policy.name().to_string(),
                fmt_f(sim.mean, 4),
                fmt_f(sim.ci95(), 4),
                analytic,
            ]);
        }
    }

    ctx.emit("thm1_policies", &t)?;
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_disjoint_wins_under_dec_convex() {
        let dir = std::env::temp_dir().join("batchrep_policies_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 30_000, seed: 5 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let t = &tables[0];
        // Within each dec-convex distribution, balanced_disjoint must
        // beat random (tie ok: same law), skewed, and overlapping among
        // same-B policies. (Full diversity may beat everything for exp —
        // that is Theorem 2, a different claim.)
        for dname in ["exp(1)", "sexp(1,0.2)"] {
            let get = |pol: &str| -> f64 {
                t.rows
                    .iter()
                    .find(|r| r[0] == dname && r[2] == pol)
                    .unwrap()[3]
                    .parse()
                    .unwrap()
            };
            let bal = get("balanced_disjoint");
            assert!(bal <= get("skewed_unbalanced") * 1.01, "{dname}");
            assert!(bal <= get("overlapping_cyclic") * 1.02, "{dname}");
            assert!((bal - get("random_balanced")).abs() < 0.05 * bal, "{dname}");
        }
    }
}
