//! E2 — Theorem 1 / Corollary 1: assignment-policy comparison.
//!
//! Balanced disjoint batches must minimize expected completion time
//! among all policies for stochastically decreasing-and-convex service
//! (Exp, SExp). We compare: balanced disjoint, random balanced, skewed
//! unbalanced, and *overlapping* batches (same per-worker storage), plus
//! the two spectrum endpoints — under the paper's distributions and two
//! heavy-tailed robustness cases where the theorem's hypothesis fails.

use super::ExpContext;
use crate::analysis;
use crate::assignment::{balanced, skewed, Policy};
use crate::batching;
use crate::des::{montecarlo, Scenario};
use crate::dist::{BatchService, ServiceSpec};
use crate::util::rng::Rng;
use crate::util::table::{fmt_f, Table};

/// Workers.
pub const N: usize = 12;
/// Batches for the policy comparison.
pub const B: usize = 4;

/// Policy variants compared (the `Policy` enum plus overlapping layout).
fn variants() -> Vec<&'static str> {
    vec![
        "balanced_disjoint",
        "random_balanced",
        "skewed_unbalanced",
        "overlapping_cyclic",
        "full_diversity",
        "full_parallelism",
    ]
}

fn scenario_for(
    variant: &str,
    spec: &ServiceSpec,
    rng: &mut Rng,
) -> anyhow::Result<Scenario> {
    let service = BatchService::paper(spec.clone());
    match variant {
        "overlapping_cyclic" => {
            // B overlapping windows, each the size of a disjoint batch's
            // share of data *times its replication degree* is NOT the
            // comparison the paper makes; storage-equal comparison: N
            // windows of N/B units each (every worker stores the same
            // amount as in the disjoint case, windows shifted cyclically).
            let layout = batching::overlapping(N, N, N / B)?;
            let assignment = balanced(N, N)?;
            Scenario::new(layout, assignment, service)
        }
        "balanced_disjoint" => Scenario::paper_balanced(N, B, service),
        "random_balanced" => {
            let layout = batching::disjoint(N, B)?;
            let assignment = Policy::RandomBalanced.assign(N, B, rng)?;
            Scenario::new(layout, assignment, service)
        }
        "skewed_unbalanced" => {
            let layout = batching::disjoint(N, B)?;
            let assignment = skewed(N, B)?;
            Scenario::new(layout, assignment, service)
        }
        "full_diversity" => Scenario::paper_balanced(N, 1, service),
        "full_parallelism" => Scenario::paper_balanced(N, N, service),
        _ => anyhow::bail!("unknown variant {variant}"),
    }
}

/// Run E2.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let dists: Vec<(&str, ServiceSpec, bool)> = vec![
        ("exp(1)", ServiceSpec::exp(1.0), true),
        ("sexp(1,0.2)", ServiceSpec::shifted_exp(1.0, 0.2), true),
        ("pareto(0.5,2.2)", ServiceSpec::pareto(0.5, 2.2), false),
        ("weibull(0.6,1)", ServiceSpec::weibull(0.6, 1.0), false),
    ];

    let mut t = Table::new(
        &format!(
            "Theorem 1 — assignment policies, N={N}, B={B} \
             (E[T]; balanced disjoint should win under dec-convex service)"
        ),
        &["distribution", "dec-convex", "policy", "E[T] sim", "ci95", "E[T] analytic"],
    );

    let mut rng = Rng::new(ctx.seed ^ 0x90CC);
    for (dname, spec, decconv) in &dists {
        for variant in variants() {
            let scn = scenario_for(variant, spec, &mut rng)?;
            let mc = montecarlo::run_trials(&scn, ctx.trials, ctx.seed + 17);
            // Analytic value where the closed form applies (equal-size
            // disjoint batches + exp family).
            let analytic = if !scn.layout.is_overlapping {
                analysis::assignment_stats(&scn.assignment, spec, N as u64)
                    .map(|s| fmt_f(s.mean, 4))
                    .unwrap_or_else(|_| "-".into())
            } else {
                "-".into()
            };
            t.row(vec![
                dname.to_string(),
                decconv.to_string(),
                variant.to_string(),
                fmt_f(mc.mean(), 4),
                fmt_f(mc.ci95(), 4),
                analytic,
            ]);
        }
    }

    ctx.emit("thm1_policies", &t)?;
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_disjoint_wins_under_dec_convex() {
        let dir = std::env::temp_dir().join("batchrep_policies_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 30_000, seed: 5 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let t = &tables[0];
        // Within each dec-convex distribution, balanced_disjoint must
        // beat random (tie ok: same law), skewed, and overlapping among
        // same-B policies. (Full diversity may beat everything for exp —
        // that is Theorem 2, a different claim.)
        for dname in ["exp(1)", "sexp(1,0.2)"] {
            let get = |pol: &str| -> f64 {
                t.rows
                    .iter()
                    .find(|r| r[0] == dname && r[2] == pol)
                    .unwrap()[3]
                    .parse()
                    .unwrap()
            };
            let bal = get("balanced_disjoint");
            assert!(bal <= get("skewed_unbalanced") * 1.01, "{dname}");
            assert!(bal <= get("overlapping_cyclic") * 1.02, "{dname}");
            assert!((bal - get("random_balanced")).abs() < 0.05 * bal, "{dname}");
        }
    }
}
