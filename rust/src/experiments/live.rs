//! E6 — live-system validation: the real coordinator (worker threads,
//! PJRT compute, injected stragglers, cancellation) must reproduce the
//! analytic completion-time curve that Fig. 2 predicts.
//!
//! For each `B` we run `rounds` gradient rounds on the live System1 and
//! compare the measured mean completion (in injected-time units) against
//! the closed form. Wall-clock includes real PJRT compute and dispatch
//! overhead, so we report both and the overhead ratio — the number the
//! §Perf pass drives down.

use super::ExpContext;
use crate::analysis;
use crate::assignment::{feasible_batch_counts, Policy};
use crate::config::SystemConfig;
use crate::coordinator::{Backend, Coordinator};
use crate::dist::ServiceSpec;
use crate::util::table::{fmt_f, Table};

/// Live workers (threads).
pub const N: usize = 8;

/// Build the live config for a given `B`.
fn live_cfg(b: usize, ctx: &ExpContext, artifacts: bool) -> SystemConfig {
    SystemConfig {
        n_workers: N,
        n_batches: b,
        policy: Policy::BalancedDisjoint,
        service: ServiceSpec::shifted_exp(1.0, 0.2),
        time_scale: 0.01, // 10 ms per unit of abstract service time
        n_samples: 4096,
        dim: if artifacts { 64 } else { 8 },
        seed: ctx.seed,
        ..SystemConfig::default()
    }
}

/// Run E6. Uses the PJRT backend when artifacts exist, otherwise falls
/// back to the mock backend (and says so) so the experiment is always
/// runnable.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let artifact_dir = crate::runtime::default_artifact_dir();
    let have_artifacts =
        artifact_dir.join("manifest.json").exists() && cfg!(feature = "pjrt");
    let backend = if have_artifacts { Backend::Pjrt } else { Backend::Mock };
    let rounds = 30u64;

    let mut t = Table::new(
        &format!(
            "Live System1 vs closed form (N={N}, SExp(1,0.2), {} backend, {} rounds/B)",
            if have_artifacts { "PJRT" } else { "mock" },
            rounds
        ),
        &[
            "B",
            "E[T] analytic (units)",
            "live injected mean (units)",
            "live wall mean (s)",
            "overhead (wall - scaled injected, ms)",
            "redundant+cancelled/round",
        ],
    );

    for &b in &feasible_batch_counts(N) {
        let mut cfg = live_cfg(b, ctx, have_artifacts);
        if have_artifacts {
            cfg.artifacts_dir = artifact_dir.to_string_lossy().to_string();
        }
        let time_scale = cfg.time_scale;
        let spec = cfg.service.clone();
        let mut coord = Coordinator::new(cfg, backend)?;
        coord.run_training(rounds, 0.3)?;
        let m = &coord.metrics;
        let cf = analysis::completion_time_stats(N as u64, b as u64, &spec)?;
        let injected_units = m.mean_injected() / time_scale;
        let overhead_ms = (m.mean_wall() - m.mean_injected()) * 1e3;
        let (d, r, c) = m.totals();
        let _ = d;
        t.row(vec![
            b.to_string(),
            fmt_f(cf.mean, 3),
            fmt_f(injected_units, 3),
            fmt_f(m.mean_wall(), 4),
            fmt_f(overhead_ms, 2),
            fmt_f((r + c) as f64 / m.len() as f64, 2),
        ]);
        coord.shutdown();
    }

    ctx.emit("live_validation", &t)?;
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_mock_tracks_analysis() {
        // Mock backend keeps this test artifact-free and fast; the
        // injected completion (in units) must track the closed form.
        let dir = std::env::temp_dir().join("batchrep_live_test");
        std::env::set_var("BATCHREP_ARTIFACTS", "/nonexistent-no-artifacts");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 0, seed: 4 };
        let tables = run(&ctx);
        std::env::remove_var("BATCHREP_ARTIFACTS");
        let tables = tables.unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let t = &tables[0];
        for row in &t.rows {
            let analytic: f64 = row[1].parse().unwrap();
            let injected: f64 = row[2].parse().unwrap();
            // 30 rounds of a max of exponentials is noisy: 35% tolerance
            // (this is a wiring check; statistical agreement is E1's job).
            let rel = (injected - analytic).abs() / analytic;
            assert!(rel < 0.35, "B={} analytic={analytic} injected={injected}", row[0]);
        }
    }
}
