//! E3/E4/E5 — Theorems 2, 3, 4: the diversity–parallelism spectrum.
//!
//! * E3 (Thm 2): under Exp service both `E[T]` and `Var[T]` are
//!   minimized at `B = 1` — the whole spectrum is monotone.
//! * E4 (Thm 3): `B*(∆µ)` crossover table.
//! * E5 (Thm 4 + trade-off): under SExp the variance is still minimized
//!   at `B = 1`, so whenever `B* > 1` the mean-optimal operating point
//!   is variance-suboptimal — the paper's mean–variance trade-off.
//!
//! E3 and E5 are one study each over the feasible-B axis (E3 with the
//! `{analytic, montecarlo}` backend pair for the validation column, E5
//! analytic-only with quantiles and cost); E4 stays on the raw
//! closed-form optimizer (`bstar_sweep` — no scenarios involved).

use super::ExpContext;
use crate::analysis::{self, bstar_sweep};
use crate::assignment::feasible_batch_counts;
use crate::dist::{BatchService, ServiceSpec};
use crate::study::BackendSel;
use crate::util::table::{fmt_f, Table};

/// Workers.
pub const N: u64 = 24;

/// Run E3+E4+E5.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    // --- E3: Exponential spectrum (Theorem 2) ---
    let mut e3 = Table::new(
        "Theorem 2 — Exp(1) service: E[T] and Var[T] vs B (B=1 optimal for both)",
        &["B", "E[T] analytic", "E[T] sim", "Var analytic", "Var sim"],
    );
    let e3_report = ctx.study(crate::study::StudySpec {
        n_workers: vec![N as usize],
        services: vec![BatchService::paper(ServiceSpec::exp(1.0))],
        backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo],
        ..ctx.spec("thm2-exp-spectrum")
    })?;
    for &b in &feasible_batch_counts(N as usize) {
        let cf = e3_report
            .stats_where(&|c| c.b == b && c.backend == BackendSel::Analytic)?;
        let mc = e3_report
            .stats_where(&|c| c.b == b && c.backend == BackendSel::MonteCarlo)?;
        e3.row(vec![
            b.to_string(),
            fmt_f(cf.mean, 4),
            fmt_f(mc.mean, 4),
            fmt_f(cf.variance, 4),
            fmt_f(mc.variance, 4),
        ]);
    }
    ctx.emit("thm2_exp_spectrum", &e3)?;

    // --- E4: B*(∆µ) crossovers (Theorem 3) ---
    let delta_mus = [0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0];
    let sweep = bstar_sweep(N, 1.0, &delta_mus)?;
    let mut e4 = Table::new(
        "Theorem 3 — optimal B* vs delta*mu (N=24): diversity→parallelism crossover",
        &["delta_mu", "B*", "g*=N/B*", "E[T] at B*", "E[T] at B=1", "E[T] at B=N"],
    );
    for p in &sweep {
        let spec = ServiceSpec::shifted_exp(1.0, p.delta_mu);
        let at1 = analysis::completion_time_stats(N, 1, &spec)?.mean;
        let atn = analysis::completion_time_stats(N, N, &spec)?.mean;
        e4.row(vec![
            fmt_f(p.delta_mu, 2),
            p.b_star.to_string(),
            (N / p.b_star).to_string(),
            fmt_f(p.mean_at_star, 4),
            fmt_f(at1, 4),
            fmt_f(atn, 4),
        ]);
    }
    ctx.emit("thm3_bstar_crossover", &e4)?;

    // --- E5: mean–variance trade-off under SExp (Theorem 4) ---
    let sexp = ServiceSpec::shifted_exp(1.0, 0.2);
    let mut e5 = Table::new(
        "Theorem 4 — SExp(1,0.2): Var[T] minimized at B=1 while E[T] is not \
         (the mean–variance trade-off)",
        &["B", "E[T]", "Var[T]", "Std[T]", "mean-optimal", "var-optimal"],
    );
    let b_star_mean = analysis::optimum_b(N, &sexp)?;
    let b_star_var = analysis::optimum_b_variance(N, &sexp)?;
    let e5_report = ctx.study(crate::study::StudySpec {
        n_workers: vec![N as usize],
        services: vec![BatchService::paper(sexp)],
        backends: vec![BackendSel::Analytic],
        ..ctx.spec("thm4-tradeoff")
    })?;
    let bs = feasible_batch_counts(N as usize);
    for &b in &bs {
        let st = e5_report.stats_where(&|c| c.b == b)?;
        e5.row(vec![
            b.to_string(),
            fmt_f(st.mean, 4),
            fmt_f(st.variance, 4),
            fmt_f(st.stddev(), 4),
            (b as u64 == b_star_mean).to_string(),
            (b as u64 == b_star_var).to_string(),
        ]);
    }
    ctx.emit("thm4_tradeoff", &e5)?;

    // --- extension: tails and cost across the spectrum ---
    // The paper motivates variance via performance guarantees (The Tail
    // at Scale); the analytic backend's quantiles make the guarantee
    // explicit, and its cost accounting shows what diversity charges.
    let mut e5x = Table::new(
        "Extension — tail latency and redundancy cost vs B (SExp(1,0.2), N=24)",
        &["B", "E[T]", "p50", "p99", "p99.9", "E[cost] (worker-s)", "cost/E[T]"],
    );
    for &b in &bs {
        let st = e5_report.stats_where(&|c| c.b == b)?;
        let cost = st
            .cost
            .ok_or_else(|| anyhow::anyhow!("analytic backend reports cost"))?
            .busy;
        e5x.row(vec![
            b.to_string(),
            fmt_f(st.mean, 4),
            st.quantile(0.5).map(|v| fmt_f(v, 4)).unwrap_or_else(|| "-".into()),
            st.quantile(0.99).map(|v| fmt_f(v, 4)).unwrap_or_else(|| "-".into()),
            st.quantile(0.999).map(|v| fmt_f(v, 4)).unwrap_or_else(|| "-".into()),
            fmt_f(cost, 3),
            fmt_f(cost / st.mean, 3),
        ]);
    }
    ctx.emit("ext_tail_and_cost", &e5x)?;

    Ok(vec![e3, e4, e5, e5x])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrum_tables_consistent() {
        let dir = std::env::temp_dir().join("batchrep_spectrum_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 10_000, seed: 9 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // E3: analytic mean strictly increasing in B (Theorem 2).
        let means: Vec<f64> =
            tables[0].rows.iter().map(|r| r[1].parse().unwrap()).collect();
        for w in means.windows(2) {
            assert!(w[1] > w[0]);
        }

        // E5: variance-optimal row is B=1; mean-optimal is interior.
        let t = &tables[2];
        assert_eq!(t.rows[0][5], "true", "var-optimal must be B=1");
        let mean_opt_b: u64 = t
            .rows
            .iter()
            .find(|r| r[4] == "true")
            .unwrap()[0]
            .parse()
            .unwrap();
        assert!(mean_opt_b > 1 && mean_opt_b < N, "trade-off requires interior B*");

        // Extension table: tail quantiles ordered, cost decreasing in B.
        let x = tables[3].clone();
        let mut prev_cost = f64::INFINITY;
        for r in &x.rows {
            let p50: f64 = r[2].parse().unwrap();
            let p99: f64 = r[3].parse().unwrap();
            let p999: f64 = r[4].parse().unwrap();
            assert!(p50 < p99 && p99 < p999, "{r:?}");
            let cost: f64 = r[5].parse().unwrap();
            assert!(cost < prev_cost, "cost must fall with B: {r:?}");
            prev_cost = cost;
        }
    }
}
