//! Experiment drivers: one function per paper figure/table (DESIGN.md
//! experiment index E1–E8), each emitting CSV + Markdown into an output
//! directory and returning its [`Table`]s for inspection.
//!
//! Every driver is a thin sweep over the [`crate::evaluator`] API: build
//! self-describing scenarios, evaluate them with the appropriate
//! backend(s), tabulate. The context's `seed` is the only source of
//! randomness, so regenerated tables are bit-identical across runs.

pub mod ablations;
pub mod extensions;
pub mod fig2;
pub mod live;
pub mod policies;
pub mod spectrum;

use crate::evaluator::{DesEvaluator, MonteCarloEvaluator};
use crate::util::table::Table;
use std::path::PathBuf;

/// Shared experiment context.
#[derive(Debug, Clone)]
pub struct ExpContext {
    /// Output directory for CSV/Markdown.
    pub out_dir: PathBuf,
    /// Monte-Carlo trials per configuration.
    pub trials: u64,
    /// Root seed (propagated into every scenario, hence every backend).
    pub seed: u64,
}

impl Default for ExpContext {
    fn default() -> Self {
        Self { out_dir: PathBuf::from("results"), trials: 100_000, seed: 42 }
    }
}

impl ExpContext {
    /// Write a table under this context's output directory and echo it.
    pub fn emit(&self, stem: &str, table: &Table) -> anyhow::Result<()> {
        table.write_to(&self.out_dir, stem)?;
        table.print();
        Ok(())
    }

    /// The Monte-Carlo backend at this context's trial budget
    /// (auto-threaded; deterministic per machine for a fixed seed).
    pub fn mc(&self) -> MonteCarloEvaluator {
        MonteCarloEvaluator { trials: self.trials.max(1), ..MonteCarloEvaluator::default() }
    }

    /// The event-engine backend (costlier per trial: 1/5 the budget).
    pub fn des(&self) -> DesEvaluator {
        DesEvaluator { trials: (self.trials / 5).max(1), ..DesEvaluator::default() }
    }
}

/// Run every experiment (the `batchrep experiment all` entry).
pub fn run_all(ctx: &ExpContext, include_live: bool) -> anyhow::Result<Vec<Table>> {
    let mut tables = Vec::new();
    tables.extend(fig2::run(ctx)?);
    tables.extend(policies::run(ctx)?);
    tables.extend(spectrum::run(ctx)?);
    tables.extend(ablations::run(ctx)?);
    tables.extend(extensions::run(ctx)?);
    if include_live {
        tables.extend(live::run(ctx)?);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_smoke() {
        // Tiny trial count: checks wiring, file emission, and that every
        // driver returns at least one table.
        let dir = std::env::temp_dir().join("batchrep_exp_smoke");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 2_000, seed: 1 };
        let tables = run_all(&ctx, false).unwrap();
        assert!(tables.len() >= 8, "expected >= 8 tables, got {}", tables.len());
        assert!(dir.join("fig2_expected_completion.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
