//! Experiment drivers: one function per paper figure/table (DESIGN.md
//! experiment index E1–E12), each emitting CSV + Markdown into an
//! output directory and returning its [`Table`]s for inspection.
//!
//! Every driver is declarative: it builds one or two
//! [`crate::study::StudySpec`]s (axes over the quantities the figure
//! sweeps), compiles them into deduplicated execution plans, runs them
//! through the shared study pool ([`ExpContext::study`]), and tabulates
//! from the [`crate::study::StudyReport`] — no hand-rolled scenario
//! loops. The context's `seed` is the only source of randomness (cell
//! seeds are derived from it through the planner's canonical keys), so
//! regenerated tables are bit-identical across runs. The one deliberate
//! exception is [`control_loop`] (E12): a feedback loop cannot be a
//! static grid, so it drives the [`crate::control`] harness directly —
//! which shards its replicates over the same fixed plan, keeping the
//! bit-determinism guarantee.

pub mod ablations;
pub mod control_loop;
pub mod extensions;
pub mod fig2;
pub mod live;
pub mod policies;
pub mod spectrum;

use crate::study::{StudyReport, StudySpec};
use crate::util::table::Table;
use std::path::PathBuf;

/// Shared experiment context.
#[derive(Debug, Clone)]
pub struct ExpContext {
    /// Output directory for CSV/Markdown.
    pub out_dir: PathBuf,
    /// Monte-Carlo trials per configuration.
    pub trials: u64,
    /// Root seed (propagated into every scenario, hence every backend).
    pub seed: u64,
}

impl Default for ExpContext {
    fn default() -> Self {
        Self { out_dir: PathBuf::from("results"), trials: 100_000, seed: 42 }
    }
}

impl ExpContext {
    /// Write a table under this context's output directory and echo it.
    pub fn emit(&self, stem: &str, table: &Table) -> anyhow::Result<()> {
        table.write_to(&self.out_dir, stem)?;
        table.print();
        Ok(())
    }

    /// A study-spec skeleton carrying this context's budgets and seed:
    /// Monte-Carlo cells at the full trial budget, event-engine cells at
    /// 1/5 of it (costlier per trial). Drivers fill the axes via
    /// struct-update syntax.
    pub fn spec(&self, name: &str) -> StudySpec {
        StudySpec {
            mc_trials: self.trials.max(1),
            des_trials: (self.trials / 5).max(1),
            seed: self.seed,
            ..StudySpec::base(name)
        }
    }

    /// Compile and execute a study on the shared pool (all cores; the
    /// report is identical for any thread count).
    pub fn study(&self, spec: StudySpec) -> anyhow::Result<StudyReport> {
        let plan = spec.compile()?;
        crate::study::execute(&plan, crate::evaluator::auto_threads(), &mut |_, _, _, _| {})
    }
}

/// Run every experiment (the `batchrep experiment all` entry).
pub fn run_all(ctx: &ExpContext, include_live: bool) -> anyhow::Result<Vec<Table>> {
    let mut tables = Vec::new();
    tables.extend(fig2::run(ctx)?);
    tables.extend(policies::run(ctx)?);
    tables.extend(spectrum::run(ctx)?);
    tables.extend(ablations::run(ctx)?);
    tables.extend(extensions::run(ctx)?);
    tables.extend(control_loop::run(ctx)?);
    if include_live {
        tables.extend(live::run(ctx)?);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_smoke() {
        // Tiny trial count: checks wiring, file emission, and that every
        // driver returns at least one table.
        let dir = std::env::temp_dir().join("batchrep_exp_smoke");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 2_000, seed: 1 };
        let tables = run_all(&ctx, false).unwrap();
        assert!(tables.len() >= 8, "expected >= 8 tables, got {}", tables.len());
        assert!(dir.join("fig2_expected_completion.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
