//! E9/E10 — extension experiments beyond the paper's evaluation:
//!
//! * **E9 trace robustness** — replace the parametric service
//!   distributions with replayed Markov-modulated straggler traces
//!   (`trace` module; the documented substitution for production
//!   traces) and re-ask the paper's question: where is B* when
//!   stragglers are bursty rather than memoryless? Both spectra run
//!   through the same Monte-Carlo backend — the trace is just another
//!   `ServiceSpec` inside the scenario.
//! * **E10 partial aggregation (k-of-B)** — the gradient-coding regime
//!   the paper cites: the master proceeds with the earliest `k` of `B`
//!   batch results. `k_of_b` is a first-class [`Scenario`] field, so the
//!   same scenario value flows through the analytic closed form
//!   (`partial_completion_stats` behind `AnalyticEvaluator`) and the
//!   Monte-Carlo sampler — closed form vs simulation, and the
//!   latency/completeness frontier.

use super::ExpContext;
use crate::assignment::feasible_batch_counts;
use crate::des::Scenario;
use crate::dist::{BatchService, ServiceSpec};
use crate::evaluator::{AnalyticEvaluator, Evaluator, ReplicationPolicy};
use crate::trace::{generate_markov_trace, trace_spec, MarkovTraceParams};
use crate::util::table::{fmt_f, Table};

/// Workers.
pub const N: usize = 24;

/// Run E9 + E10.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    // --- E9: trace-driven spectrum ---
    let params = MarkovTraceParams::default();
    let trace = generate_markov_trace(&params, 200_000, ctx.seed ^ 0x7ACE);
    let spec = trace_spec(trace);
    let sexp_match = ServiceSpec::shifted_exp(
        1.0 / (spec.mean().unwrap() - params.base_delta),
        params.base_delta,
    );
    let mc = ctx.mc();
    let mut t9 = Table::new(
        "E9 — bursty straggler trace vs fitted SExp: E[T] across the spectrum (N=24)",
        &["B", "E[T] trace replay", "E[T] fitted SExp", "trace/SExp"],
    );
    let mut best_trace = (f64::INFINITY, 0usize);
    for &b in &feasible_batch_counts(N) {
        let seed = ctx.seed + b as u64;
        let scn_t = Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            N,
            b,
            BatchService::paper(spec.clone()),
            seed,
        )?;
        let scn_s = Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            N,
            b,
            BatchService::paper(sexp_match.clone()),
            seed,
        )?;
        let mt = mc.evaluate(&scn_t)?;
        let ms = mc.evaluate(&scn_s)?;
        if mt.mean < best_trace.0 {
            best_trace = (mt.mean, b);
        }
        t9.row(vec![
            b.to_string(),
            fmt_f(mt.mean, 4),
            fmt_f(ms.mean, 4),
            fmt_f(mt.mean / ms.mean, 3),
        ]);
    }
    ctx.emit("ext_trace_robustness", &t9)?;

    // --- E10: k-of-B partial aggregation (a scenario field, not a
    // bespoke sampler: every backend consumes the same value) ---
    let sexp = ServiceSpec::shifted_exp(1.0, 0.2);
    let service = BatchService::paper(sexp);
    let mut t10 = Table::new(
        "E10 — partial aggregation: wait for k of B batches (N=24, SExp(1,0.2))",
        &["B", "k", "k/B", "E[T] analytic", "E[T] sim", "speedup vs k=B"],
    );
    for &b in &[4usize, 8, 12] {
        let seed = ctx.seed ^ 0x0b_0f_b7 ^ (b as u64);
        let base = Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            N,
            b,
            service.clone(),
            seed,
        )?;
        let full = AnalyticEvaluator.evaluate(&base)?;
        for k in [b / 2, (3 * b) / 4, b] {
            let k = k.max(1);
            let scn = base.clone().with_k_of_b(k)?;
            let cf = AnalyticEvaluator.evaluate(&scn)?;
            let sim = mc.evaluate(&scn)?;
            t10.row(vec![
                b.to_string(),
                k.to_string(),
                fmt_f(k as f64 / b as f64, 2),
                fmt_f(cf.mean, 4),
                fmt_f(sim.mean, 4),
                fmt_f(full.mean / cf.mean, 3),
            ]);
        }
    }
    ctx.emit("ext_partial_aggregation", &t10)?;

    // --- E11: heterogeneous worker speeds (closed-form leg of the
    // conformance matrix) — per-worker-rate order statistics, exact for
    // Exp, a two-sided bound for SExp, against the same scenarios
    // simulated ---
    let mut t11 = Table::new(
        "E11 — heterogeneous speeds: analytic bounds vs simulation (N=24, B=4)",
        &["spread", "service", "E[T] lo", "E[T] hi", "E[T] sim", "sim inside"],
    );
    for &spread in &[1.0f64, 1.5, 3.0] {
        // Linear ramp with unit geometric midpoint: c_w ∈ [1/√spread, √spread].
        let (lo_c, hi_c) = (1.0 / spread.sqrt(), spread.sqrt());
        let speeds: Vec<f64> = (0..N)
            .map(|w| lo_c + (hi_c - lo_c) * w as f64 / (N - 1) as f64)
            .collect();
        for spec in [ServiceSpec::exp(1.0), ServiceSpec::shifted_exp(1.0, 0.3)] {
            let seed = ctx.seed ^ 0xE11 ^ (spread.to_bits() >> 32);
            let scn = Scenario::from_policy(
                ReplicationPolicy::BalancedDisjoint,
                N,
                4,
                BatchService::paper(spec.clone()),
                seed,
            )?
            .with_speeds(speeds.clone())?;
            let bounds = crate::analysis::hetero_completion_bounds(
                &scn.assignment,
                &spec,
                N as u64,
                &speeds,
            )?;
            let sim = mc.evaluate(&scn)?;
            let slack = 4.0 * sim.sem;
            let inside =
                sim.mean >= bounds.lower.mean - slack && sim.mean <= bounds.upper.mean + slack;
            t11.row(vec![
                fmt_f(spread, 2),
                spec.name(),
                fmt_f(bounds.lower.mean, 4),
                fmt_f(bounds.upper.mean, 4),
                fmt_f(sim.mean, 4),
                inside.to_string(),
            ]);
        }
    }
    ctx.emit("ext_hetero_speeds", &t11)?;

    Ok(vec![t9, t10, t11])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extension_tables_sound() {
        let dir = std::env::temp_dir().join("batchrep_ext_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 10_000, seed: 6 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // E9: bursty traces are heavier-tailed than the fitted SExp, so
        // replication (small B) must help *at least* as much — the ratio
        // should grow with B (replication hides bursts).
        let t9 = &tables[0];
        let first_ratio: f64 = t9.rows.first().unwrap()[3].parse().unwrap();
        let last_ratio: f64 = t9.rows.last().unwrap()[3].parse().unwrap();
        assert!(
            last_ratio >= first_ratio * 0.95,
            "burst penalty should not shrink with B: {first_ratio} -> {last_ratio}"
        );

        // E10: k < B is faster; analytic ≈ sim.
        for r in &tables[1].rows {
            let ana: f64 = r[3].parse().unwrap();
            let sim: f64 = r[4].parse().unwrap();
            assert!((ana - sim).abs() / ana < 0.05, "{r:?}");
            let speedup: f64 = r[5].parse().unwrap();
            assert!(speedup >= 0.999, "{r:?}");
        }

        // E11: every simulated mean sits inside its analytic bound, and
        // the bound is a point (lo == hi) exactly when the service is
        // Exponential or the cluster is homogeneous (spread = 1).
        for r in &tables[2].rows {
            assert_eq!(r[5], "true", "simulation escaped the bound: {r:?}");
            let spread: f64 = r[0].parse().unwrap();
            let (lo, hi): (f64, f64) = (r[2].parse().unwrap(), r[3].parse().unwrap());
            if spread == 1.0 || r[1].starts_with("exp:") {
                assert!((hi - lo).abs() < 1e-9, "bound should collapse: {r:?}");
            } else {
                assert!(hi > lo, "SExp spread must widen the bound: {r:?}");
            }
        }
    }
}
