//! E9/E10/E11 — extension experiments beyond the paper's evaluation:
//!
//! * **E9 trace robustness** — replace the parametric service
//!   distributions with replayed Markov-modulated straggler traces
//!   (`trace` module; the documented substitution for production
//!   traces) and re-ask the paper's question: where is B* when
//!   stragglers are bursty rather than memoryless? The trace is just
//!   another service-axis entry (trace specs key by content hash in the
//!   planner), swept next to its fitted SExp through one study.
//! * **E10 partial aggregation (k-of-B)** — the gradient-coding regime
//!   the paper cites: the master proceeds with the earliest `k` of `B`
//!   batch results. A k-target axis (`½B`, `¾B`, full) × a batch axis ×
//!   the `{analytic, montecarlo}` backend pair; the planner
//!   canonicalizes `k = B` onto the full-completion cell.
//! * **E11 heterogeneous worker speeds** — a speed-ramp axis across
//!   spreads; the closed-form leg (`hetero_completion_bounds`) brackets
//!   the simulated mean of the same scenarios.

use super::ExpContext;
use crate::assignment::feasible_batch_counts;
use crate::dist::{BatchService, ServiceSpec};
use crate::study::{BackendSel, BatchAxis, KTarget, SpeedAxis, StudySpec};
use crate::trace::{generate_markov_trace, trace_spec, MarkovTraceParams};
use crate::util::table::{fmt_f, Table};

/// Workers.
pub const N: usize = 24;

/// Run E9 + E10 + E11.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    // --- E9: trace-driven spectrum ---
    let params = MarkovTraceParams::default();
    let trace = generate_markov_trace(&params, 200_000, ctx.seed ^ 0x7ACE);
    let spec = trace_spec(trace);
    let trace_mean = spec
        .mean()
        .ok_or_else(|| anyhow::anyhow!("trace spectrum has no finite mean"))?;
    let sexp_match =
        ServiceSpec::shifted_exp(1.0 / (trace_mean - params.base_delta), params.base_delta);
    let mut t9 = Table::new(
        "E9 — bursty straggler trace vs fitted SExp: E[T] across the spectrum (N=24)",
        &["B", "E[T] trace replay", "E[T] fitted SExp", "trace/SExp"],
    );
    let t9_report = ctx.study(StudySpec {
        n_workers: vec![N],
        services: vec![BatchService::paper(spec), BatchService::paper(sexp_match)],
        ..ctx.spec("ext-trace-robustness")
    })?;
    for &b in &feasible_batch_counts(N) {
        let mt = t9_report.stats_where(&|c| c.b == b && c.service_idx == 0)?;
        let ms = t9_report.stats_where(&|c| c.b == b && c.service_idx == 1)?;
        t9.row(vec![
            b.to_string(),
            fmt_f(mt.mean, 4),
            fmt_f(ms.mean, 4),
            fmt_f(mt.mean / ms.mean, 3),
        ]);
    }
    ctx.emit("ext_trace_robustness", &t9)?;

    // --- E10: k-of-B partial aggregation (a scenario field and a
    // planner axis, not a bespoke sampler: every backend consumes the
    // same value, and k = B is canonicalized onto the full cell) ---
    let sexp = ServiceSpec::shifted_exp(1.0, 0.2);
    let mut t10 = Table::new(
        "E10 — partial aggregation: wait for k of B batches (N=24, SExp(1,0.2))",
        &["B", "k", "k/B", "E[T] analytic", "E[T] sim", "speedup vs k=B"],
    );
    let k_axis = [KTarget::Fraction(0.5), KTarget::Fraction(0.75), KTarget::Full];
    let t10_report = ctx.study(StudySpec {
        n_workers: vec![N],
        batches: BatchAxis::Explicit(vec![4, 8, 12]),
        services: vec![BatchService::paper(sexp.clone())],
        k_targets: k_axis.to_vec(),
        backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo],
        ..ctx.spec("ext-partial-aggregation")
    })?;
    for &b in &[4usize, 8, 12] {
        let full = t10_report
            .stats_where(&|c| c.b == b && c.k_idx == 2 && c.backend == BackendSel::Analytic)?
            .clone();
        for ki in 0..k_axis.len() {
            // The printed k is the planner-resolved coordinate of the
            // evaluated cell (None = full completion), not a local
            // re-derivation of the fraction rule.
            let point = t10_report
                .point_where(&|c| {
                    c.b == b && c.k_idx == ki && c.backend == BackendSel::Analytic
                })
                .ok_or_else(|| anyhow::anyhow!("E10 grid missing (B={b}, k_idx={ki})"))?;
            let k = point.coords.k_of_b.unwrap_or(b);
            let cf = t10_report.stats_where(&|c| {
                c.b == b && c.k_idx == ki && c.backend == BackendSel::Analytic
            })?;
            let sim = t10_report.stats_where(&|c| {
                c.b == b && c.k_idx == ki && c.backend == BackendSel::MonteCarlo
            })?;
            t10.row(vec![
                b.to_string(),
                k.to_string(),
                fmt_f(k as f64 / b as f64, 2),
                fmt_f(cf.mean, 4),
                fmt_f(sim.mean, 4),
                fmt_f(full.mean / cf.mean, 3),
            ]);
        }
    }
    ctx.emit("ext_partial_aggregation", &t10)?;

    // --- E11: heterogeneous worker speeds (closed-form leg of the
    // conformance matrix) — per-worker-rate order statistics, exact for
    // Exp, a two-sided bound for SExp, against the same scenarios
    // simulated ---
    let mut t11 = Table::new(
        "E11 — heterogeneous speeds: analytic bounds vs simulation (N=24, B=4)",
        &["spread", "service", "E[T] lo", "E[T] hi", "E[T] sim", "sim inside"],
    );
    let spreads = [1.0f64, 1.5, 3.0];
    // Linear ramp with unit geometric midpoint: c_w ∈ [1/√spread, √spread].
    let ramp_of = |spread: f64| SpeedAxis::Ramp {
        lo: 1.0 / spread.sqrt(),
        hi: spread.sqrt(),
    };
    let t11_report = ctx.study(StudySpec {
        n_workers: vec![N],
        batches: BatchAxis::Explicit(vec![4]),
        services: vec![
            BatchService::paper(ServiceSpec::exp(1.0)),
            BatchService::paper(ServiceSpec::shifted_exp(1.0, 0.3)),
        ],
        speeds: spreads.iter().map(|&s| ramp_of(s)).collect(),
        ..ctx.spec("ext-hetero-speeds")
    })?;
    let assignment = crate::assignment::balanced(N, 4)?;
    for (wi, &spread) in spreads.iter().enumerate() {
        // The bounds leg consumes the same resolved vector the planner
        // gave the simulated cells (spread = 1 canonicalizes to the
        // homogeneous cluster, i.e. unit factors).
        let speeds = ramp_of(spread).resolve(N)?.unwrap_or_else(|| vec![1.0; N]);
        for (si, spec) in
            [ServiceSpec::exp(1.0), ServiceSpec::shifted_exp(1.0, 0.3)].iter().enumerate()
        {
            let bounds = crate::analysis::hetero_completion_bounds(
                &assignment,
                spec,
                N as u64,
                &speeds,
            )?;
            let sim = t11_report
                .stats_where(&|c| c.service_idx == si && c.speeds_idx == wi)?;
            let slack = 4.0 * sim.sem;
            let inside =
                sim.mean >= bounds.lower.mean - slack && sim.mean <= bounds.upper.mean + slack;
            t11.row(vec![
                fmt_f(spread, 2),
                spec.name(),
                fmt_f(bounds.lower.mean, 4),
                fmt_f(bounds.upper.mean, 4),
                fmt_f(sim.mean, 4),
                inside.to_string(),
            ]);
        }
    }
    ctx.emit("ext_hetero_speeds", &t11)?;

    Ok(vec![t9, t10, t11])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extension_tables_sound() {
        let dir = std::env::temp_dir().join("batchrep_ext_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 10_000, seed: 6 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // E9: bursty traces are heavier-tailed than the fitted SExp, so
        // replication (small B) must help *at least* as much — the ratio
        // should grow with B (replication hides bursts).
        let t9 = &tables[0];
        let first_ratio: f64 = t9.rows.first().unwrap()[3].parse().unwrap();
        let last_ratio: f64 = t9.rows.last().unwrap()[3].parse().unwrap();
        assert!(
            last_ratio >= first_ratio * 0.95,
            "burst penalty should not shrink with B: {first_ratio} -> {last_ratio}"
        );

        // E10: k < B is faster; analytic ≈ sim.
        for r in &tables[1].rows {
            let ana: f64 = r[3].parse().unwrap();
            let sim: f64 = r[4].parse().unwrap();
            assert!((ana - sim).abs() / ana < 0.05, "{r:?}");
            let speedup: f64 = r[5].parse().unwrap();
            assert!(speedup >= 0.999, "{r:?}");
        }

        // E11: every simulated mean sits inside its analytic bound, and
        // the bound is a point (lo == hi) exactly when the service is
        // Exponential or the cluster is homogeneous (spread = 1).
        for r in &tables[2].rows {
            assert_eq!(r[5], "true", "simulation escaped the bound: {r:?}");
            let spread: f64 = r[0].parse().unwrap();
            let (lo, hi): (f64, f64) = (r[2].parse().unwrap(), r[3].parse().unwrap());
            if spread == 1.0 || r[1].starts_with("exp:") {
                assert!((hi - lo).abs() < 1e-9, "bound should collapse: {r:?}");
            } else {
                assert!(hi > lo, "SExp spread must widen the bound: {r:?}");
            }
        }
    }
}
