//! E1 — paper Fig. 2: expected completion time vs number of batches
//! `B`, Shifted-Exponential per-sample service, one curve per `∆µ`.
//!
//! The paper plots `E[T] = N∆/B + H_B/µ` over `B ∈ F_B` and observes
//! that larger `∆µ` pushes the optimum toward parallelism. We reproduce
//! each curve twice — closed form and Monte-Carlo simulation — and they
//! must agree to sampling error, which is the repo's strongest check
//! that simulator and theory describe the same system.

use super::ExpContext;
use crate::analysis;
use crate::assignment::feasible_batch_counts;
use crate::des::{montecarlo, Scenario};
use crate::dist::{BatchService, ServiceSpec};
use crate::util::table::{fmt_f, Table};

/// Workers, matching the paper's figure scale (divisor-rich).
pub const N: u64 = 24;
/// Service rate µ.
pub const MU: f64 = 1.0;
/// The ∆µ products plotted (the paper's λ legend).
pub const DELTA_MUS: [f64; 5] = [0.05, 0.2, 0.5, 1.0, 2.0];

/// Run E1: one table of curves + one table of optima.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let mut curve = Table::new(
        "Fig. 2 — E[T] vs B (Shifted-Exponential service), analytic vs simulated",
        &["delta_mu", "B", "g=N/B", "E[T] analytic", "E[T] sim", "ci95", "Var analytic", "Var sim"],
    );
    let mut optima = Table::new(
        "Fig. 2 companion — optimum B* per delta_mu (Theorem 3)",
        &["delta_mu", "B* analytic", "B* sim", "E[T] at B*"],
    );

    for (di, &dm) in DELTA_MUS.iter().enumerate() {
        let spec = ServiceSpec::shifted_exp(MU, dm / MU);
        let mut best_sim = (f64::INFINITY, 1u64);
        for &b in &feasible_batch_counts(N as usize) {
            let b = b as u64;
            let cf = analysis::completion_time_stats(N, b, &spec)?;
            let scn = Scenario::paper_balanced(
                N as usize,
                b as usize,
                BatchService::paper(spec.clone()),
            )?;
            let mc = montecarlo::run_trials(&scn, ctx.trials, ctx.seed + di as u64 * 131 + b);
            if mc.mean() < best_sim.0 {
                best_sim = (mc.mean(), b);
            }
            curve.row(vec![
                fmt_f(dm, 2),
                b.to_string(),
                (N / b).to_string(),
                fmt_f(cf.mean, 4),
                fmt_f(mc.mean(), 4),
                fmt_f(mc.ci95(), 4),
                fmt_f(cf.var, 4),
                fmt_f(mc.variance(), 4),
            ]);
        }
        let b_star = analysis::optimum_b(N, &spec);
        let at_star = analysis::completion_time_stats(N, b_star, &spec)?.mean;
        optima.row(vec![
            fmt_f(dm, 2),
            b_star.to_string(),
            best_sim.1.to_string(),
            fmt_f(at_star, 4),
        ]);
    }

    ctx.emit("fig2_expected_completion", &curve)?;
    ctx.emit("fig2_optima", &optima)?;
    Ok(vec![curve, optima])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape_holds() {
        let dir = std::env::temp_dir().join("batchrep_fig2_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 20_000, seed: 3 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let optima = &tables[1];
        // B* must be nondecreasing in delta_mu (the paper's headline
        // qualitative claim), and the simulated optimum must be
        // mean-equivalent to the analytic one (exact tie-breaks between
        // near-equal B values are sampling noise, not errors).
        let mut prev = 0u64;
        for row in &optima.rows {
            let dm: f64 = row[0].parse().unwrap();
            let b_ana: u64 = row[1].parse().unwrap();
            let b_sim: u64 = row[2].parse().unwrap();
            assert!(b_ana >= prev, "B* not monotone: {:?}", optima.rows);
            prev = b_ana;
            let spec = ServiceSpec::shifted_exp(MU, dm / MU);
            let at_ana = analysis::completion_time_stats(N, b_ana, &spec).unwrap().mean;
            let at_sim = analysis::completion_time_stats(N, b_sim, &spec).unwrap().mean;
            assert!(
                (at_sim - at_ana) / at_ana < 0.02,
                "sim optimum B={b_sim} is not near-optimal: {at_sim} vs {at_ana}"
            );
        }
        // Smallest delta_mu (0.05) → near-full diversity (B* = 2:
        // 1.2/B + H_B is minimized at 2); largest → parallelism end.
        let first: u64 = optima.rows[0][1].parse().unwrap();
        assert!(first <= 2, "{:?}", optima.rows[0]);
        let last: u64 = optima.rows.last().unwrap()[1].parse().unwrap();
        assert!(last >= 12);
    }
}
