//! E1 — paper Fig. 2: expected completion time vs number of batches
//! `B`, Shifted-Exponential per-sample service, one curve per `∆µ`.
//!
//! The paper plots `E[T] = N∆/B + H_B/µ` over `B ∈ F_B` and observes
//! that larger `∆µ` pushes the optimum toward parallelism. The whole
//! figure is **one study**: a ∆µ-service axis × the feasible batch
//! counts × the `{analytic, montecarlo}` backend axis, compiled into a
//! deduplicated plan and executed on the shared pool. Each grid point's
//! two cells are then validated against each other with
//! [`cross_check_stats`] — the repo's strongest check that simulator
//! and theory describe the same system.

use super::ExpContext;
use crate::analysis;
use crate::assignment::feasible_batch_counts;
use crate::dist::{BatchService, ServiceSpec};
use crate::evaluator::cross_check_stats;
use crate::study::BackendSel;
use crate::util::table::{fmt_f, Table};

/// Workers, matching the paper's figure scale (divisor-rich).
pub const N: usize = 24;
/// Service rate µ.
pub const MU: f64 = 1.0;
/// The ∆µ products plotted (the paper's λ legend).
pub const DELTA_MUS: [f64; 5] = [0.05, 0.2, 0.5, 1.0, 2.0];

/// Run E1: one table of curves + one table of optima. Every row is a
/// cross-checked (analytic, Monte-Carlo) cell pair from one study.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    let mut curve = Table::new(
        "Fig. 2 — E[T] vs B (Shifted-Exponential service), analytic vs simulated",
        &["delta_mu", "B", "g=N/B", "E[T] analytic", "E[T] sim", "ci95", "Var analytic", "Var sim"],
    );
    let mut optima = Table::new(
        "Fig. 2 companion — optimum B* per delta_mu (Theorem 3)",
        &["delta_mu", "B* analytic", "B* sim", "E[T] at B*"],
    );

    let spec = crate::study::StudySpec {
        n_workers: vec![N],
        services: DELTA_MUS
            .iter()
            .map(|&dm| BatchService::paper(ServiceSpec::shifted_exp(MU, dm / MU)))
            .collect(),
        backends: vec![BackendSel::Analytic, BackendSel::MonteCarlo],
        ..ctx.spec("fig2")
    };
    let report = ctx.study(spec)?;

    for (di, &dm) in DELTA_MUS.iter().enumerate() {
        let mut best_sim = (f64::INFINITY, 1usize);
        for &b in &feasible_batch_counts(N) {
            let cf = report
                .stats_where(&|c| {
                    c.service_idx == di && c.b == b && c.backend == BackendSel::Analytic
                })?
                .clone();
            let sim = report
                .stats_where(&|c| {
                    c.service_idx == di && c.b == b && c.backend == BackendSel::MonteCarlo
                })?
                .clone();
            // The paper's own validation, as one API call: theory and
            // simulation must agree on this point or the run fails.
            let ck = cross_check_stats("analytic", "montecarlo", cf, sim)?;
            let (cf, sim) = (&ck.a, &ck.b);
            if sim.mean < best_sim.0 {
                best_sim = (sim.mean, b);
            }
            curve.row(vec![
                fmt_f(dm, 2),
                b.to_string(),
                (N / b).to_string(),
                fmt_f(cf.mean, 4),
                fmt_f(sim.mean, 4),
                fmt_f(sim.ci95(), 4),
                fmt_f(cf.variance, 4),
                fmt_f(sim.variance, 4),
            ]);
        }
        let spec = ServiceSpec::shifted_exp(MU, dm / MU);
        let b_star = analysis::optimum_b(N as u64, &spec)?;
        let at_star = analysis::completion_time_stats(N as u64, b_star, &spec)?.mean;
        optima.row(vec![
            fmt_f(dm, 2),
            b_star.to_string(),
            best_sim.1.to_string(),
            fmt_f(at_star, 4),
        ]);
    }

    ctx.emit("fig2_expected_completion", &curve)?;
    ctx.emit("fig2_optima", &optima)?;
    Ok(vec![curve, optima])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape_holds() {
        let dir = std::env::temp_dir().join("batchrep_fig2_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 20_000, seed: 3 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let optima = &tables[1];
        // B* must be nondecreasing in delta_mu (the paper's headline
        // qualitative claim), and the simulated optimum must be
        // mean-equivalent to the analytic one (exact tie-breaks between
        // near-equal B values are sampling noise, not errors).
        let mut prev = 0u64;
        for row in &optima.rows {
            let dm: f64 = row[0].parse().unwrap();
            let b_ana: u64 = row[1].parse().unwrap();
            let b_sim: u64 = row[2].parse().unwrap();
            assert!(b_ana >= prev, "B* not monotone: {:?}", optima.rows);
            prev = b_ana;
            let spec = ServiceSpec::shifted_exp(MU, dm / MU);
            let at_ana = analysis::completion_time_stats(N as u64, b_ana, &spec).unwrap().mean;
            let at_sim = analysis::completion_time_stats(N as u64, b_sim, &spec).unwrap().mean;
            assert!(
                (at_sim - at_ana) / at_ana < 0.02,
                "sim optimum B={b_sim} is not near-optimal: {at_sim} vs {at_ana}"
            );
        }
        // Smallest delta_mu (0.05) → near-full diversity (B* = 2:
        // 1.2/B + H_B is minimized at 2); largest → parallelism end.
        let first: u64 = optima.rows[0][1].parse().unwrap();
        assert!(first <= 2, "{:?}", optima.rows[0]);
        let last: u64 = optima.rows.last().unwrap()[1].parse().unwrap();
        assert!(last >= 12);
    }

    #[test]
    fn every_curve_point_is_cross_checked() {
        // The run itself enforces theory≈simulation per point; this
        // spot-checks that the emitted numbers reflect that.
        let dir = std::env::temp_dir().join("batchrep_fig2_ck_test");
        let ctx = ExpContext { out_dir: dir.clone(), trials: 15_000, seed: 8 };
        let tables = run(&ctx).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        for row in &tables[0].rows {
            let ana: f64 = row[3].parse().unwrap();
            let sim: f64 = row[4].parse().unwrap();
            assert!((ana - sim).abs() / ana < 0.05, "{row:?}");
        }
    }
}
