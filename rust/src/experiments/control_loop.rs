//! E12 — closed-loop adaptive control: regret vs the oracle plan.
//!
//! Unlike E1–E11 this driver does not sweep a study grid — the quantity
//! under test is the *feedback loop* (estimate → plan → observe), so it
//! runs the [`crate::control`] harness directly: the controller starts
//! from a deliberately mis-specified prior, sees only censored
//! per-replica telemetry, and is scored per epoch against the oracle
//! batch count computed from the hidden true spec via the `analysis`
//! closed forms.
//!
//! * **E12a (stationary)** — the `smoke` preset across objectives
//!   (mean, λ-blend, variance): does the chosen B converge to the
//!   oracle B*, and how much regret does the mis-specified start cost?
//! * **E12b (drift)** — the `drift` preset trajectory: the truth shifts
//!   from ∆µ = 1.0 (oracle: full parallelism) to ∆µ = 0.02 (oracle:
//!   full replication) mid-run; the CUSUM must catch it and the
//!   controller re-converge from post-change data.
//!
//! Replicates run over the crate's fixed shard plan, so both tables are
//! bit-identical across runs and thread counts for a fixed seed.

use super::ExpContext;
use crate::control::{plan, ControlSpec, Objective, TrueService};
use crate::evaluator::auto_threads;
use crate::util::table::{fmt_f, Table};

/// Scale a preset to the context's budget: small smoke budgets get the
/// `fast()` cut, full runs keep the preset sizes.
fn sized(ctx: &ExpContext, spec: ControlSpec) -> ControlSpec {
    if ctx.trials < 10_000 {
        spec.fast()
    } else {
        spec
    }
}

/// Run E12a + E12b.
pub fn run(ctx: &ExpContext) -> anyhow::Result<Vec<Table>> {
    // --- E12a: stationary convergence across objectives ---
    let objectives =
        [Objective::Mean, Objective::Blend { lambda: 0.5 }, Objective::Variance];
    let mut t12a = Table::new(
        "E12a — adaptive controller vs oracle: stationary truth SExp(1,0.2), \
         prior SExp(4,0.8), N=12",
        &[
            "objective",
            "prior B",
            "oracle B",
            "final mean B",
            "frac@oracle",
            "final rel regret",
            "replans",
            "drift replans",
        ],
    );
    for obj in &objectives {
        let mut spec = sized(ctx, ControlSpec::smoke());
        spec.objective = obj.clone();
        spec.seed = ctx.seed;
        spec.name = format!("e12-{}", obj.name());
        let prior_b = plan(spec.n_workers, &spec.prior, obj)?.b;
        let report = spec.run(auto_threads())?;
        let last = report
            .epochs
            .last()
            .ok_or_else(|| anyhow::anyhow!("control run produced no epochs"))?;
        let replans: u64 = report.epochs.iter().map(|e| e.replans).sum();
        let drifts: u64 = report.epochs.iter().map(|e| e.drift_replans).sum();
        t12a.row(vec![
            obj.name(),
            prior_b.to_string(),
            last.oracle_b.to_string(),
            fmt_f(last.mean_b, 2),
            fmt_f(last.frac_oracle, 2),
            fmt_f(last.mean_rel_regret, 4),
            replans.to_string(),
            drifts.to_string(),
        ]);
    }
    ctx.emit("e12_control_regret", &t12a)?;

    // --- E12b: drift trajectory, mean objective ---
    let mut spec = sized(ctx, ControlSpec::drift());
    spec.seed = ctx.seed;
    let truth = TrueService::piecewise(spec.phases.clone())?;
    let report = spec.run(auto_threads())?;
    let mut t12b = Table::new(
        "E12b — drift re-convergence: truth shifts SExp(1,1) → SExp(1,0.02) at \
         epoch 12 (N=24, mean objective)",
        &[
            "epoch",
            "truth",
            "oracle B",
            "mean B",
            "frac@oracle",
            "mean regret",
            "rel regret",
            "replans",
            "drift replans",
        ],
    );
    for e in &report.epochs {
        t12b.row(vec![
            e.epoch.to_string(),
            truth.at(e.epoch).name(),
            e.oracle_b.to_string(),
            fmt_f(e.mean_b, 2),
            fmt_f(e.frac_oracle, 2),
            fmt_f(e.mean_regret, 4),
            fmt_f(e.mean_rel_regret, 4),
            e.replans.to_string(),
            e.drift_replans.to_string(),
        ]);
    }
    ctx.emit("e12_control_drift", &t12b)?;

    Ok(vec![t12a, t12b])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExpContext {
        let dir = std::env::temp_dir().join("batchrep_e12_test");
        std::fs::create_dir_all(&dir).expect("mkdir");
        ExpContext { out_dir: dir, trials: 2_000, seed: 1 }
    }

    #[test]
    fn e12_demonstrates_adaptation() {
        let tables = run(&ctx()).expect("run");
        assert_eq!(tables.len(), 2);

        // E12a: the mean-objective row converges to the oracle plan.
        let t12a = &tables[0];
        let mean_row = &t12a.rows[0];
        assert_eq!(mean_row[0], "mean");
        assert_eq!(mean_row[1], "12", "mis-specified prior should plan full parallelism");
        assert_eq!(mean_row[2], "3", "oracle B* for SExp(1,0.2), N=12");
        let frac: f64 = mean_row[4].parse().expect("frac");
        let rel: f64 = mean_row[5].parse().expect("rel regret");
        assert!(frac >= 0.75, "frac@oracle = {frac}");
        assert!(rel < 0.05, "final rel regret = {rel}");
        // The variance objective is minimized at full replication for
        // any exp-family parameters, so prior and oracle agree at B=1.
        let var_row = &t12a.rows[2];
        assert_eq!(var_row[0], "variance");
        assert_eq!(var_row[1], "1");
        assert_eq!(var_row[2], "1");

        // E12b: converged pre-shift, regret spike at the shift epoch,
        // re-converged by the end.
        let t12b = &tables[1];
        let shift = 12usize;
        let pre: f64 = t12b.rows[shift - 1][4].parse().expect("pre frac");
        let at_regret: f64 = t12b.rows[shift][5].parse().expect("shift regret");
        let pre_regret: f64 = t12b.rows[shift - 1][5].parse().expect("pre regret");
        let final_frac: f64 = t12b.rows.last().expect("rows")[4].parse().expect("final frac");
        assert!(pre >= 0.75, "pre-shift frac@oracle = {pre}");
        assert!(at_regret > 5.0 * pre_regret.max(1e-9), "no regret spike at the shift");
        assert!(final_frac >= 0.75, "final frac@oracle = {final_frac}");
        // Oracle flips from full parallelism to full replication.
        assert_eq!(t12b.rows[shift - 1][2], "24");
        assert_eq!(t12b.rows[shift][2], "1");
    }
}
