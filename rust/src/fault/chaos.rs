//! The chaos harness behind `batchrep chaos`: replay a declarative
//! [`FaultPlan`] against a replicated round loop for many Monte-Carlo
//! replicates and aggregate recovery behaviour into a
//! [`ChaosReport`] artifact.
//!
//! The round loop is the DES fault model
//! ([`crate::des::engine::simulate_fault_rounds`]), which mirrors the
//! live coordinator's semantics event for event — crash, backoff
//! respawn, deadline relaunch, degraded re-plan, task drop — so the
//! artifact characterizes both backends (the conformance matrix's
//! `live<->des-fault` cells pin the equivalence). Replicates fan out
//! over the crate's block shard plan, so the report is bit-identical
//! for a fixed `(spec, seed)` at any `--threads`.

use super::report::{ChaosReport, RoundAgg};
use super::{FaultEvent, FaultPlan};
use crate::des::engine::{simulate_fault_rounds, EngineConfig, FaultRoundStats};
use crate::des::montecarlo::{execute_shard_plan, shard_plan};
use crate::des::Scenario;
use crate::dist::{BatchService, ServiceSpec};
use crate::trace::MarkovTraceParams;
use crate::util::json::Json;
use crate::util::stats::Welford;
use std::collections::VecDeque;

/// One chaos experiment: a balanced-disjoint cluster, a service law,
/// and a fault plan replayed for `rounds` rounds per replicate.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosSpec {
    /// Experiment name (artifact stem).
    pub name: String,
    /// Cluster size `N`.
    pub n_workers: usize,
    /// Batch count `B` (`B | N`, balanced disjoint replication).
    pub n_batches: usize,
    /// Per-unit service law.
    pub service: ServiceSpec,
    /// The fault schedule to replay.
    pub plan: FaultPlan,
    /// Rounds per replicate.
    pub rounds: u64,
    /// Monte-Carlo replicates (service-time draws differ; the fault
    /// schedule is identical in every replicate).
    pub replicates: u64,
    /// Root seed for the replicate shard plan.
    pub seed: u64,
    /// Result-integrity vote size `m` (0 or 1 disables verification;
    /// `m >= 2` makes each batch wait for `m` replicas and vote, so
    /// the plan's `corruption` events become detectable).
    pub verify_m: u64,
}

impl ChaosSpec {
    /// Names accepted by [`ChaosSpec::preset`].
    pub fn preset_names() -> &'static [&'static str] {
        &["smoke", "fig2"]
    }

    /// Small mixed-fault preset: a transient crash, a congestion
    /// slowdown, and a lossy worker on an 8-worker, 4-batch cluster.
    pub fn smoke() -> ChaosSpec {
        ChaosSpec {
            name: "smoke".into(),
            n_workers: 8,
            n_batches: 4,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            plan: FaultPlan {
                name: "smoke".into(),
                seed: 42,
                events: vec![
                    (0, FaultEvent::TransientCrash { round: 2, fraction: 0.5, respawn_after: 2 }),
                    (
                        1,
                        FaultEvent::Slowdown {
                            from_round: 1,
                            rounds: 12,
                            params: MarkovTraceParams::default(),
                        },
                    ),
                    (2, FaultEvent::TaskDrop { prob: 0.05 }),
                ],
            },
            rounds: 40,
            replicates: 16,
            seed: 42,
            verify_m: 0,
        }
    }

    /// Fig-2-scale transient-crash preset: 24 workers, 6 batches
    /// (replication group 4), the built-in `respawn` plan.
    pub fn fig2() -> ChaosSpec {
        ChaosSpec {
            name: "fig2".into(),
            n_workers: 24,
            n_batches: 6,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            plan: FaultPlan::respawn_preset(),
            rounds: 48,
            replicates: 16,
            seed: 42,
            verify_m: 0,
        }
    }

    /// Look up a built-in preset.
    pub fn preset(name: &str) -> Option<ChaosSpec> {
        match name {
            "smoke" => Some(Self::smoke()),
            "fig2" => Some(Self::fig2()),
            _ => None,
        }
    }

    /// Resolve a CLI argument: a preset name, else a path to a spec
    /// JSON file (see [`ChaosSpec::from_json`]).
    pub fn load(which: &str) -> anyhow::Result<ChaosSpec> {
        if let Some(spec) = Self::preset(which) {
            return Ok(spec);
        }
        let text = std::fs::read_to_string(which).map_err(|e| {
            anyhow::anyhow!(
                "'{which}' is not a chaos preset ({}) and not a readable file: {e}",
                Self::preset_names().join(", ")
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {which}: {e}"))?;
        let spec = Self::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON:
    ///
    /// ```json
    /// {"name": "my-chaos", "n_workers": 8, "n_batches": 4,
    ///  "service": "sexp:1,0.2", "rounds": 40, "replicates": 16,
    ///  "seed": 42, "plan": {"name": "...", "seed": 42, "events": [...]}}
    /// ```
    ///
    /// Optional keys default to the `smoke` preset's values; `plan` is
    /// required and uses the [`FaultPlan::from_json`] format.
    pub fn from_json(j: &Json) -> anyhow::Result<ChaosSpec> {
        let base = Self::smoke();
        let plan_j = j
            .get("plan")
            .ok_or_else(|| anyhow::anyhow!("chaos spec needs a 'plan' object"))?;
        let service = match j.get("service").and_then(Json::as_str) {
            Some(s) => ServiceSpec::parse(s)?,
            None => base.service,
        };
        let get_u = |key: &str, default: u64| -> anyhow::Result<u64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_i64()
                    .filter(|x| *x >= 0)
                    .map(|x| x as u64)
                    .ok_or_else(|| anyhow::anyhow!("'{key}' must be a non-negative integer")),
            }
        };
        Ok(ChaosSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or(&base.name)
                .to_string(),
            n_workers: get_u("n_workers", base.n_workers as u64)? as usize,
            n_batches: get_u("n_batches", base.n_batches as u64)? as usize,
            service,
            plan: FaultPlan::from_json(plan_j)?,
            rounds: get_u("rounds", base.rounds)?,
            replicates: get_u("replicates", base.replicates)?,
            seed: get_u("seed", base.seed)?,
            verify_m: get_u("verify_m", 0)?,
        })
    }

    /// Serialize (round-trips through [`ChaosSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("n_workers", self.n_workers.into()),
            ("n_batches", self.n_batches.into()),
            ("service", self.service.name().as_str().into()),
            ("rounds", (self.rounds as i64).into()),
            ("replicates", (self.replicates as i64).into()),
            ("seed", (self.seed as i64).into()),
            ("verify_m", (self.verify_m as i64).into()),
            ("plan", self.plan.to_json()),
        ])
    }

    /// Check internal consistency (cluster shape, counts, plan).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "n_workers must be >= 1");
        anyhow::ensure!(
            self.n_batches >= 1 && self.n_batches <= self.n_workers,
            "n_batches must be in [1, n_workers]"
        );
        anyhow::ensure!(
            self.n_workers % self.n_batches == 0,
            "chaos runs use balanced replication: n_batches must divide n_workers"
        );
        anyhow::ensure!(self.rounds >= 1, "rounds must be >= 1");
        anyhow::ensure!(self.replicates >= 1, "replicates must be >= 1");
        if self.verify_m > 0 {
            let degree = (self.n_workers / self.n_batches) as u64;
            anyhow::ensure!(
                self.verify_m <= degree,
                "verify_m = {} exceeds the replication degree {degree}",
                self.verify_m
            );
        }
        self.plan.validate(self.n_workers)
    }

    /// Shrink for `--fast` smoke runs (caps replicates and rounds).
    pub fn fast(mut self) -> ChaosSpec {
        self.replicates = self.replicates.min(8);
        self.rounds = self.rounds.min(16);
        self
    }
}

/// Run the chaos experiment: `spec.replicates` independent replicates
/// of `spec.rounds` fault-injected rounds, sharded over `threads`
/// workers with the block shard plan (bit-identical results for any
/// `threads`). The fault/recovery counters and the liveness trajectory
/// are schedule-driven and must agree across replicates — divergence is
/// an internal-determinism error; only the round completion time is a
/// random variable and gets mean/sem aggregation.
pub fn run_chaos(spec: &ChaosSpec, threads: usize) -> anyhow::Result<ChaosReport> {
    spec.validate()?;
    let mut scn = Scenario::paper_balanced(
        spec.n_workers,
        spec.n_batches,
        BatchService::paper(spec.service.clone()),
    )?
    .with_seed(spec.seed);
    if spec.verify_m > 0 {
        scn = scn.with_verify_m(spec.verify_m as usize)?;
    }
    let plan = spec.plan.compile(spec.n_workers)?;
    let cfg = EngineConfig::default();
    let shards = shard_plan(spec.replicates, spec.seed);
    let per_shard: Vec<anyhow::Result<Vec<Vec<FaultRoundStats>>>> = execute_shard_plan(
        shards,
        threads,
        || (),
        |_, count, mut rng| {
            (0..count)
                .map(|_| simulate_fault_rounds(&scn, &plan, spec.rounds, &cfg, &mut rng))
                .collect()
        },
    );
    let mut runs: Vec<Vec<FaultRoundStats>> = Vec::with_capacity(spec.replicates as usize);
    for shard in per_shard {
        runs.extend(shard?);
    }
    anyhow::ensure!(!runs.is_empty(), "chaos run produced no replicates");

    let schedule_key = |s: &FaultRoundStats| {
        (
            s.crashes,
            s.respawns,
            s.relaunches,
            s.degradations,
            s.dropped,
            s.corrupted,
            s.flagged,
            s.quarantined,
            s.live_workers,
        )
    };
    let mut per_round = Vec::with_capacity(spec.rounds as usize);
    for r in 0..spec.rounds as usize {
        let first = runs[0][r];
        let mut comp = Welford::new();
        for run in &runs {
            let st = run[r];
            anyhow::ensure!(
                schedule_key(&st) == schedule_key(&first),
                "fault schedule diverged across replicates at round {r}"
            );
            comp.push(st.completion);
        }
        per_round.push(RoundAgg {
            round: r as u64,
            mean_completion: comp.mean(),
            sem_completion: comp.sem(),
            live_workers: first.live_workers,
            crashes: first.crashes,
            respawns: first.respawns,
            relaunches: first.relaunches,
            degradations: first.degradations,
            dropped: first.dropped,
            corrupted: first.corrupted,
            flagged: first.flagged,
            quarantined: first.quarantined,
        });
    }

    // MTTR: FIFO-match each respawn to the oldest outstanding crash.
    // Respawns fire at round start (before that round's crashes), so
    // they are consumed before the round's crashes are enqueued.
    let mut outstanding: VecDeque<u64> = VecDeque::new();
    let mut mttr_sum = 0.0;
    let mut mttr_n = 0u64;
    for agg in &per_round {
        for _ in 0..agg.respawns {
            if let Some(crashed_at) = outstanding.pop_front() {
                mttr_sum += (agg.round - crashed_at) as f64;
                mttr_n += 1;
            }
        }
        for _ in 0..agg.crashes {
            outstanding.push_back(agg.round);
        }
    }
    let mttr_rounds = if mttr_n > 0 { mttr_sum / mttr_n as f64 } else { 0.0 };

    let first_crash = per_round.iter().find(|a| a.crashes > 0).map(|a| a.round);
    let last_degraded = per_round
        .iter()
        .rev()
        .find(|a| a.live_workers < spec.n_workers)
        .map(|a| a.round);
    let rounds_to_recover = match (first_crash, last_degraded) {
        (Some(f), Some(l)) if l >= f => l + 1 - f,
        _ => 0,
    };

    let degraded_rounds = per_round
        .iter()
        .filter(|a| a.live_workers < spec.n_workers)
        .count();
    let degraded_round_frac = degraded_rounds as f64 / per_round.len() as f64;

    let mut normal = (0.0f64, 0u64);
    let mut degraded = (0.0f64, 0u64);
    for a in &per_round {
        if a.live_workers < spec.n_workers {
            degraded.0 += a.mean_completion;
            degraded.1 += 1;
        } else if a.crashes
            + a.respawns
            + a.relaunches
            + a.degradations
            + a.dropped
            + a.corrupted
            + a.flagged
            + a.quarantined
            == 0
        {
            normal.0 += a.mean_completion;
            normal.1 += 1;
        }
    }
    let mean_of = |(sum, n): (f64, u64)| if n > 0 { sum / n as f64 } else { 0.0 };

    let (t_crash, t_respawn, t_relaunch, t_degrade, t_drop, t_corrupt, t_flag, t_quar) =
        per_round.iter().fold((0, 0, 0, 0, 0, 0, 0, 0), |acc, a| {
            (
                acc.0 + a.crashes,
                acc.1 + a.respawns,
                acc.2 + a.relaunches,
                acc.3 + a.degradations,
                acc.4 + a.dropped,
                acc.5 + a.corrupted,
                acc.6 + a.flagged,
                acc.7 + a.quarantined,
            )
        });

    crate::obs::bump(crate::obs::Counter::FaultChaosRuns, 1);
    if crate::obs::enabled() {
        crate::obs::emit(
            "fault",
            "chaos_run",
            &[
                ("rounds", spec.rounds.into()),
                ("replicates", (runs.len() as u64).into()),
                ("crashes", t_crash.into()),
                ("respawns", t_respawn.into()),
                ("relaunches", t_relaunch.into()),
                ("degradations", t_degrade.into()),
                ("dropped", t_drop.into()),
                ("mttr_rounds", mttr_rounds.into()),
            ],
        );
    }
    Ok(ChaosReport {
        name: spec.name.clone(),
        seed: spec.seed,
        n_workers: spec.n_workers,
        n_batches: spec.n_batches,
        service: spec.service.name(),
        plan: spec.plan.clone(),
        rounds: spec.rounds,
        replicates: runs.len() as u64,
        total_crashes: t_crash,
        total_respawns: t_respawn,
        total_relaunches: t_relaunch,
        total_degradations: t_degrade,
        total_dropped: t_drop,
        total_corrupted: t_corrupt,
        total_flagged: t_flag,
        total_quarantined: t_quar,
        mttr_rounds,
        rounds_to_recover,
        degraded_round_frac,
        mean_completion_normal: mean_of(normal),
        mean_completion_degraded: mean_of(degraded),
        per_round,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_load() {
        for name in ChaosSpec::preset_names() {
            let spec = ChaosSpec::preset(name).expect("preset exists");
            spec.validate().expect("preset is valid");
            assert_eq!(&ChaosSpec::load(name).expect("loads").name, name);
        }
        assert!(ChaosSpec::load("no-such-preset-or-file").is_err());
    }

    #[test]
    fn spec_round_trips_through_json() {
        let mut spec = ChaosSpec::fig2();
        spec.verify_m = 2;
        let j = spec.to_json();
        let back = ChaosSpec::from_json(&j).expect("parse");
        assert_eq!(back, spec);
    }

    /// A corruption plan under `verify_m = 2` populates the integrity
    /// columns: the corrupt worker's results are counted, flagged by
    /// the vote, and the worker is quarantined — identically in every
    /// replicate (the flag schedule is plan-deterministic).
    #[test]
    fn corruption_columns_flow_through_the_report() {
        let spec = ChaosSpec {
            name: "corrupt-smoke".into(),
            n_workers: 12,
            n_batches: 4,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            plan: FaultPlan {
                name: "corrupt-smoke".into(),
                seed: 7,
                events: vec![(0, FaultEvent::Corruption { from_round: 1, prob: 1.0 })],
            },
            rounds: 8,
            replicates: 4,
            seed: 11,
            verify_m: 2,
        };
        let report = run_chaos(&spec, 2).expect("run");
        assert!(report.total_corrupted >= 2, "corrupt results were injected");
        assert!(report.total_flagged >= 2, "votes flagged the corrupt replicas");
        assert!(report.total_quarantined >= 1, "strike budget quarantined the worker");
        // Quarantine empties a slot, so some rounds run short-handed.
        assert!(report.degraded_round_frac > 0.0);
        crate::fault::report::validate_json(&report.to_json()).expect("schema-valid");
        // The integrity schedule is deterministic across thread counts.
        let other = run_chaos(&spec, 1).expect("run");
        assert_eq!(report.to_json().to_string(), other.to_json().to_string());
    }

    /// Without verification the same plan corrupts silently: results
    /// are counted as corrupted but nothing is flagged or quarantined.
    #[test]
    fn corruption_without_verification_is_silent() {
        let spec = ChaosSpec {
            name: "corrupt-blind".into(),
            n_workers: 8,
            n_batches: 4,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            plan: FaultPlan {
                name: "corrupt-blind".into(),
                seed: 7,
                events: vec![(0, FaultEvent::Corruption { from_round: 0, prob: 1.0 })],
            },
            rounds: 6,
            replicates: 4,
            seed: 11,
            verify_m: 0,
        };
        let report = run_chaos(&spec, 1).expect("run");
        assert!(report.total_corrupted >= spec.rounds, "corruption injected every round");
        assert_eq!(report.total_flagged, 0);
        assert_eq!(report.total_quarantined, 0);
        crate::fault::report::validate_json(&report.to_json()).expect("schema-valid");
    }

    #[test]
    fn smoke_run_recovers_and_counts_faults() {
        let spec = ChaosSpec::smoke().fast();
        let report = run_chaos(&spec, 1).expect("run");
        assert_eq!(report.per_round.len(), spec.rounds as usize);
        // The transient crash fires and the worker comes back.
        assert_eq!(report.total_crashes, 1);
        assert_eq!(report.total_respawns, 1);
        assert!((report.mttr_rounds - 2.0).abs() < 1e-12);
        assert_eq!(report.rounds_to_recover, 2);
        assert!(report.degraded_round_frac > 0.0 && report.degraded_round_frac < 1.0);
        // Degraded rounds still complete (replication covers the loss).
        assert!(report.mean_completion_degraded > 0.0);
        assert!(report.mean_completion_normal > 0.0);
        crate::fault::report::validate_json(&report.to_json()).expect("schema-valid");
    }

    #[test]
    fn report_is_bit_identical_across_thread_counts() {
        let spec = ChaosSpec::smoke().fast();
        let base = run_chaos(&spec, 1).expect("run").to_json().to_string();
        for threads in [2, 4] {
            let other = run_chaos(&spec, threads).expect("run").to_json().to_string();
            assert_eq!(base, other, "threads={threads} diverged");
        }
    }

    #[test]
    fn fig2_scale_transient_crash_completes_every_round() {
        let mut spec = ChaosSpec::fig2().fast();
        spec.replicates = 2;
        let report = run_chaos(&spec, 2).expect("run");
        assert_eq!(report.per_round.len(), spec.rounds as usize);
        for agg in &report.per_round {
            assert!(
                agg.mean_completion.is_finite() && agg.mean_completion > 0.0,
                "round {} did not complete",
                agg.round
            );
        }
        assert_eq!(report.total_crashes, 2);
        assert_eq!(report.total_respawns, 2);
    }
}
