//! The `CHAOS_*.json` artifact: a versioned, schema-validated record of
//! one chaos run — per-round completion aggregates across replicates,
//! the deterministic fault/recovery counters, and the derived recovery
//! metrics (MTTR, rounds-to-recover, throughput under degradation).
//!
//! Follows the crate's artifact idiom (`study::report`,
//! `control::report`): an explicit `version` field, a [`validate_json`]
//! that checks structure *and* internal consistency (totals vs per-round
//! columns, finite stats), and a [`validate_file`] the CLI runs on the
//! artifact it just wrote. The artifact carries no thread count or wall
//! time: a fixed `(spec, seed)` pair is bit-identical for any
//! `--threads`.

use super::FaultPlan;
use crate::util::json::Json;
use std::path::Path;

/// Artifact schema version. v2 added the result-integrity columns
/// (`corrupted`, `flagged`, `quarantined`) to every `per_round` entry
/// and to `totals`; v1 artifacts are rejected (regenerate them — the
/// run is deterministic for a fixed `(spec, seed)`). See PERF.md for
/// the migration note.
pub const SCHEMA_VERSION: i64 = 2;

/// Per-round aggregate across replicates. The fault/recovery counters
/// and the liveness column are schedule-driven (identical in every
/// replicate — [`super::chaos::run_chaos`] verifies it); only the
/// completion statistics average over replicates.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundAgg {
    /// Round index (the fault plan's clock).
    pub round: u64,
    /// Mean injected completion across replicates (normalized units).
    pub mean_completion: f64,
    /// Standard error of the completion mean.
    pub sem_completion: f64,
    /// Workers alive at the end of the round.
    pub live_workers: usize,
    /// Workers that died this round.
    pub crashes: u64,
    /// Dead workers respawned at the start of this round.
    pub respawns: u64,
    /// Batches recovered by a deadline relaunch this round.
    pub relaunches: u64,
    /// Degraded-mode re-plans performed this round.
    pub degradations: u64,
    /// Tasks dropped before dispatch this round.
    pub dropped: u64,
    /// Results returned corrupted this round (the plan's corruption
    /// coin fired on a completed task).
    pub corrupted: u64,
    /// Corrupt replicas flagged by m-of-g voting this round.
    pub flagged: u64,
    /// Workers quarantined at the end of this round.
    pub quarantined: u64,
}

impl RoundAgg {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", (self.round as i64).into()),
            ("mean_completion", self.mean_completion.into()),
            ("sem_completion", self.sem_completion.into()),
            ("live_workers", self.live_workers.into()),
            ("crashes", (self.crashes as i64).into()),
            ("respawns", (self.respawns as i64).into()),
            ("relaunches", (self.relaunches as i64).into()),
            ("degradations", (self.degradations as i64).into()),
            ("dropped", (self.dropped as i64).into()),
            ("corrupted", (self.corrupted as i64).into()),
            ("flagged", (self.flagged as i64).into()),
            ("quarantined", (self.quarantined as i64).into()),
        ])
    }
}

/// Result of one chaos run (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosReport {
    /// Spec name (preset or file stem).
    pub name: String,
    /// Root seed of the shard plan (and of the fault plan's schedule).
    pub seed: u64,
    /// Cluster size `N`.
    pub n_workers: usize,
    /// Initial batch count `B`.
    pub n_batches: usize,
    /// Service spec string (e.g. `sexp:1,0.2`).
    pub service: String,
    /// The fault plan, embedded verbatim for replay.
    pub plan: FaultPlan,
    /// Rounds simulated per replicate.
    pub rounds: u64,
    /// Replicates run.
    pub replicates: u64,
    /// Sum of per-round `crashes`.
    pub total_crashes: u64,
    /// Sum of per-round `respawns`.
    pub total_respawns: u64,
    /// Sum of per-round `relaunches`.
    pub total_relaunches: u64,
    /// Sum of per-round `degradations`.
    pub total_degradations: u64,
    /// Sum of per-round `dropped`.
    pub total_dropped: u64,
    /// Sum of per-round `corrupted`.
    pub total_corrupted: u64,
    /// Sum of per-round `flagged`.
    pub total_flagged: u64,
    /// Sum of per-round `quarantined`.
    pub total_quarantined: u64,
    /// Mean rounds from a crash to the matching respawn (FIFO-matched;
    /// 0 when nothing respawned).
    pub mttr_rounds: f64,
    /// Rounds from the first crash until full liveness was last
    /// restored (0 when nothing crashed; equals the remaining rounds
    /// when the run ends still degraded).
    pub rounds_to_recover: u64,
    /// Fraction of rounds that ended with fewer than `N` live workers.
    pub degraded_round_frac: f64,
    /// Mean round completion over fault-free full-liveness rounds
    /// (0 when there are none).
    pub mean_completion_normal: f64,
    /// Mean round completion over rounds that ended short-handed —
    /// throughput under degradation (0 when there are none).
    pub mean_completion_degraded: f64,
    /// Per-round aggregates, one per round in order.
    pub per_round: Vec<RoundAgg>,
}

impl ChaosReport {
    /// Serialize to the versioned artifact schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", SCHEMA_VERSION.into()),
            ("name", self.name.as_str().into()),
            ("seed", (self.seed as i64).into()),
            ("n_workers", self.n_workers.into()),
            ("n_batches", self.n_batches.into()),
            ("service", self.service.as_str().into()),
            ("plan", self.plan.to_json()),
            ("rounds", (self.rounds as i64).into()),
            ("replicates", (self.replicates as i64).into()),
            (
                "totals",
                Json::obj(vec![
                    ("crashes", (self.total_crashes as i64).into()),
                    ("respawns", (self.total_respawns as i64).into()),
                    ("relaunches", (self.total_relaunches as i64).into()),
                    ("degradations", (self.total_degradations as i64).into()),
                    ("dropped", (self.total_dropped as i64).into()),
                    ("corrupted", (self.total_corrupted as i64).into()),
                    ("flagged", (self.total_flagged as i64).into()),
                    ("quarantined", (self.total_quarantined as i64).into()),
                ]),
            ),
            ("mttr_rounds", self.mttr_rounds.into()),
            ("rounds_to_recover", (self.rounds_to_recover as i64).into()),
            ("degraded_round_frac", self.degraded_round_frac.into()),
            ("mean_completion_normal", self.mean_completion_normal.into()),
            ("mean_completion_degraded", self.mean_completion_degraded.into()),
            ("per_round", Json::Array(self.per_round.iter().map(RoundAgg::to_json).collect())),
        ])
    }

    /// Write the artifact (newline-terminated canonical JSON).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Validate a chaos artifact: schema version, required keys, a parseable
/// embedded fault plan, finite per-round stats, and totals consistent
/// with the per-round columns.
pub fn validate_json(j: &Json) -> anyhow::Result<()> {
    anyhow::ensure!(
        j.get("version").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "missing or unexpected chaos schema version"
    );
    for key in ["name", "seed", "service"] {
        anyhow::ensure!(j.get(key).is_some(), "missing key '{key}'");
    }
    let n_workers = j
        .get("n_workers")
        .and_then(Json::as_i64)
        .ok_or_else(|| anyhow::anyhow!("missing 'n_workers'"))?;
    anyhow::ensure!(n_workers >= 1, "n_workers must be >= 1");
    let n_batches = j
        .get("n_batches")
        .and_then(Json::as_i64)
        .ok_or_else(|| anyhow::anyhow!("missing 'n_batches'"))?;
    anyhow::ensure!(
        n_batches >= 1 && n_batches <= n_workers,
        "n_batches must be in [1, n_workers]"
    );
    let plan_j = j.get("plan").ok_or_else(|| anyhow::anyhow!("missing 'plan'"))?;
    FaultPlan::from_json(plan_j).map_err(|e| anyhow::anyhow!("embedded plan: {e}"))?;
    let rounds = j
        .get("rounds")
        .and_then(Json::as_i64)
        .filter(|r| *r >= 1)
        .ok_or_else(|| anyhow::anyhow!("missing or non-positive 'rounds'"))?;
    anyhow::ensure!(
        j.get("replicates").and_then(Json::as_i64).is_some_and(|r| r >= 1),
        "missing or non-positive 'replicates'"
    );
    let per_round = j
        .get("per_round")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'per_round'"))?;
    anyhow::ensure!(
        per_round.len() as i64 == rounds,
        "per_round has {} entries for {rounds} rounds",
        per_round.len()
    );
    let counters = [
        "crashes",
        "respawns",
        "relaunches",
        "degradations",
        "dropped",
        "corrupted",
        "flagged",
        "quarantined",
    ];
    let mut sums = [0i64; 8];
    for (i, r) in per_round.iter().enumerate() {
        anyhow::ensure!(
            r.get("round").and_then(Json::as_i64) == Some(i as i64),
            "per_round entry {i} out of order"
        );
        for stat in ["mean_completion", "sem_completion"] {
            let v = r
                .get(stat)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("round {i} missing '{stat}'"))?;
            anyhow::ensure!(v.is_finite() && v >= 0.0, "round {i} has bad '{stat}' = {v}");
        }
        let live = r
            .get("live_workers")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("round {i} missing 'live_workers'"))?;
        anyhow::ensure!(
            (0..=n_workers).contains(&live),
            "round {i} live_workers {live} outside [0, {n_workers}]"
        );
        for (k, &counter) in counters.iter().enumerate() {
            let c = r
                .get(counter)
                .and_then(Json::as_i64)
                .filter(|c| *c >= 0)
                .ok_or_else(|| anyhow::anyhow!("round {i} missing counter '{counter}'"))?;
            sums[k] += c;
        }
    }
    let totals = j
        .get("totals")
        .ok_or_else(|| anyhow::anyhow!("missing 'totals'"))?;
    for (k, &counter) in counters.iter().enumerate() {
        let t = totals
            .get(counter)
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("totals missing '{counter}'"))?;
        anyhow::ensure!(
            t == sums[k],
            "totals.{counter} = {t} but per-round column sums to {}",
            sums[k]
        );
    }
    let frac = j
        .get("degraded_round_frac")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("missing 'degraded_round_frac'"))?;
    anyhow::ensure!((0.0..=1.0).contains(&frac), "degraded_round_frac out of [0, 1]");
    let mttr = j
        .get("mttr_rounds")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("missing 'mttr_rounds'"))?;
    anyhow::ensure!(mttr.is_finite() && mttr >= 0.0, "bad mttr_rounds = {mttr}");
    let recover = j
        .get("rounds_to_recover")
        .and_then(Json::as_i64)
        .ok_or_else(|| anyhow::anyhow!("missing 'rounds_to_recover'"))?;
    anyhow::ensure!(
        (0..=rounds).contains(&recover),
        "rounds_to_recover {recover} outside [0, rounds]"
    );
    for stat in ["mean_completion_normal", "mean_completion_degraded"] {
        let v = j
            .get(stat)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing '{stat}'"))?;
        anyhow::ensure!(v.is_finite() && v >= 0.0, "bad '{stat}' = {v}");
    }
    Ok(())
}

/// Read, parse, and validate an artifact file; returns the parsed JSON.
pub fn validate_file(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    validate_json(&j).map_err(|e| anyhow::anyhow!("validating {}: {e}", path.display()))?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::chaos::{run_chaos, ChaosSpec};

    fn sample_report() -> ChaosReport {
        run_chaos(&ChaosSpec::smoke().fast(), 1).expect("run")
    }

    #[test]
    fn artifact_round_trips_and_validates() {
        let report = sample_report();
        let j = report.to_json();
        validate_json(&j).expect("valid");
        let reparsed = Json::parse(&j.to_string()).expect("parse");
        assert_eq!(reparsed, j);
        validate_json(&reparsed).expect("still valid");
    }

    #[test]
    fn write_then_validate_file() {
        let report = sample_report();
        let dir = std::env::temp_dir().join("batchrep-chaos-report-test");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("CHAOS_roundtrip.json");
        report.write(&path).expect("write");
        let j = validate_file(&path).expect("validate");
        assert_eq!(j.get("name").and_then(Json::as_str), Some("smoke"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_rejects_malformed_artifacts() {
        let good = sample_report().to_json();
        let mutate = |f: &dyn Fn(&mut std::collections::BTreeMap<String, Json>)| {
            let mut m = good.as_object().expect("obj").clone();
            f(&mut m);
            Json::Object(m)
        };
        // Wrong version.
        let bad = mutate(&|m| {
            m.insert("version".into(), Json::Num(99.0));
        });
        assert!(validate_json(&bad).is_err());
        // Missing per-round array.
        let bad = mutate(&|m| {
            m.remove("per_round");
        });
        assert!(validate_json(&bad).is_err());
        // Totals out of sync with the per-round columns.
        let bad = mutate(&|m| {
            let mut totals =
                m.get("totals").and_then(Json::as_object).expect("totals").clone();
            totals.insert("crashes".into(), Json::Num(999.0));
            m.insert("totals".into(), Json::Object(totals));
        });
        assert!(validate_json(&bad).is_err());
        // Degraded fraction outside [0, 1].
        let bad = mutate(&|m| {
            m.insert("degraded_round_frac".into(), Json::Num(1.5));
        });
        assert!(validate_json(&bad).is_err());
        // Unparseable embedded plan.
        let bad = mutate(&|m| {
            m.insert("plan".into(), Json::obj(vec![("events", Json::Num(1.0))]));
        });
        assert!(validate_json(&bad).is_err());
        // A v1-style per_round entry (no integrity columns) is rejected.
        let bad = mutate(&|m| {
            let mut rounds = m.get("per_round").and_then(Json::as_array).expect("rows").clone();
            let mut row = rounds[0].as_object().expect("row obj").clone();
            row.remove("corrupted");
            rounds[0] = Json::Object(row);
            m.insert("per_round".into(), Json::Array(rounds));
        });
        assert!(validate_json(&bad).is_err());
    }
}
