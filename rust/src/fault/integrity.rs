//! The integrity harness behind `batchrep integrity`: sweep the vote
//! size `m` and the corruption probability over a replicated round
//! loop and aggregate detection behaviour into an `INTEGRITY_*.json`
//! artifact.
//!
//! Each cell of the `(m, prob)` grid replays the same corruption plan
//! (worker 0 returns deterministically-perturbed results from
//! `from_round` on, coin-flipped per round with probability `prob`)
//! against the DES fault loop
//! ([`crate::des::engine::simulate_fault_rounds`]) under
//! [`Scenario::verify_m`] `= m`. All cells share one replicate shard
//! plan and root seed — common random numbers — so the latency
//! overhead of `m`-of-`g` voting is a paired comparison against the
//! `m = 1` baseline, and the artifact is bit-identical for a fixed
//! `(spec, seed)` at any `--threads`.
//!
//! Reported per cell: the deterministic corruption/flag/quarantine
//! totals, the detection rate (flagged replicas over corrupt results —
//! 1.0 on disjoint layouts with `m >= 2`), false-positive flags
//! (flags in excess of corrupt results — structurally zero, and the
//! `prob = 0` column measures it directly), rounds from corruption
//! onset to the first quarantine, and the completion-time overhead
//! relative to the `m = 1` cell at the same corruption probability.

use super::{FaultEvent, FaultPlan};
use crate::des::engine::{simulate_fault_rounds, EngineConfig, FaultRoundStats};
use crate::des::montecarlo::{execute_shard_plan, shard_plan};
use crate::des::Scenario;
use crate::dist::{BatchService, ServiceSpec};
use crate::util::json::Json;
use crate::util::stats::Welford;
use std::path::Path;

/// `INTEGRITY_*.json` artifact schema version.
pub const SCHEMA_VERSION: i64 = 1;

/// One integrity experiment: a balanced-disjoint cluster, a service
/// law, a single corrupt worker, and the `(m, prob)` grid to sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct IntegritySpec {
    /// Experiment name (artifact stem).
    pub name: String,
    /// Cluster size `N`.
    pub n_workers: usize,
    /// Batch count `B` (`B | N`, balanced disjoint replication).
    pub n_batches: usize,
    /// Per-unit service law.
    pub service: ServiceSpec,
    /// Round from which worker 0's corruption coin is armed.
    pub from_round: u64,
    /// Vote sizes to sweep. Must contain `1` — the verification-off
    /// baseline every overhead is measured against.
    pub ms: Vec<u64>,
    /// Corruption probabilities to sweep (worker 0's per-round coin).
    pub probs: Vec<f64>,
    /// Strike budget: flags before quarantine.
    pub strikes: u64,
    /// Rounds per replicate.
    pub rounds: u64,
    /// Monte-Carlo replicates per cell (service-time draws differ; the
    /// corruption/flag/quarantine schedule is identical in every
    /// replicate and every cell shares the same draws).
    pub replicates: u64,
    /// Root seed for the shard plan and the corruption coin.
    pub seed: u64,
}

impl IntegritySpec {
    /// Names accepted by [`IntegritySpec::preset`].
    pub fn preset_names() -> &'static [&'static str] {
        &["smoke", "fig2"]
    }

    /// Small preset: 16 workers, 4 batches (replication group 4), a
    /// certainly-corrupt worker versus a clean column.
    pub fn smoke() -> IntegritySpec {
        IntegritySpec {
            name: "smoke".into(),
            n_workers: 16,
            n_batches: 4,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            from_round: 1,
            ms: vec![1, 2, 3],
            probs: vec![0.0, 1.0],
            strikes: 2,
            rounds: 12,
            replicates: 8,
            seed: 42,
        }
    }

    /// Fig-2-scale preset: 24 workers, 6 batches (replication group
    /// 4), intermittent and certain corruption columns.
    pub fn fig2() -> IntegritySpec {
        IntegritySpec {
            name: "fig2".into(),
            n_workers: 24,
            n_batches: 6,
            service: ServiceSpec::shifted_exp(1.0, 0.2),
            from_round: 1,
            ms: vec![1, 2, 3],
            probs: vec![0.0, 0.5, 1.0],
            strikes: 2,
            rounds: 24,
            replicates: 16,
            seed: 42,
        }
    }

    /// Look up a built-in preset.
    pub fn preset(name: &str) -> Option<IntegritySpec> {
        match name {
            "smoke" => Some(Self::smoke()),
            "fig2" => Some(Self::fig2()),
            _ => None,
        }
    }

    /// Resolve a CLI argument: a preset name, else a path to a spec
    /// JSON file (see [`IntegritySpec::from_json`]).
    pub fn load(which: &str) -> anyhow::Result<IntegritySpec> {
        if let Some(spec) = Self::preset(which) {
            return Ok(spec);
        }
        let text = std::fs::read_to_string(which).map_err(|e| {
            anyhow::anyhow!(
                "'{which}' is not an integrity preset ({}) and not a readable file: {e}",
                Self::preset_names().join(", ")
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {which}: {e}"))?;
        let spec = Self::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON:
    ///
    /// ```json
    /// {"name": "my-integrity", "n_workers": 16, "n_batches": 4,
    ///  "service": "sexp:1,0.2", "from_round": 1, "ms": [1, 2],
    ///  "probs": [0.0, 1.0], "strikes": 2, "rounds": 12,
    ///  "replicates": 8, "seed": 42}
    /// ```
    ///
    /// Optional keys default to the `smoke` preset's values.
    pub fn from_json(j: &Json) -> anyhow::Result<IntegritySpec> {
        let base = Self::smoke();
        let service = match j.get("service").and_then(Json::as_str) {
            Some(s) => ServiceSpec::parse(s)?,
            None => base.service,
        };
        let get_u = |key: &str, default: u64| -> anyhow::Result<u64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_i64()
                    .filter(|x| *x >= 0)
                    .map(|x| x as u64)
                    .ok_or_else(|| anyhow::anyhow!("'{key}' must be a non-negative integer")),
            }
        };
        let ms = match j.get("ms") {
            None => base.ms,
            Some(v) => v
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("'ms' must be an array"))?
                .iter()
                .map(|x| {
                    x.as_i64()
                        .filter(|m| *m >= 1)
                        .map(|m| m as u64)
                        .ok_or_else(|| anyhow::anyhow!("'ms' entries must be integers >= 1"))
                })
                .collect::<anyhow::Result<Vec<u64>>>()?,
        };
        let probs = match j.get("probs") {
            None => base.probs,
            Some(v) => v
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("'probs' must be an array"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|p| (0.0..=1.0).contains(p))
                        .ok_or_else(|| anyhow::anyhow!("'probs' entries must be in [0, 1]"))
                })
                .collect::<anyhow::Result<Vec<f64>>>()?,
        };
        Ok(IntegritySpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or(&base.name)
                .to_string(),
            n_workers: get_u("n_workers", base.n_workers as u64)? as usize,
            n_batches: get_u("n_batches", base.n_batches as u64)? as usize,
            service,
            from_round: get_u("from_round", base.from_round)?,
            ms,
            probs,
            strikes: get_u("strikes", base.strikes)?,
            rounds: get_u("rounds", base.rounds)?,
            replicates: get_u("replicates", base.replicates)?,
            seed: get_u("seed", base.seed)?,
        })
    }

    /// Serialize (round-trips through [`IntegritySpec::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("n_workers", self.n_workers.into()),
            ("n_batches", self.n_batches.into()),
            ("service", self.service.name().as_str().into()),
            ("from_round", (self.from_round as i64).into()),
            ("ms", Json::Array(self.ms.iter().map(|m| (*m as i64).into()).collect())),
            ("probs", Json::Array(self.probs.iter().map(|p| (*p).into()).collect())),
            ("strikes", (self.strikes as i64).into()),
            ("rounds", (self.rounds as i64).into()),
            ("replicates", (self.replicates as i64).into()),
            ("seed", (self.seed as i64).into()),
        ])
    }

    /// Check internal consistency (cluster shape, grid, counts).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "n_workers must be >= 1");
        anyhow::ensure!(
            self.n_batches >= 1 && self.n_batches <= self.n_workers,
            "n_batches must be in [1, n_workers]"
        );
        anyhow::ensure!(
            self.n_workers % self.n_batches == 0,
            "integrity runs use balanced replication: n_batches must divide n_workers"
        );
        anyhow::ensure!(!self.ms.is_empty(), "ms must be non-empty");
        anyhow::ensure!(
            self.ms.contains(&1),
            "ms must contain 1: the verification-off baseline anchors the overhead column"
        );
        let degree = (self.n_workers / self.n_batches) as u64;
        // Quarantine empties one slot, so the degraded re-plan must
        // still seat m votes per batch: require m <= degree - 1.
        for &m in &self.ms {
            anyhow::ensure!(
                m < degree,
                "verify_m = {m} needs replication degree > m (got {degree}) so that \
                 quarantining the corrupt worker leaves every batch with m replicas"
            );
        }
        anyhow::ensure!(!self.probs.is_empty(), "probs must be non-empty");
        for &p in &self.probs {
            anyhow::ensure!((0.0..=1.0).contains(&p), "probs entries must be in [0, 1]");
        }
        anyhow::ensure!(self.strikes >= 1, "strikes must be >= 1");
        anyhow::ensure!(self.rounds >= 1, "rounds must be >= 1");
        anyhow::ensure!(
            self.from_round < self.rounds,
            "from_round must fall inside the simulated rounds"
        );
        anyhow::ensure!(self.replicates >= 1, "replicates must be >= 1");
        Ok(())
    }

    /// Shrink for `--fast` smoke runs (caps replicates and rounds).
    pub fn fast(mut self) -> IntegritySpec {
        self.replicates = self.replicates.min(4);
        self.rounds = self.rounds.min(8);
        self
    }
}

/// One `(m, prob)` grid cell of an [`IntegrityReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct IntegrityCell {
    /// Vote size (1 = verification off).
    pub m: u64,
    /// Worker 0's per-round corruption probability.
    pub prob: f64,
    /// Corrupt results injected across all rounds (replicate-invariant).
    pub corrupted: u64,
    /// Corrupt replicas flagged by voting.
    pub flagged: u64,
    /// Quarantines triggered (strike budget exhausted).
    pub quarantined: u64,
    /// Degraded-mode re-plans (quarantine coverage loss).
    pub degradations: u64,
    /// Flagged over corrupted; 1.0 (vacuously) when nothing was
    /// corrupted. On disjoint layouts with `m >= 2` this is 1.0.
    pub detection_rate: f64,
    /// Flags in excess of corrupt results — honest replicas flagged.
    /// Structurally zero; the `prob = 0` column measures it directly.
    pub false_positive_flags: u64,
    /// Rounds from corruption onset to the first quarantine (0 when
    /// nothing was quarantined).
    pub rounds_to_quarantine: u64,
    /// Mean round completion over all rounds and replicates
    /// (normalized units).
    pub mean_completion: f64,
    /// Standard error of the completion mean.
    pub sem_completion: f64,
    /// `mean_completion` relative to the `m = 1` cell at the same
    /// `prob`, minus one — the price of waiting for `m` votes. Exactly
    /// 0 on the baseline cells themselves.
    pub latency_overhead: f64,
}

impl IntegrityCell {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("m", (self.m as i64).into()),
            ("prob", self.prob.into()),
            ("corrupted", (self.corrupted as i64).into()),
            ("flagged", (self.flagged as i64).into()),
            ("quarantined", (self.quarantined as i64).into()),
            ("degradations", (self.degradations as i64).into()),
            ("detection_rate", self.detection_rate.into()),
            ("false_positive_flags", (self.false_positive_flags as i64).into()),
            ("rounds_to_quarantine", (self.rounds_to_quarantine as i64).into()),
            ("mean_completion", self.mean_completion.into()),
            ("sem_completion", self.sem_completion.into()),
            ("latency_overhead", self.latency_overhead.into()),
        ])
    }
}

/// Result of one integrity sweep (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct IntegrityReport {
    /// Spec name (preset or file stem).
    pub name: String,
    /// The spec, embedded verbatim for replay.
    pub spec: IntegritySpec,
    /// Grid cells in `ms`-major, `probs`-minor order.
    pub cells: Vec<IntegrityCell>,
}

impl IntegrityReport {
    /// Serialize to the versioned artifact schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", SCHEMA_VERSION.into()),
            ("name", self.name.as_str().into()),
            ("spec", self.spec.to_json()),
            ("cells", Json::Array(self.cells.iter().map(IntegrityCell::to_json).collect())),
        ])
    }

    /// Write the artifact (newline-terminated canonical JSON).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Run the integrity sweep: every `(m, prob)` cell replays the same
/// corruption plan over the same replicate shard plan (common random
/// numbers), aggregating detection and latency metrics. Like the chaos
/// harness, the corruption/flag/quarantine schedule must agree across
/// replicates — divergence is an internal-determinism error.
pub fn run_integrity(spec: &IntegritySpec, threads: usize) -> anyhow::Result<IntegrityReport> {
    spec.validate()?;
    let cfg = EngineConfig { verify_strikes: spec.strikes, ..EngineConfig::default() };
    let mut cells = Vec::with_capacity(spec.ms.len() * spec.probs.len());
    // Baseline means, one per prob, filled by the m = 1 pass.
    let mut baselines = vec![f64::NAN; spec.probs.len()];
    let mut ms = spec.ms.clone();
    ms.sort_unstable();
    ms.dedup();
    for &m in &ms {
        for (pi, &prob) in spec.probs.iter().enumerate() {
            let mut scn = Scenario::paper_balanced(
                spec.n_workers,
                spec.n_batches,
                BatchService::paper(spec.service.clone()),
            )?
            .with_seed(spec.seed);
            if m >= 2 {
                scn = scn.with_verify_m(m as usize)?;
            }
            let events = if prob > 0.0 {
                vec![(0usize, FaultEvent::Corruption { from_round: spec.from_round, prob })]
            } else {
                Vec::new()
            };
            let plan =
                FaultPlan { name: spec.name.clone(), seed: spec.seed, events }
                    .compile(spec.n_workers)?;
            let shards = shard_plan(spec.replicates, spec.seed);
            let per_shard: Vec<anyhow::Result<Vec<Vec<FaultRoundStats>>>> = execute_shard_plan(
                shards,
                threads,
                || (),
                |_, count, mut rng| {
                    (0..count)
                        .map(|_| simulate_fault_rounds(&scn, &plan, spec.rounds, &cfg, &mut rng))
                        .collect()
                },
            );
            let mut runs: Vec<Vec<FaultRoundStats>> = Vec::with_capacity(spec.replicates as usize);
            for shard in per_shard {
                runs.extend(shard?);
            }
            anyhow::ensure!(!runs.is_empty(), "integrity cell produced no replicates");

            let schedule = &runs[0];
            let mut comp = Welford::new();
            for run in &runs {
                for (r, st) in run.iter().enumerate() {
                    anyhow::ensure!(
                        (st.corrupted, st.flagged, st.quarantined, st.live_workers)
                            == (
                                schedule[r].corrupted,
                                schedule[r].flagged,
                                schedule[r].quarantined,
                                schedule[r].live_workers
                            ),
                        "integrity schedule diverged across replicates at round {r} \
                         (m = {m}, prob = {prob})"
                    );
                    comp.push(st.completion);
                }
            }
            let (mut corrupted, mut flagged, mut quarantined, mut degradations) = (0, 0, 0, 0);
            for st in schedule {
                corrupted += st.corrupted;
                flagged += st.flagged;
                quarantined += st.quarantined;
                degradations += st.degradations;
            }
            let detection_rate =
                if corrupted > 0 { flagged as f64 / corrupted as f64 } else { 1.0 };
            let rounds_to_quarantine = schedule
                .iter()
                .position(|st| st.quarantined > 0)
                .map(|r| (r as u64 + 1).saturating_sub(spec.from_round))
                .unwrap_or(0);
            let mean_completion = comp.mean();
            if m == 1 {
                baselines[pi] = mean_completion;
            }
            let base = baselines[pi];
            anyhow::ensure!(
                base.is_finite() && base > 0.0,
                "m = 1 baseline missing for prob = {prob}"
            );
            cells.push(IntegrityCell {
                m,
                prob,
                corrupted,
                flagged,
                quarantined,
                degradations,
                detection_rate,
                false_positive_flags: flagged.saturating_sub(corrupted),
                rounds_to_quarantine,
                mean_completion,
                sem_completion: comp.sem(),
                latency_overhead: mean_completion / base - 1.0,
            });
        }
    }
    crate::obs::bump(crate::obs::Counter::FaultIntegrityRuns, 1);
    if crate::obs::enabled() {
        crate::obs::emit(
            "fault",
            "integrity_run",
            &[
                ("cells", cells.len().into()),
                ("rounds", spec.rounds.into()),
                ("replicates", spec.replicates.into()),
            ],
        );
    }
    Ok(IntegrityReport { name: spec.name.clone(), spec: spec.clone(), cells })
}

/// Validate an integrity artifact: schema version, a re-parseable
/// embedded spec, a full grid, and per-cell internal consistency
/// (rates recomputable from the counters, exact-zero baseline
/// overhead, clean `prob = 0` columns).
pub fn validate_json(j: &Json) -> anyhow::Result<()> {
    anyhow::ensure!(
        j.get("version").and_then(Json::as_i64) == Some(SCHEMA_VERSION),
        "missing or unexpected integrity schema version"
    );
    anyhow::ensure!(j.get("name").is_some(), "missing key 'name'");
    let spec_j = j.get("spec").ok_or_else(|| anyhow::anyhow!("missing 'spec'"))?;
    let spec = IntegritySpec::from_json(spec_j).map_err(|e| anyhow::anyhow!("embedded spec: {e}"))?;
    spec.validate().map_err(|e| anyhow::anyhow!("embedded spec: {e}"))?;
    let cells = j
        .get("cells")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array 'cells'"))?;
    let mut ms = spec.ms.clone();
    ms.sort_unstable();
    ms.dedup();
    anyhow::ensure!(
        cells.len() == ms.len() * spec.probs.len(),
        "cells has {} entries for a {}x{} grid",
        cells.len(),
        ms.len(),
        spec.probs.len()
    );
    for (i, c) in cells.iter().enumerate() {
        let m = c
            .get("m")
            .and_then(Json::as_i64)
            .filter(|m| *m >= 1)
            .ok_or_else(|| anyhow::anyhow!("cell {i} missing 'm'"))?;
        let prob = c
            .get("prob")
            .and_then(Json::as_f64)
            .filter(|p| (0.0..=1.0).contains(p))
            .ok_or_else(|| anyhow::anyhow!("cell {i} missing or out-of-range 'prob'"))?;
        let count = |key: &str| -> anyhow::Result<i64> {
            c.get(key)
                .and_then(Json::as_i64)
                .filter(|v| *v >= 0)
                .ok_or_else(|| anyhow::anyhow!("cell {i} missing counter '{key}'"))
        };
        let corrupted = count("corrupted")?;
        let flagged = count("flagged")?;
        let quarantined = count("quarantined")?;
        count("degradations")?;
        let fp = count("false_positive_flags")?;
        let to_quarantine = count("rounds_to_quarantine")?;
        let rate = c
            .get("detection_rate")
            .and_then(Json::as_f64)
            .filter(|r| (0.0..=1.0).contains(r))
            .ok_or_else(|| anyhow::anyhow!("cell {i} missing or out-of-range 'detection_rate'"))?;
        let expect_rate = if corrupted > 0 { flagged as f64 / corrupted as f64 } else { 1.0 };
        anyhow::ensure!(
            (rate - expect_rate).abs() < 1e-12,
            "cell {i} detection_rate {rate} disagrees with flagged/corrupted = {expect_rate}"
        );
        anyhow::ensure!(
            fp == (flagged - corrupted).max(0),
            "cell {i} false_positive_flags inconsistent with counters"
        );
        if prob == 0.0 {
            anyhow::ensure!(corrupted == 0, "cell {i} corrupted > 0 with prob = 0");
        }
        if m == 1 {
            anyhow::ensure!(
                flagged == 0 && quarantined == 0,
                "cell {i} flags or quarantines with verification off"
            );
        }
        anyhow::ensure!(
            to_quarantine as u64 <= spec.rounds,
            "cell {i} rounds_to_quarantine outside the run"
        );
        for stat in ["mean_completion", "sem_completion"] {
            let v = c
                .get(stat)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("cell {i} missing '{stat}'"))?;
            anyhow::ensure!(v.is_finite() && v >= 0.0, "cell {i} has bad '{stat}' = {v}");
        }
        let overhead = c
            .get("latency_overhead")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("cell {i} missing 'latency_overhead'"))?;
        anyhow::ensure!(
            overhead.is_finite() && overhead >= -1.0,
            "cell {i} has bad 'latency_overhead' = {overhead}"
        );
        if m == 1 {
            anyhow::ensure!(
                overhead == 0.0,
                "cell {i} is an m = 1 baseline but has nonzero latency_overhead"
            );
        }
    }
    Ok(())
}

/// Read, parse, and validate an artifact file; returns the parsed JSON.
pub fn validate_file(path: &Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    validate_json(&j).map_err(|e| anyhow::anyhow!("validating {}: {e}", path.display()))?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_load() {
        for name in IntegritySpec::preset_names() {
            let spec = IntegritySpec::preset(name).expect("preset exists");
            spec.validate().expect("preset is valid");
            assert_eq!(&IntegritySpec::load(name).expect("loads").name, name);
        }
        assert!(IntegritySpec::load("no-such-preset-or-file").is_err());
    }

    #[test]
    fn spec_round_trips_through_json() {
        let spec = IntegritySpec::fig2();
        let j = spec.to_json();
        let back = IntegritySpec::from_json(&j).expect("parse");
        assert_eq!(back, spec);
    }

    #[test]
    fn spec_rejects_degenerate_grids() {
        let mut spec = IntegritySpec::smoke();
        spec.ms = vec![2, 3];
        assert!(spec.validate().is_err(), "missing the m = 1 baseline");
        let mut spec = IntegritySpec::smoke();
        spec.ms = vec![1, 4];
        assert!(spec.validate().is_err(), "m = degree leaves no quarantine headroom");
        let mut spec = IntegritySpec::smoke();
        spec.from_round = spec.rounds;
        assert!(spec.validate().is_err(), "corruption onset outside the run");
    }

    #[test]
    fn smoke_sweep_detects_all_corruption_with_zero_false_positives() {
        let report = run_integrity(&IntegritySpec::smoke().fast(), 2).expect("run");
        assert_eq!(report.cells.len(), 6);
        for c in &report.cells {
            assert_eq!(c.false_positive_flags, 0, "m={} prob={}", c.m, c.prob);
            if c.prob == 0.0 {
                assert_eq!(c.corrupted, 0, "clean column stays clean (m={})", c.m);
                assert_eq!(c.flagged, 0);
                assert_eq!(c.quarantined, 0);
            } else if c.m == 1 {
                assert!(c.corrupted > 0, "corruption was injected");
                assert_eq!(c.flagged, 0, "verification off: corruption is invisible");
                assert_eq!(c.quarantined, 0);
                assert_eq!(c.detection_rate, 0.0);
            } else {
                assert!(c.corrupted > 0, "corruption was injected (m={})", c.m);
                assert_eq!(c.detection_rate, 1.0, "m={} detects every corrupt result", c.m);
                assert!(c.quarantined > 0, "m={} quarantined the corrupt worker", c.m);
                assert!(c.rounds_to_quarantine > 0);
            }
            if c.m == 1 {
                assert_eq!(c.latency_overhead, 0.0);
            } else {
                assert!(
                    c.latency_overhead > 0.0,
                    "waiting for {} votes costs latency (prob={})",
                    c.m,
                    c.prob
                );
            }
        }
        validate_json(&report.to_json()).expect("schema-valid");
    }

    #[test]
    fn report_is_bit_identical_across_thread_counts() {
        let spec = IntegritySpec::smoke().fast();
        let base = run_integrity(&spec, 1).expect("run").to_json().to_string();
        for threads in [2, 4, 8] {
            let other = run_integrity(&spec, threads).expect("run").to_json().to_string();
            assert_eq!(base, other, "threads={threads} diverged");
        }
    }

    #[test]
    fn write_then_validate_file() {
        let report = run_integrity(&IntegritySpec::smoke().fast(), 1).expect("run");
        let dir = std::env::temp_dir().join("batchrep-integrity-report-test");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("INTEGRITY_roundtrip.json");
        report.write(&path).expect("write");
        let j = validate_file(&path).expect("validate");
        assert_eq!(j.get("name").and_then(Json::as_str), Some("smoke"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_rejects_malformed_artifacts() {
        let good = run_integrity(&IntegritySpec::smoke().fast(), 1).expect("run").to_json();
        let mutate = |f: &dyn Fn(&mut std::collections::BTreeMap<String, Json>)| {
            let mut m = good.as_object().expect("obj").clone();
            f(&mut m);
            Json::Object(m)
        };
        // Wrong version.
        let bad = mutate(&|m| {
            m.insert("version".into(), Json::Num(99.0));
        });
        assert!(validate_json(&bad).is_err());
        // Grid size mismatch.
        let bad = mutate(&|m| {
            let mut cells = m.get("cells").and_then(Json::as_array).expect("cells").clone();
            cells.pop();
            m.insert("cells".into(), Json::Array(cells));
        });
        assert!(validate_json(&bad).is_err());
        // Detection rate out of sync with the counters.
        let bad = mutate(&|m| {
            let mut cells = m.get("cells").and_then(Json::as_array).expect("cells").clone();
            let mut cell = cells[0].as_object().expect("cell").clone();
            cell.insert("detection_rate".into(), Json::Num(0.5));
            cells[0] = Json::Object(cell);
            m.insert("cells".into(), Json::Array(cells));
        });
        assert!(validate_json(&bad).is_err());
        // Unparseable embedded spec.
        let bad = mutate(&|m| {
            m.insert("spec".into(), Json::obj(vec![("ms", Json::Num(1.0))]));
        });
        assert!(validate_json(&bad).is_err());
    }
}
