//! Declarative fault injection shared by the live runtime and the DES
//! engine.
//!
//! A [`FaultPlan`] is a seed-deterministic schedule of per-worker fault
//! events — permanent crashes, transient crashes with respawn, straggler
//! slowdown intervals driven by the [`crate::trace`] Markov law, and
//! per-round task drops. The same plan compiles (via
//! [`FaultPlan::compile`]) into a [`CompiledPlan`] consumed by **both**
//! backends: the live [`crate::coordinator::Coordinator`] injects the
//! faults into real worker threads (and self-heals: deadline relaunch,
//! respawn, degraded re-planning), while
//! [`crate::des::engine::simulate_fault_rounds`] replays the identical
//! schedule in simulated time. Live↔DES fault conformance cells
//! ([`crate::conformance`]) hold the two accountable to each other, and
//! `batchrep chaos` ([`chaos`]) measures recovery (MTTR,
//! rounds-to-recover, throughput under degradation) into the versioned
//! `CHAOS_*.json` artifact ([`report`]).
//!
//! Determinism contract: every stochastic choice a plan makes (slowdown
//! trace, task-drop coins) is a pure function of `(plan seed, worker,
//! round)` — no coordinator or engine RNG state is consumed — so the
//! injected fault schedule is bit-identical across backends, thread
//! counts, and replays.

pub mod chaos;
pub mod integrity;
pub mod report;

pub use chaos::{run_chaos, ChaosSpec};
pub use integrity::{run_integrity, IntegrityCell, IntegrityReport, IntegritySpec};
pub use report::{validate_file, validate_json, ChaosReport, RoundAgg, SCHEMA_VERSION};

use crate::assignment::Assignment;
use crate::trace::{generate_markov_trace, MarkovTraceParams};
use crate::util::json::Json;
use crate::util::rng::{fnv1a, splitmix64};

/// Base respawn delay, in rounds, of a worker quarantined by the
/// result-integrity strike budget (m-of-g voting, PR 8). Doubled per
/// respawn attempt with the same `1 << min(attempts, 3)` backoff the
/// transient-crash path uses, and shared verbatim by the live
/// coordinator and the DES fault-round mirror so their quarantine
/// schedules agree.
pub const QUARANTINE_RESPAWN_ROUNDS: u64 = 2;

/// One scheduled fault on one worker.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// The worker dies at the start of round `round` (it is dispatched
    /// to, crashes `fraction` of the way through its task, and never
    /// comes back).
    PermanentCrash {
        /// Round index (0-based) the crash fires in.
        round: u64,
        /// Fraction of the sampled task delay the worker survives.
        fraction: f64,
    },
    /// Like [`FaultEvent::PermanentCrash`], but the coordinator respawns
    /// the worker `respawn_after` rounds later (with exponential backoff
    /// if it keeps dying).
    TransientCrash {
        /// Round index (0-based) the crash fires in.
        round: u64,
        /// Fraction of the sampled task delay the worker survives.
        fraction: f64,
        /// Rounds the worker stays down before its first respawn.
        respawn_after: u64,
    },
    /// The worker's service times are multiplied by a Markov-modulated
    /// straggle factor for `rounds` rounds starting at `from_round` —
    /// the [`crate::trace`] contention law, normalized so the factor has
    /// mean ≈ 1 outside congestion bursts.
    Slowdown {
        /// First affected round (0-based).
        from_round: u64,
        /// Number of affected rounds.
        rounds: u64,
        /// The Markov-modulated straggle law.
        params: MarkovTraceParams,
    },
    /// Every round, the worker independently drops its task (never
    /// starts it) with probability `prob`; the coordinator's deadline
    /// relaunch is the recovery path.
    TaskDrop {
        /// Per-round drop probability.
        prob: f64,
    },
    /// From round `from_round` on, the worker independently returns a
    /// **silently corrupted** result with probability `prob` each round:
    /// the task completes on time but its output is deterministically
    /// perturbed (worker-dependent, so two corrupt replicas never agree
    /// with each other). Detection is the `verify_m` replica-voting
    /// path; the quarantine machinery is the recovery path.
    Corruption {
        /// First affected round (0-based).
        from_round: u64,
        /// Per-round corruption probability.
        prob: f64,
    },
}

impl FaultEvent {
    /// Stable kind tag used in the JSON encoding.
    pub fn kind(&self) -> &'static str {
        match self {
            FaultEvent::PermanentCrash { .. } => "permanent_crash",
            FaultEvent::TransientCrash { .. } => "transient_crash",
            FaultEvent::Slowdown { .. } => "slowdown",
            FaultEvent::TaskDrop { .. } => "task_drop",
            FaultEvent::Corruption { .. } => "corruption",
        }
    }
}

/// A declarative, seed-deterministic schedule of worker faults.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Name (artifact stem / preset name).
    pub name: String,
    /// Seed of the plan's own randomness (slowdown traces, drop coins).
    pub seed: u64,
    /// `(worker, event)` pairs; a worker may carry several events but at
    /// most one crash.
    pub events: Vec<(usize, FaultEvent)>,
}

impl FaultPlan {
    /// Names accepted by [`FaultPlan::preset`].
    pub fn preset_names() -> &'static [&'static str] {
        &["crash", "respawn", "slowdown", "mixed", "corrupt"]
    }

    /// The built-in `respawn` preset: two staggered transient crashes.
    ///
    /// Exposed as an infallible constructor so callers that hard-code
    /// this preset (e.g. [`crate::fault::chaos::ChaosSpec::fig2`]) need
    /// not unwrap the string-keyed [`FaultPlan::preset`] lookup.
    pub fn respawn_preset() -> FaultPlan {
        FaultPlan {
            name: "respawn".into(),
            seed: 42,
            events: vec![
                (0, FaultEvent::TransientCrash { round: 2, fraction: 0.5, respawn_after: 2 }),
                (1, FaultEvent::TransientCrash { round: 6, fraction: 0.3, respawn_after: 3 }),
            ],
        }
    }

    /// Look up a built-in preset.
    pub fn preset(name: &str) -> Option<FaultPlan> {
        match name {
            "crash" => Some(FaultPlan {
                name: "crash".into(),
                seed: 42,
                events: vec![(0, FaultEvent::PermanentCrash { round: 3, fraction: 0.5 })],
            }),
            "respawn" => Some(Self::respawn_preset()),
            "slowdown" => Some(FaultPlan {
                name: "slowdown".into(),
                seed: 42,
                events: vec![(
                    0,
                    FaultEvent::Slowdown {
                        from_round: 2,
                        rounds: 24,
                        params: MarkovTraceParams {
                            // Always-congested burst: enter immediately,
                            // essentially never exit within the window.
                            p_enter: 1.0,
                            p_exit: 1e-9,
                            ..MarkovTraceParams::default()
                        },
                    },
                )],
            }),
            "mixed" => Some(FaultPlan {
                name: "mixed".into(),
                seed: 42,
                events: vec![
                    (0, FaultEvent::TransientCrash { round: 3, fraction: 0.5, respawn_after: 2 }),
                    (
                        1,
                        FaultEvent::Slowdown {
                            from_round: 1,
                            rounds: 16,
                            params: MarkovTraceParams::default(),
                        },
                    ),
                    (2, FaultEvent::TaskDrop { prob: 0.15 }),
                ],
            }),
            "corrupt" => Some(FaultPlan {
                name: "corrupt".into(),
                seed: 42,
                events: vec![
                    (0, FaultEvent::Corruption { from_round: 2, prob: 0.6 }),
                    (1, FaultEvent::Corruption { from_round: 4, prob: 0.3 }),
                ],
            }),
            _ => None,
        }
    }

    /// Resolve a CLI argument: a preset name, else a path to a plan JSON
    /// file (see [`FaultPlan::from_json`] for the format).
    pub fn load(which: &str) -> anyhow::Result<FaultPlan> {
        if let Some(plan) = FaultPlan::preset(which) {
            return Ok(plan);
        }
        let text = std::fs::read_to_string(which).map_err(|e| {
            anyhow::anyhow!(
                "'{which}' is not a fault-plan preset ({}) and not a readable file: {e}",
                FaultPlan::preset_names().join("|")
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {which}: {e}"))?;
        let mut plan = FaultPlan::from_json(&j)?;
        if plan.name.is_empty() {
            plan.name = std::path::Path::new(which)
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("custom")
                .to_string();
        }
        Ok(plan)
    }

    /// Parse a plan object:
    ///
    /// ```json
    /// {
    ///   "name": "custom",
    ///   "seed": 42,
    ///   "events": [
    ///     {"worker": 0, "kind": "transient_crash", "round": 2,
    ///      "fraction": 0.5, "respawn_after": 2},
    ///     {"worker": 1, "kind": "permanent_crash", "round": 5,
    ///      "fraction": 0.5},
    ///     {"worker": 2, "kind": "slowdown", "from_round": 1, "rounds": 16,
    ///      "p_enter": 0.1, "p_exit": 0.05, "slowdown": 8.0,
    ///      "base_mu": 1.0, "base_delta": 0.2},
    ///     {"worker": 3, "kind": "task_drop", "prob": 0.1},
    ///     {"worker": 4, "kind": "corruption", "from_round": 2, "prob": 0.5}
    ///   ]
    /// }
    /// ```
    ///
    /// `name` and `seed` are optional (default: file stem, 42); the
    /// slowdown's Markov parameters default to
    /// [`MarkovTraceParams::default`] when omitted.
    pub fn from_json(j: &Json) -> anyhow::Result<FaultPlan> {
        let events_j = j
            .get("events")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow::anyhow!("fault plan needs array 'events'"))?;
        let mut events = Vec::with_capacity(events_j.len());
        for (i, e) in events_j.iter().enumerate() {
            let int = |key: &str| -> anyhow::Result<u64> {
                e.get(key)
                    .and_then(Json::as_i64)
                    .filter(|v| *v >= 0)
                    .map(|v| v as u64)
                    .ok_or_else(|| {
                        anyhow::anyhow!("fault event {i} needs non-negative integer '{key}'")
                    })
            };
            let num = |key: &str| -> anyhow::Result<f64> {
                e.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("fault event {i} needs number '{key}'"))
            };
            let worker = int("worker")? as usize;
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("fault event {i} needs string 'kind'"))?;
            let event = match kind {
                "permanent_crash" => {
                    FaultEvent::PermanentCrash { round: int("round")?, fraction: num("fraction")? }
                }
                "transient_crash" => FaultEvent::TransientCrash {
                    round: int("round")?,
                    fraction: num("fraction")?,
                    respawn_after: int("respawn_after")?,
                },
                "slowdown" => {
                    let d = MarkovTraceParams::default();
                    let opt = |key: &str, dv: f64| {
                        e.get(key).and_then(Json::as_f64).unwrap_or(dv)
                    };
                    FaultEvent::Slowdown {
                        from_round: int("from_round")?,
                        rounds: int("rounds")?,
                        params: MarkovTraceParams {
                            p_enter: opt("p_enter", d.p_enter),
                            p_exit: opt("p_exit", d.p_exit),
                            slowdown: opt("slowdown", d.slowdown),
                            base_mu: opt("base_mu", d.base_mu),
                            base_delta: opt("base_delta", d.base_delta),
                        },
                    }
                }
                "task_drop" => FaultEvent::TaskDrop { prob: num("prob")? },
                "corruption" => FaultEvent::Corruption {
                    from_round: int("from_round")?,
                    prob: num("prob")?,
                },
                other => anyhow::bail!(
                    "fault event {i} has unknown kind '{other}' \
                     (permanent_crash|transient_crash|slowdown|task_drop|corruption)"
                ),
            };
            events.push((worker, event));
        }
        Ok(FaultPlan {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            seed: j.get("seed").and_then(Json::as_i64).map(|s| s as u64).unwrap_or(42),
            events,
        })
    }

    /// Serialize back to the [`FaultPlan::from_json`] format.
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|(w, e)| {
                let mut fields: Vec<(&str, Json)> =
                    vec![("worker", (*w).into()), ("kind", e.kind().into())];
                match e {
                    FaultEvent::PermanentCrash { round, fraction } => {
                        fields.push(("round", (*round as i64).into()));
                        fields.push(("fraction", (*fraction).into()));
                    }
                    FaultEvent::TransientCrash { round, fraction, respawn_after } => {
                        fields.push(("round", (*round as i64).into()));
                        fields.push(("fraction", (*fraction).into()));
                        fields.push(("respawn_after", (*respawn_after as i64).into()));
                    }
                    FaultEvent::Slowdown { from_round, rounds, params } => {
                        fields.push(("from_round", (*from_round as i64).into()));
                        fields.push(("rounds", (*rounds as i64).into()));
                        fields.push(("p_enter", params.p_enter.into()));
                        fields.push(("p_exit", params.p_exit.into()));
                        fields.push(("slowdown", params.slowdown.into()));
                        fields.push(("base_mu", params.base_mu.into()));
                        fields.push(("base_delta", params.base_delta.into()));
                    }
                    FaultEvent::TaskDrop { prob } => {
                        fields.push(("prob", (*prob).into()));
                    }
                    FaultEvent::Corruption { from_round, prob } => {
                        fields.push(("from_round", (*from_round as i64).into()));
                        fields.push(("prob", (*prob).into()));
                    }
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("seed", (self.seed as i64).into()),
            ("events", Json::Array(events)),
        ])
    }

    /// Structural validation against a cluster of `n_workers`.
    pub fn validate(&self, n_workers: usize) -> anyhow::Result<()> {
        let mut has_crash = vec![false; n_workers];
        let mut has_corruption = vec![false; n_workers];
        for (w, e) in &self.events {
            anyhow::ensure!(
                *w < n_workers,
                "fault plan '{}' targets worker {w} of a {n_workers}-worker cluster",
                self.name
            );
            match e {
                FaultEvent::PermanentCrash { fraction, .. }
                | FaultEvent::TransientCrash { fraction, .. } => {
                    anyhow::ensure!(
                        !has_crash[*w],
                        "fault plan '{}' schedules two crashes on worker {w}",
                        self.name
                    );
                    has_crash[*w] = true;
                    anyhow::ensure!(
                        *fraction > 0.0 && *fraction <= 1.0 && fraction.is_finite(),
                        "crash fraction must be in (0, 1], got {fraction}"
                    );
                    if let FaultEvent::TransientCrash { respawn_after, .. } = e {
                        anyhow::ensure!(
                            *respawn_after >= 1,
                            "transient crash needs respawn_after >= 1"
                        );
                    }
                }
                FaultEvent::Slowdown { rounds, params, .. } => {
                    anyhow::ensure!(*rounds >= 1, "slowdown needs rounds >= 1");
                    anyhow::ensure!(
                        params.slowdown >= 1.0 && params.slowdown.is_finite(),
                        "slowdown factor must be >= 1, got {}",
                        params.slowdown
                    );
                    anyhow::ensure!(
                        params.base_mu > 0.0 && params.base_delta >= 0.0,
                        "slowdown base law needs mu > 0 and delta >= 0"
                    );
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&params.p_enter)
                            && (0.0..=1.0).contains(&params.p_exit),
                        "slowdown Markov probabilities must be in [0, 1]"
                    );
                }
                FaultEvent::TaskDrop { prob } => {
                    anyhow::ensure!(
                        (0.0..1.0).contains(prob),
                        "task-drop probability must be in [0, 1), got {prob}"
                    );
                }
                FaultEvent::Corruption { prob, .. } => {
                    anyhow::ensure!(
                        !has_corruption[*w],
                        "fault plan '{}' schedules two corruption events on worker {w}",
                        self.name
                    );
                    has_corruption[*w] = true;
                    anyhow::ensure!(
                        *prob > 0.0 && *prob <= 1.0 && prob.is_finite(),
                        "corruption probability must be in (0, 1], got {prob}"
                    );
                }
            }
        }
        Ok(())
    }

    /// Compile the plan for an `n_workers` cluster: precompute the
    /// per-worker crash schedule, slowdown factor traces, and drop
    /// probabilities. Validates first.
    pub fn compile(&self, n_workers: usize) -> anyhow::Result<CompiledPlan> {
        self.validate(n_workers)?;
        let mut crash: Vec<Option<CrashSpec>> = vec![None; n_workers];
        let mut slow: Vec<Vec<(u64, Vec<f64>)>> = vec![Vec::new(); n_workers];
        let mut drop_prob = vec![0f64; n_workers];
        let mut corrupt: Vec<Option<(u64, f64)>> = vec![None; n_workers];
        for (w, e) in &self.events {
            match e {
                FaultEvent::PermanentCrash { round, fraction } => {
                    crash[*w] = Some(CrashSpec {
                        round: *round,
                        fraction: *fraction,
                        respawn_after: None,
                    });
                }
                FaultEvent::TransientCrash { round, fraction, respawn_after } => {
                    crash[*w] = Some(CrashSpec {
                        round: *round,
                        fraction: *fraction,
                        respawn_after: Some(*respawn_after),
                    });
                }
                FaultEvent::Slowdown { from_round, rounds, params } => {
                    // Normalize the Markov trace by its base mean so the
                    // factor is ≈ 1 in the normal state and ≈ `slowdown`
                    // inside a congestion burst; the trace seed mixes
                    // the plan seed with (worker, from_round) so every
                    // slowdown interval gets its own stream.
                    let trace_seed = self.seed
                        ^ fnv1a(
                            (*w as u64)
                                .to_le_bytes()
                                .into_iter()
                                .chain(from_round.to_le_bytes()),
                        );
                    let trace = generate_markov_trace(params, *rounds as usize, trace_seed);
                    let base_mean = params.base_delta + 1.0 / params.base_mu;
                    let factors = trace.iter().map(|t| t / base_mean).collect();
                    slow[*w].push((*from_round, factors));
                }
                FaultEvent::TaskDrop { prob } => drop_prob[*w] = *prob,
                FaultEvent::Corruption { from_round, prob } => {
                    corrupt[*w] = Some((*from_round, *prob));
                }
            }
        }
        Ok(CompiledPlan { n_workers, seed: self.seed, crash, slow, drop_prob, corrupt })
    }
}

/// A compiled crash event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashSpec {
    /// Round the crash fires in.
    pub round: u64,
    /// Fraction of the sampled task delay the worker survives.
    pub fraction: f64,
    /// `Some(d)` = transient (respawn after `d` rounds), `None` =
    /// permanent.
    pub respawn_after: Option<u64>,
}

/// A [`FaultPlan`] compiled for a concrete cluster size: pure-function
/// lookups for the coordinator's dispatch loop and the DES engine.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    n_workers: usize,
    seed: u64,
    crash: Vec<Option<CrashSpec>>,
    slow: Vec<Vec<(u64, Vec<f64>)>>,
    drop_prob: Vec<f64>,
    corrupt: Vec<Option<(u64, f64)>>,
}

impl CompiledPlan {
    /// Cluster size the plan was compiled for.
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// The crash scheduled on worker `w`, if any.
    pub fn crash_of(&self, w: usize) -> Option<CrashSpec> {
        self.crash[w]
    }

    /// Multiplicative straggle factor for worker `w` in round `round`
    /// (product over overlapping slowdown intervals; 1.0 outside them).
    pub fn slow_factor(&self, w: usize, round: u64) -> f64 {
        let mut f = 1.0;
        for (from, factors) in &self.slow[w] {
            if round >= *from {
                if let Some(x) = factors.get((round - from) as usize) {
                    f *= x;
                }
            }
        }
        f
    }

    /// Whether worker `w` drops its task in round `round`. A pure
    /// function of `(plan seed, w, round)` — the live coordinator and
    /// the DES engine flip the **same** coin, so dropped-task counts
    /// agree deterministically across backends.
    pub fn drops_task(&self, w: usize, round: u64) -> bool {
        let p = self.drop_prob[w];
        if p <= 0.0 {
            return false;
        }
        let mut state = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((w as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93))
            .wrapping_add(round.wrapping_mul(0xA076_1D64_78BD_642F));
        let x = splitmix64(&mut state);
        ((x >> 11) as f64) * (1.0 / 9_007_199_254_740_992.0) < p
    }

    /// Drop probability configured for worker `w`.
    pub fn drop_prob(&self, w: usize) -> f64 {
        self.drop_prob[w]
    }

    /// Whether worker `w` silently corrupts its result in round
    /// `round`. A pure function of `(plan seed, w, round)` on a coin
    /// stream **independent of the drop coin** (different mixing
    /// constants), so drop and corruption schedules never correlate.
    /// The live coordinator and the DES corruption path flip the same
    /// coin, so corrupted-result counts agree deterministically across
    /// backends.
    pub fn corrupts_result(&self, w: usize, round: u64) -> bool {
        let Some((from, p)) = self.corrupt[w] else {
            return false;
        };
        if round < from || p <= 0.0 {
            return false;
        }
        let mut state = self
            .seed
            .wrapping_mul(0xBF58_476D_1CE4_E5B9)
            .wrapping_add((w as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
            .wrapping_add(round.wrapping_mul(0x2545_F491_4F6C_DD1D));
        let x = splitmix64(&mut state);
        ((x >> 11) as f64) * (1.0 / 9_007_199_254_740_992.0) < p
    }

    /// Corruption `(from_round, prob)` configured for worker `w`.
    pub fn corruption_of(&self, w: usize) -> Option<(u64, f64)> {
        self.corrupt[w]
    }

    /// Whether any worker carries a corruption event.
    pub fn any_corruption(&self) -> bool {
        self.corrupt.iter().any(Option::is_some)
    }

    /// One past the last round any scheduled (non-drop) event is still
    /// active — the minimum horizon a chaos run needs to see every
    /// event fire at least once.
    pub fn horizon(&self) -> u64 {
        let mut h = 0u64;
        for c in self.crash.iter().flatten() {
            h = h.max(c.round + 1 + c.respawn_after.unwrap_or(0));
        }
        for per_worker in &self.slow {
            for (from, factors) in per_worker {
                h = h.max(from + factors.len() as u64);
            }
        }
        // Corruption is open-ended like task drops, but its onset round
        // must be inside the horizon so a chaos run sees it fire.
        for (from, _) in self.corrupt.iter().flatten() {
            h = h.max(from + 1);
        }
        h
    }
}

/// Largest feasible batch count for a degraded round: the biggest
/// divisor of `n_units` that is at most `min(n_live, b_cur)` (a batch
/// needs at least one live worker, and degradation only ever *shrinks*
/// the batch count — more replication, never less).
pub fn degraded_batch_count(n_units: usize, n_live: usize, b_cur: usize) -> usize {
    let cap = n_live.min(b_cur).max(1);
    (1..=cap).rev().find(|d| n_units % d == 0).unwrap_or(1)
}

/// Re-plan the assignment onto the survivors: live workers round-robin
/// over the `b_new` batches in id order (every batch gets at least one
/// live replica when `b_new <= live count`), dead workers continue the
/// round-robin so the [`Assignment`] stays total (they are never
/// dispatched to).
pub fn degraded_assignment(
    n_workers: usize,
    dead: &[bool],
    b_new: usize,
) -> anyhow::Result<Assignment> {
    anyhow::ensure!(dead.len() == n_workers, "need one liveness flag per worker");
    let n_live = dead.iter().filter(|&&d| !d).count();
    anyhow::ensure!(
        b_new >= 1 && b_new <= n_live,
        "degraded batch count {b_new} needs at least that many live workers ({n_live} live)"
    );
    let mut workers_of_batch = vec![Vec::new(); b_new];
    let mut batch_of_worker = vec![0usize; n_workers];
    let mut next = 0usize;
    for (w, b) in batch_of_worker.iter_mut().enumerate() {
        if !dead[w] {
            *b = next % b_new;
            workers_of_batch[next % b_new].push(w);
            next += 1;
        }
    }
    for (w, b) in batch_of_worker.iter_mut().enumerate() {
        if dead[w] {
            *b = next % b_new;
            workers_of_batch[next % b_new].push(w);
            next += 1;
        }
    }
    let assignment = Assignment { n_workers, n_batches: b_new, workers_of_batch, batch_of_worker };
    assignment.validate()?;
    Ok(assignment)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_compile() {
        for name in FaultPlan::preset_names() {
            let plan = FaultPlan::preset(name).expect("preset");
            assert_eq!(&plan.name, name);
            plan.compile(8).expect("compiles for N=8");
        }
        assert!(FaultPlan::preset("nope").is_none());
        assert!(FaultPlan::load("nope").is_err());
    }

    #[test]
    fn plan_json_round_trips() {
        let plan = FaultPlan::preset("mixed").expect("preset");
        let j = plan.to_json();
        let back = FaultPlan::from_json(&Json::parse(&j.to_string()).expect("parse"))
            .expect("from_json");
        assert_eq!(plan, back);
    }

    #[test]
    fn validation_rejects_malformed_plans() {
        let base = FaultPlan {
            name: "t".into(),
            seed: 1,
            events: vec![(0, FaultEvent::PermanentCrash { round: 1, fraction: 0.5 })],
        };
        base.validate(4).expect("valid");
        // Worker out of range.
        assert!(base.validate(0).is_err());
        // Two crashes on one worker.
        let double = FaultPlan {
            events: vec![
                (0, FaultEvent::PermanentCrash { round: 1, fraction: 0.5 }),
                (0, FaultEvent::TransientCrash { round: 3, fraction: 0.5, respawn_after: 1 }),
            ],
            ..base.clone()
        };
        assert!(double.validate(4).is_err());
        // Bad fraction / probability.
        let bad_frac = FaultPlan {
            events: vec![(0, FaultEvent::PermanentCrash { round: 1, fraction: 1.5 })],
            ..base.clone()
        };
        assert!(bad_frac.validate(4).is_err());
        let bad_drop =
            FaultPlan { events: vec![(0, FaultEvent::TaskDrop { prob: 1.0 })], ..base.clone() };
        assert!(bad_drop.validate(4).is_err());
    }

    #[test]
    fn compiled_lookups_are_deterministic() {
        let plan = FaultPlan::preset("mixed").expect("preset");
        let a = plan.compile(8).expect("compile");
        let b = plan.compile(8).expect("compile");
        for w in 0..8 {
            assert_eq!(a.crash_of(w), b.crash_of(w));
            for round in 0..40 {
                assert_eq!(a.slow_factor(w, round), b.slow_factor(w, round));
                assert_eq!(a.drops_task(w, round), b.drops_task(w, round));
            }
        }
        // A different plan seed flips at least one drop coin over a
        // long window (prob 0.15 on worker 2).
        let reseeded = FaultPlan { seed: 7, ..plan }.compile(8).expect("compile");
        let flips = (0..400)
            .filter(|&r| reseeded.drops_task(2, r) != a.drops_task(2, r))
            .count();
        assert!(flips > 0, "reseeded plan flipped no drop coins");
    }

    #[test]
    fn slowdown_factor_is_one_outside_the_interval() {
        let plan = FaultPlan::preset("slowdown").expect("preset");
        let c = plan.compile(4).expect("compile");
        assert_eq!(c.slow_factor(0, 0), 1.0);
        assert_eq!(c.slow_factor(0, 1), 1.0);
        assert_eq!(c.slow_factor(0, 2 + 24), 1.0);
        assert_eq!(c.slow_factor(1, 5), 1.0, "untargeted worker never slows");
        // Inside the always-congested interval the mean factor is far
        // above 1 (slowdown 8 on a mean-1.2 base law).
        let mean: f64 =
            (2..26).map(|r| c.slow_factor(0, r)).sum::<f64>() / 24.0;
        assert!(mean > 3.0, "congested mean factor {mean}");
        assert_eq!(c.horizon(), 26);
    }

    #[test]
    fn drop_coin_frequency_tracks_probability() {
        let plan = FaultPlan {
            name: "d".into(),
            seed: 9,
            events: vec![(0, FaultEvent::TaskDrop { prob: 0.25 })],
        };
        let c = plan.compile(2).expect("compile");
        let hits = (0..4000).filter(|&r| c.drops_task(0, r)).count() as f64 / 4000.0;
        assert!((hits - 0.25).abs() < 0.03, "drop frequency {hits}");
        assert!(!(0..4000).any(|r| c.drops_task(1, r)), "untargeted worker never drops");
    }

    #[test]
    fn corruption_round_trips_validates_and_flips_independent_coins() {
        // Preset resolves, compiles, and survives the JSON round trip.
        let plan = FaultPlan::preset("corrupt").expect("preset");
        let j = plan.to_json();
        let back = FaultPlan::from_json(&Json::parse(&j.to_string()).expect("parse"))
            .expect("from_json");
        assert_eq!(plan, back);
        let c = plan.compile(4).expect("compile");
        assert_eq!(c.corruption_of(0), Some((2, 0.6)));
        assert_eq!(c.corruption_of(2), None);
        assert!(c.any_corruption());
        assert_eq!(c.horizon(), 5, "corruption onset rounds extend the horizon");
        // Nothing fires before from_round; the frequency tracks prob after.
        assert!(!(0..2).any(|r| c.corrupts_result(0, r)));
        let hits = (2..4002).filter(|&r| c.corrupts_result(0, r)).count() as f64 / 4000.0;
        assert!((hits - 0.6).abs() < 0.03, "corruption frequency {hits}");
        assert!(!(0..4000).any(|r| c.corrupts_result(2, r)), "untargeted worker is honest");
        // Validation: prob bounds and the one-event-per-worker rule.
        let bad = FaultPlan {
            name: "bad".into(),
            seed: 1,
            events: vec![(0, FaultEvent::Corruption { from_round: 0, prob: 1.5 })],
        };
        assert!(bad.validate(4).is_err());
        let double = FaultPlan {
            events: vec![
                (0, FaultEvent::Corruption { from_round: 0, prob: 0.5 }),
                (0, FaultEvent::Corruption { from_round: 3, prob: 0.2 }),
            ],
            ..bad.clone()
        };
        assert!(double.validate(4).is_err());
        // The corruption coin stream is independent of the drop coin
        // stream: same worker, same prob, same seed — different draws.
        let both = FaultPlan {
            name: "both".into(),
            seed: 11,
            events: vec![
                (0, FaultEvent::TaskDrop { prob: 0.5 }),
                (0, FaultEvent::Corruption { from_round: 0, prob: 0.5 }),
            ],
        }
        .compile(2)
        .expect("compile");
        let differs =
            (0..400).filter(|&r| both.drops_task(0, r) != both.corrupts_result(0, r)).count();
        assert!(differs > 50, "drop and corruption coins look correlated ({differs}/400 differ)");
    }

    #[test]
    fn degraded_replan_covers_every_batch_with_a_live_worker() {
        // 8 units, 4 batches, workers {1, 3, 6} alive → b_new = 2.
        let mut dead = vec![true; 8];
        for w in [1usize, 3, 6] {
            dead[w] = false;
        }
        let b_new = degraded_batch_count(8, 3, 4);
        assert_eq!(b_new, 2);
        let a = degraded_assignment(8, &dead, b_new).expect("assignment");
        assert_eq!(a.n_batches, 2);
        for (b, ws) in a.workers_of_batch.iter().enumerate() {
            assert!(
                ws.iter().any(|&w| !dead[w]),
                "degraded batch {b} has no live replica: {ws:?}"
            );
        }
        // Sole survivor degrades to full replication.
        assert_eq!(degraded_batch_count(8, 1, 4), 1);
        // Prime unit counts can always fall back to b = 1.
        assert_eq!(degraded_batch_count(7, 3, 4), 1);
        // Requesting more batches than live workers is refused.
        assert!(degraded_assignment(8, &dead, 4).is_err());
    }
}
