//! Worker nodes: threads that execute compute tasks over their local
//! data shard, with injected straggler delays and cancellation.
//!
//! Each worker owns its data shard (placed once at setup — the paper's
//! stage-two distribution) and a compute backend. The default backend
//! executes the AOT-compiled PJRT artifacts ([`PjrtCompute`], created
//! *inside* the worker thread because PJRT executables are not `Send`);
//! [`MockCompute`] is a pure-Rust implementation of the same math used
//! by tests and as an independent numerical oracle.
//!
//! Straggling is *injected*: before computing, the worker sleeps for the
//! service time the master sampled from the paper's distributions
//! (scaled by `time_scale`), polling its cancellation token so a
//! cancelled replica stops early — the live analogue of the DES
//! engine's cancel events.

use crate::runtime::GradOut;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// A worker's local data shard (row-major `rows×dim` plus targets).
#[derive(Debug, Clone, Default)]
pub struct Shard {
    /// Row count.
    pub rows: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Row-major features.
    pub x: Vec<f32>,
    /// Targets (`grad` job only).
    pub y: Vec<f32>,
}

/// Job payload: which computation to run against the shard.
#[derive(Debug, Clone)]
pub enum JobSpec {
    /// Least-squares partial gradient at weights `w`.
    Grad { w: Arc<Vec<f32>> },
    /// Map-sum with per-feature coefficients.
    MapSum { a: Arc<Vec<f32>>, b: Arc<Vec<f32>> },
}

/// Job output from one replica.
#[derive(Debug, Clone, PartialEq)]
pub enum JobOut {
    /// Gradient + loss sums.
    Grad(GradOut),
    /// Map-sum scalar.
    MapSum(f32),
}

/// A task dispatched to a worker.
#[derive(Debug)]
pub struct TaskMsg {
    /// Job (round) id.
    pub job_id: u64,
    /// Batch this replica covers.
    pub batch_id: usize,
    /// What to compute.
    pub spec: JobSpec,
    /// Injected straggler delay, wall-clock seconds.
    pub delay_s: f64,
    /// Cooperative cancellation token for this (job, batch).
    pub cancel: Arc<AtomicBool>,
    /// Fault injection: `Some(s)` crashes the worker `s` wall-clock
    /// seconds into this task — it reports one final `out: None` result
    /// (the failure detector firing) and its thread exits, never to
    /// accept another task.
    pub crash_after_s: Option<f64>,
    /// Fault injection: silently corrupt this replica's result — the
    /// worker completes on time but returns a deterministically
    /// perturbed value (see [`corrupt_output`]), the failure mode the
    /// m-of-g vote exists to catch.
    pub corrupt: bool,
}

/// The silent-corruption perturbation: every output component is
/// shifted by `1 + worker_id`. Additive (so zero outputs still differ)
/// and worker-dependent (so two corrupt replicas of the same batch
/// never agree with *each other* either — an all-corrupt batch stays
/// detectable as disagreement even though it is unattributable).
pub fn corrupt_output(worker_id: usize, out: &mut JobOut) {
    let shift = 1.0 + worker_id as f32;
    match out {
        JobOut::Grad(g) => {
            for v in &mut g.grad {
                *v += shift;
            }
            g.loss += shift;
        }
        JobOut::MapSum(v) => *v += shift,
    }
}

/// Worker → master result.
#[derive(Debug)]
pub struct ResultMsg {
    /// Job id echoed from the task.
    pub job_id: u64,
    /// Batch id echoed from the task.
    pub batch_id: usize,
    /// Reporting worker.
    pub worker_id: usize,
    /// `Some(out)` when the task ran to completion; `None` when it was
    /// cancelled mid-delay (or the backend failed).
    pub out: Option<JobOut>,
    /// The injected delay that was configured for this replica.
    pub injected_s: f64,
}

/// Compute backend interface. Implementations live on the worker thread
/// and need not be `Send`.
pub trait Compute {
    /// Run a job over the local shard.
    fn run(&mut self, shard: &Shard, spec: &JobSpec) -> anyhow::Result<JobOut>;
}

/// Pure-Rust reference backend (tests, oracle, and artifact-free runs).
#[derive(Debug, Default, Clone)]
pub struct MockCompute;

impl Compute for MockCompute {
    fn run(&mut self, shard: &Shard, spec: &JobSpec) -> anyhow::Result<JobOut> {
        let (rows, dim) = (shard.rows, shard.dim);
        match spec {
            JobSpec::Grad { w } => {
                let mut grad = vec![0f32; dim];
                let mut loss = 0f32;
                for r in 0..rows {
                    let xr = &shard.x[r * dim..(r + 1) * dim];
                    let mut pred = 0f32;
                    for j in 0..dim {
                        pred += xr[j] * w[j];
                    }
                    let resid = pred - shard.y[r];
                    loss += 0.5 * resid * resid;
                    for j in 0..dim {
                        grad[j] += resid * xr[j];
                    }
                }
                Ok(JobOut::Grad(GradOut { grad, loss }))
            }
            JobSpec::MapSum { a, b } => {
                let mut total = 0f32;
                for r in 0..rows {
                    let xr = &shard.x[r * dim..(r + 1) * dim];
                    let mut s = 0f32;
                    for j in 0..dim {
                        s += a[j] * xr[j] * xr[j] + b[j] * xr[j];
                    }
                    total += s.tanh();
                }
                Ok(JobOut::MapSum(total))
            }
        }
    }
}

/// PJRT backend: executes the AOT artifacts. The shard row count is
/// padded with zero rows up to the nearest available artifact variant
/// (exact for both jobs: zero rows contribute 0 to every output sum).
#[derive(Debug)]
pub struct PjrtCompute {
    engine: crate::runtime::Engine,
    /// Padded-variant cache: (kernel, shard rows) → artifact rows.
    pad_to: std::collections::BTreeMap<(String, usize), usize>,
}

impl PjrtCompute {
    /// Create over an artifact directory.
    pub fn new(artifact_dir: &std::path::Path) -> anyhow::Result<Self> {
        Ok(Self {
            engine: crate::runtime::Engine::new(artifact_dir)?,
            pad_to: Default::default(),
        })
    }

    fn variant_rows(&mut self, kernel: &str, rows: usize, dim: usize) -> anyhow::Result<usize> {
        if let Some(&v) = self.pad_to.get(&(kernel.to_string(), rows)) {
            return Ok(v);
        }
        let avail = self.engine.manifest().rows_for(kernel, dim);
        let v = *avail.iter().find(|&&r| r >= rows).ok_or_else(|| {
            anyhow::anyhow!(
                "no {kernel} artifact with rows >= {rows} (dim {dim}); \
                 available rows: {avail:?} — re-run `make artifacts` with --rows"
            )
        })?;
        self.pad_to.insert((kernel.to_string(), rows), v);
        Ok(v)
    }
}

impl Compute for PjrtCompute {
    fn run(&mut self, shard: &Shard, spec: &JobSpec) -> anyhow::Result<JobOut> {
        let (rows, dim) = (shard.rows, shard.dim);
        match spec {
            JobSpec::Grad { w } => {
                let v = self.variant_rows("grad", rows, dim)?;
                let out = if v == rows {
                    self.engine.grad(v, dim, &shard.x, &shard.y, w)?
                } else {
                    let mut x = shard.x.clone();
                    x.resize(v * dim, 0.0);
                    let mut y = shard.y.clone();
                    y.resize(v, 0.0);
                    self.engine.grad(v, dim, &x, &y, w)?
                };
                Ok(JobOut::Grad(out))
            }
            JobSpec::MapSum { a, b } => {
                let v = self.variant_rows("mapsum", rows, dim)?;
                let out = if v == rows {
                    self.engine.mapsum(v, dim, &shard.x, a, b)?
                } else {
                    let mut x = shard.x.clone();
                    x.resize(v * dim, 0.0);
                    self.engine.mapsum(v, dim, &x, a, b)?
                };
                Ok(JobOut::MapSum(out))
            }
        }
    }
}

/// Handle to a spawned worker thread.
#[derive(Debug)]
pub struct WorkerHandle {
    /// Task channel into the worker.
    pub tx: Sender<TaskMsg>,
    join: std::thread::JoinHandle<()>,
}

impl WorkerHandle {
    /// Close the task channel and join the thread.
    pub fn shutdown(self) {
        drop(self.tx);
        let _ = self.join.join();
    }
}

/// Granularity of the cancellation poll while sleeping out the injected
/// delay.
const CANCEL_POLL: std::time::Duration = std::time::Duration::from_millis(1);

/// Spawn a worker thread.
///
/// `compute_factory` runs *on the worker thread* (PJRT engines are not
/// `Send`); a factory error is reported once and the worker then answers
/// every task with a cancelled result rather than wedging the master.
/// A thread-spawn failure (OS limit) is a named error, not a panic —
/// the coordinator routes it through its respawn/degradation machinery.
pub fn spawn_worker<F>(
    worker_id: usize,
    shard: Shard,
    compute_factory: F,
    results: Sender<ResultMsg>,
) -> anyhow::Result<WorkerHandle>
where
    F: FnOnce() -> anyhow::Result<Box<dyn Compute>> + Send + 'static,
{
    let (tx, rx): (Sender<TaskMsg>, Receiver<TaskMsg>) = std::sync::mpsc::channel();
    let join = std::thread::Builder::new()
        .name(format!("batchrep-worker-{worker_id}"))
        .spawn(move || {
            let mut compute = match compute_factory() {
                Ok(c) => Some(c),
                Err(e) => {
                    eprintln!("worker {worker_id}: compute init failed: {e}");
                    None
                }
            };
            while let Ok(task) = rx.recv() {
                if let Some(crash_s) = task.crash_after_s {
                    // Die mid-task: sleep out the time-to-failure, emit
                    // the death notice, and exit the thread.
                    std::thread::sleep(std::time::Duration::from_secs_f64(crash_s));
                    let _ = results.send(ResultMsg {
                        job_id: task.job_id,
                        batch_id: task.batch_id,
                        worker_id,
                        out: None,
                        injected_s: task.delay_s,
                    });
                    return;
                }
                let mut out = run_task(worker_id, &shard, compute.as_mut(), &task);
                if task.corrupt {
                    if let Some(o) = &mut out {
                        corrupt_output(worker_id, o);
                    }
                }
                let msg = ResultMsg {
                    job_id: task.job_id,
                    batch_id: task.batch_id,
                    worker_id,
                    out,
                    injected_s: task.delay_s,
                };
                if results.send(msg).is_err() {
                    break; // master gone
                }
            }
        })
        .map_err(|e| anyhow::anyhow!("failed to spawn worker thread {worker_id}: {e}"))?;
    Ok(WorkerHandle { tx, join })
}

#[allow(clippy::disallowed_methods)] // worker straggle injection is inherently wall-clock
fn run_task(
    worker_id: usize,
    shard: &Shard,
    compute: Option<&mut Box<dyn Compute>>,
    task: &TaskMsg,
) -> Option<JobOut> {
    // Injected straggle: sleep in small slices, checking cancellation.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs_f64(task.delay_s);
    loop {
        if task.cancel.load(Ordering::Relaxed) {
            return None;
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep((deadline - now).min(CANCEL_POLL));
    }
    if task.cancel.load(Ordering::Relaxed) {
        return None;
    }
    let compute = compute?;
    match compute.run(shard, &task.spec) {
        Ok(out) => Some(out),
        Err(e) => {
            eprintln!("worker {worker_id}: compute error: {e}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_2x2() -> Shard {
        // X = [[1,2],[3,4]], y = [1, 1]
        Shard { rows: 2, dim: 2, x: vec![1.0, 2.0, 3.0, 4.0], y: vec![1.0, 1.0] }
    }

    #[test]
    fn mock_grad_math() {
        let mut c = MockCompute;
        let w = Arc::new(vec![1.0f32, 0.0]);
        let out = c.run(&shard_2x2(), &JobSpec::Grad { w }).unwrap();
        // pred = [1, 3], resid = [0, 2], loss = 2, grad = 2*[3,4] = [6,8]
        match out {
            JobOut::Grad(g) => {
                assert_eq!(g.grad, vec![6.0, 8.0]);
                assert_eq!(g.loss, 2.0);
            }
            _ => panic!("wrong output kind"),
        }
    }

    #[test]
    fn mock_mapsum_math() {
        let mut c = MockCompute;
        let a = Arc::new(vec![0.0f32, 0.0]);
        let b = Arc::new(vec![1.0f32, 0.0]);
        let out = c.run(&shard_2x2(), &JobSpec::MapSum { a, b }).unwrap();
        // scores = tanh(1) + tanh(3)
        match out {
            JobOut::MapSum(s) => {
                let expect = 1f32.tanh() + 3f32.tanh();
                assert!((s - expect).abs() < 1e-6);
            }
            _ => panic!("wrong output kind"),
        }
    }

    #[test]
    fn worker_executes_and_reports() {
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let h =
            spawn_worker(3, shard_2x2(), || Ok(Box::new(MockCompute) as Box<dyn Compute>), res_tx)
                .unwrap();
        let cancel = Arc::new(AtomicBool::new(false));
        h.tx.send(TaskMsg {
            job_id: 9,
            batch_id: 1,
            spec: JobSpec::Grad { w: Arc::new(vec![0.0, 0.0]) },
            delay_s: 0.0,
            cancel,
            crash_after_s: None,
            corrupt: false,
        })
        .unwrap();
        let r = res_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!((r.job_id, r.batch_id, r.worker_id), (9, 1, 3));
        assert!(r.out.is_some());
        h.shutdown();
    }

    #[test]
    fn cancellation_stops_delayed_task() {
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let h =
            spawn_worker(0, shard_2x2(), || Ok(Box::new(MockCompute) as Box<dyn Compute>), res_tx)
                .unwrap();
        let cancel = Arc::new(AtomicBool::new(false));
        h.tx.send(TaskMsg {
            job_id: 1,
            batch_id: 0,
            spec: JobSpec::Grad { w: Arc::new(vec![0.0, 0.0]) },
            delay_s: 10.0, // would block the test if not cancelled
            cancel: cancel.clone(),
            crash_after_s: None,
            corrupt: false,
        })
        .unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        cancel.store(true, Ordering::Relaxed);
        let r = res_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert!(r.out.is_none(), "cancelled task must not produce output");
        h.shutdown();
    }

    #[test]
    fn failed_factory_reports_cancelled_results() {
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let h = spawn_worker(0, shard_2x2(), || anyhow::bail!("boom"), res_tx).unwrap();
        let cancel = Arc::new(AtomicBool::new(false));
        h.tx.send(TaskMsg {
            job_id: 1,
            batch_id: 0,
            spec: JobSpec::Grad { w: Arc::new(vec![0.0, 0.0]) },
            delay_s: 0.0,
            cancel,
            crash_after_s: None,
            corrupt: false,
        })
        .unwrap();
        let r = res_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert!(r.out.is_none());
        h.shutdown();
    }

    #[test]
    fn crash_reports_death_notice_and_kills_thread() {
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let h =
            spawn_worker(2, shard_2x2(), || Ok(Box::new(MockCompute) as Box<dyn Compute>), res_tx)
                .unwrap();
        let cancel = Arc::new(AtomicBool::new(false));
        h.tx.send(TaskMsg {
            job_id: 7,
            batch_id: 0,
            spec: JobSpec::Grad { w: Arc::new(vec![0.0, 0.0]) },
            delay_s: 10.0, // never slept: the crash preempts the task
            cancel: cancel.clone(),
            crash_after_s: Some(0.005),
            corrupt: false,
        })
        .unwrap();
        let r = res_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!((r.job_id, r.batch_id, r.worker_id), (7, 0, 2));
        assert!(r.out.is_none(), "crashed replica must not produce output");
        // The thread has exited: a follow-up task is never answered, and
        // shutdown (which joins) returns promptly.
        h.tx.send(TaskMsg {
            job_id: 8,
            batch_id: 0,
            spec: JobSpec::Grad { w: Arc::new(vec![0.0, 0.0]) },
            delay_s: 0.0,
            cancel,
            crash_after_s: None,
            corrupt: false,
        })
        .ok();
        h.shutdown();
    }

    #[test]
    fn corrupt_task_perturbs_deterministically() {
        // A corrupted replica completes on time but returns the honest
        // value shifted by 1 + worker_id on every component — so two
        // corrupt workers never agree with the honest value or each
        // other.
        let run = |worker: usize, corrupt: bool| -> JobOut {
            let (res_tx, res_rx) = std::sync::mpsc::channel();
            let h = spawn_worker(
                worker,
                shard_2x2(),
                || Ok(Box::new(MockCompute) as Box<dyn Compute>),
                res_tx,
            )
            .unwrap();
            h.tx.send(TaskMsg {
                job_id: 0,
                batch_id: 0,
                spec: JobSpec::Grad { w: Arc::new(vec![1.0, 0.0]) },
                delay_s: 0.0,
                cancel: Arc::new(AtomicBool::new(false)),
                crash_after_s: None,
                corrupt,
            })
            .unwrap();
            let r = res_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
            h.shutdown();
            r.out.expect("task completed")
        };
        let honest = run(3, false);
        match honest {
            JobOut::Grad(ref g) => assert_eq!(g.grad, vec![6.0, 8.0]),
            _ => panic!("wrong output kind"),
        }
        let corrupt3 = run(3, true);
        match (&honest, &corrupt3) {
            (JobOut::Grad(h), JobOut::Grad(c)) => {
                assert_eq!(c.grad, vec![h.grad[0] + 4.0, h.grad[1] + 4.0]);
                assert_eq!(c.loss, h.loss + 4.0);
            }
            _ => panic!("wrong output kind"),
        }
        // Determinism and worker-dependence.
        assert_eq!(run(3, true), corrupt3);
        assert_ne!(run(5, true), corrupt3);
    }
}
