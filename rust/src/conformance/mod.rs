//! Scenario-space conformance harness: pin every evaluation backend
//! against every other over **generated** scenarios.
//!
//! The paper's claims are only as trustworthy as the agreement between
//! the closed forms, the two independent simulators, and the live
//! runtime — and straggler-mitigation results are notoriously sensitive
//! to which corner of scenario space is evaluated. This module sweeps a
//! [`testkit`]-driven random scenario generator (policy × redundancy
//! mode × k-of-B × worker speeds × failure injection × service spec,
//! all drawn from valid ranges, shrunk on failure) through a
//! [`cross_check_matrix`](run_matrix) of every applicable backend pair:
//!
//! * **Analytic ↔ Monte-Carlo** — upfront, no failures, disjoint,
//!   exp-family (including heterogeneous speeds: exact for Exp,
//!   bounded for SExp);
//! * **Analytic ↔ DES** — same scope as Analytic ↔ MC;
//! * **Monte-Carlo ↔ DES** — every upfront reliable scenario (any
//!   service spec, any layout, k-of-B);
//! * **DES ↔ DES-reference** — *every* scenario: the flat+block engine
//!   vs the retained heap+scalar engine on an independent substream —
//!   the only pair that covers speculative redundancy and failure
//!   injection;
//! * **DES ↔ Live** — small clusters, upfront, no failures, exp-family:
//!   the real coordinator with injected time, k-of-B included.
//!
//! Tolerances are **statistically sound**: each cell compares two mean
//! estimates through an interval test — `|gap| ≤ z·√(sem_a² + sem_b²) +
//! floor·scale` where the analytic leg contributes a zero-width point
//! (exact) or its provable bound interval (heterogeneous SExp), and the
//! floor is a small relative guard for rounding/CLT-tail effects, not a
//! hand-tuned epsilon. Live cells carry a wider floor for wall-clock
//! scheduling noise.
//!
//! Every failure panics through [`testkit::check_with`], so it is
//! reported at its **shrunk minimal case** together with a
//! `BATCHREP_PROP_SEED` replay seed that reproduces it deterministically
//! (backend results are bit-reproducible per seed for *any* thread
//! count — the logical-shard plan guarantees it). Run it as
//! `batchrep conformance [--fast|--long]`; `ci.sh` runs the fast mode
//! as a merge gate, and `--long` is the off-by-default soak sweep
//! ([`MatrixOptions::long`]) for releases and backend rewrites.
//!
//! The deterministic anchor corners are **enumerated through the study
//! planner** ([`crate::study::StudySpec`] grids compiled to scenario
//! lists), so the matrix and the planner share one grid vocabulary —
//! axes, canonicalization, and derived seeds.

use crate::analysis;
use crate::des::engine::{simulate_many_reference, EngineConfig, Redundancy};
use crate::des::Scenario;
use crate::dist::{BatchService, ServiceSpec};
use crate::evaluator::{
    AnalyticEvaluator, CompletionStats, DesEvaluator, Evaluator, LiveEvaluator,
    MonteCarloEvaluator, ReplicationPolicy,
};
use crate::testkit::{self, Gen};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

/// Knobs of one conformance-matrix run.
#[derive(Debug, Clone)]
pub struct MatrixOptions {
    /// Randomly generated scenarios to sweep (anchor scenarios run in
    /// addition to these).
    pub scenarios: u64,
    /// Monte-Carlo trials per cell.
    pub mc_trials: u64,
    /// DES trials per cell (fast engine and reference each).
    pub des_trials: u64,
    /// Live rounds per DES↔Live cell.
    pub live_rounds: u64,
    /// Evaluator worker threads — wall-clock only; results are
    /// identical for every thread count.
    pub threads: usize,
    /// Run the DES↔Live cells (real coordinator + worker threads).
    pub include_live: bool,
    /// Base seed override for the random sweep (`None` = the stable
    /// name-hash / `BATCHREP_PROP_SEED` default).
    pub seed: Option<u64>,
    /// z-multiplier of the combined standard error.
    pub z: f64,
    /// Relative tolerance floor of the simulation cells (rounding and
    /// CLT-tail guard).
    pub rel_floor: f64,
    /// Relative tolerance floor of the live cells (wall-clock
    /// scheduling noise rides on top of sampling error).
    pub live_floor: f64,
}

impl MatrixOptions {
    /// The CI gate: ~200 scenarios at smoke-quality trial counts.
    pub fn fast() -> Self {
        Self {
            scenarios: 200,
            mc_trials: 24_000,
            des_trials: 12_000,
            live_rounds: 48,
            threads: crate::evaluator::auto_threads().min(8),
            include_live: true,
            seed: None,
            z: 5.0,
            rel_floor: 0.004,
            live_floor: 0.12,
        }
    }

    /// The thorough sweep: more scenarios, tighter standard errors.
    pub fn full() -> Self {
        Self {
            scenarios: 600,
            mc_trials: 120_000,
            des_trials: 50_000,
            live_rounds: 90,
            ..Self::fast()
        }
    }

    /// The soak sweep (`batchrep conformance --long`, off by default):
    /// a much larger scenario count at full-precision trial budgets.
    /// Expect minutes to hours of wall clock — run it before releases
    /// or after backend rewrites, not in CI. Failures replay exactly
    /// like the other modes: rerun `batchrep conformance --long` with
    /// the printed `BATCHREP_PROP_SEED` environment variable (or
    /// `--seed`) and the same trial counts.
    pub fn long() -> Self {
        Self {
            scenarios: 2_000,
            mc_trials: 240_000,
            des_trials: 100_000,
            live_rounds: 120,
            ..Self::fast()
        }
    }
}

/// Tally of a completed matrix run (what `batchrep conformance`
/// prints). Counters are advisory; any disagreement aborts the run
/// before the report is returned.
#[derive(Debug, Clone, Default)]
pub struct MatrixReport {
    /// Scenarios swept (anchors + random).
    pub scenarios: u64,
    /// Total backend-pair cells checked.
    pub cells: u64,
    /// Analytic ↔ Monte-Carlo cells.
    pub analytic_mc: u64,
    /// Analytic ↔ DES cells.
    pub analytic_des: u64,
    /// Monte-Carlo ↔ DES cells.
    pub mc_des: u64,
    /// Fast-engine ↔ reference-engine cells.
    pub des_reference: u64,
    /// DES ↔ Live cells.
    pub des_live: u64,
    /// Cells whose analytic leg used heterogeneous `worker_speeds`.
    pub hetero_analytic_cells: u64,
    /// DES ↔ Live cells with a `k_of_b` target below `B`.
    pub live_k_of_b_cells: u64,
    /// Largest observed `gap / tolerance` over all cells (1.0 = the
    /// tightest cell sat exactly on its bound).
    pub worst_gap_over_tol: f64,
}

/// Which backend pair a cell compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Pair {
    AnalyticMc,
    AnalyticDes,
    McDes,
    DesReference,
    DesLive,
}

impl Pair {
    fn name(self) -> &'static str {
        match self {
            Pair::AnalyticMc => "analytic<->montecarlo",
            Pair::AnalyticDes => "analytic<->des",
            Pair::McDes => "montecarlo<->des",
            Pair::DesReference => "des<->des-reference",
            Pair::DesLive => "des<->live",
        }
    }
}

/// One backend's mean estimate: a point with a standard error, or an
/// interval (the heterogeneous-SExp analytic bound) with `sem = 0`.
#[derive(Debug, Clone, Copy)]
struct Estimate {
    mean: f64,
    sem: f64,
    lo: f64,
    hi: f64,
}

fn point(st: &CompletionStats) -> Estimate {
    Estimate { mean: st.mean, sem: st.sem, lo: st.mean, hi: st.mean }
}

/// One generated conformance case: the scenario plus the engine-level
/// knobs that are not scenario fields (failure injection) and the
/// generator's decision to pay for a live cell.
#[derive(Debug, Clone)]
pub struct GeneratedCase {
    /// The fully self-describing scenario every backend consumes.
    pub scenario: Scenario,
    /// Per-replica crash probability of the DES cells (0 = reliable).
    pub fail_prob: f64,
    /// Whether this case also runs a DES↔Live cell (live cells cost
    /// real wall-clock, so only a small fraction of cases draw one).
    pub live: bool,
}

/// Draw one valid scenario from the full cross-product the backends
/// claim to support. Integer draws shrink toward the smallest cluster,
/// so a failing case is reported at (close to) its minimal shape.
pub fn gen_case(g: &mut Gen) -> GeneratedCase {
    let n = *g.pick(&[4usize, 6, 8, 12, 16, 24]);
    let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
    let b = *g.pick(&divisors);
    let policy = *g.pick(ReplicationPolicy::all());
    let kind = g.usize_in(0, 9);
    let mu = g.f64_in(0.6, 2.0);
    let spec = match kind {
        0..=3 => ServiceSpec::exp(mu),
        4..=7 => ServiceSpec::shifted_exp(mu, g.f64_in(0.0, 0.8)),
        // Heavy-tail ablations keep α comfortably above 3 so the means
        // and standard errors the z-cells rely on are well-behaved.
        8 => ServiceSpec::pareto(g.f64_in(0.4, 1.0), g.f64_in(3.2, 4.5)),
        _ => ServiceSpec::weibull(g.f64_in(0.7, 1.5), g.f64_in(0.5, 1.5)),
    };
    let seed = g.u64_in(0, 1 << 40);
    let mut scn = Scenario::from_policy(policy, n, b, BatchService::paper(spec), seed)
        .expect("generated (policy, N, B | N) combinations are valid by construction");
    if g.coin(0.22) {
        scn = scn
            .with_redundancy(Redundancy::Speculative { deadline_factor: g.f64_in(0.8, 2.2) });
    }
    // Policies can change the effective batch count (FullDiversity → 1,
    // OverlappingCyclic → one window per worker), so k draws against
    // the scenario's own B.
    let eff_b = scn.assignment.n_batches;
    if g.coin(0.35) {
        let k = g.usize_in(1, eff_b);
        scn = scn.with_k_of_b(k).expect("1 <= k <= B by construction");
    }
    if g.coin(0.35) {
        let speeds: Vec<f64> = (0..n).map(|_| g.f64_in(0.5, 2.0)).collect();
        scn = scn.with_speeds(speeds).expect("one positive speed per worker");
    }
    let fail_prob = if g.coin(0.2) { g.f64_in(0.05, 0.4) } else { 0.0 };
    let live = g.coin(0.05);
    GeneratedCase { scenario: scn, fail_prob, live }
}

/// Human-readable cell context (embedded in every failure message so a
/// disagreement identifies its scenario without replaying).
pub fn describe(case: &GeneratedCase) -> String {
    let scn = &case.scenario;
    let speeds = scn
        .worker_speeds
        .as_ref()
        .map(|s| format!("{s:.2?}"))
        .unwrap_or_else(|| "homogeneous".into());
    format!(
        "N={} B={} policy={} service={} redundancy={:?} k_of_b={:?} speeds={speeds} \
         fail_prob={:.3} seed={}",
        scn.n_workers(),
        scn.assignment.n_batches,
        scn.policy.name(),
        scn.service.spec.name(),
        scn.redundancy,
        scn.k_of_b,
        case.fail_prob,
        scn.seed,
    )
}

/// Does the analytic backend cover this scenario? (Mirror of
/// `AnalyticEvaluator`'s acceptance rules — kept in sync by
/// `prop_applicability_matches_the_evaluator`.)
fn analytic_applies(scn: &Scenario) -> bool {
    if scn.layout.is_overlapping || scn.redundancy != Redundancy::Upfront {
        return false;
    }
    if scn.service.spec.exp_family().is_none() {
        return false;
    }
    let b = scn.assignment.n_batches;
    if scn.worker_speeds.is_some() {
        // Exact (Exp) or bounded (SExp) — full completion only.
        !matches!(scn.k_of_b, Some(k) if k < b) && b <= 20
    } else if matches!(scn.k_of_b, Some(k) if k < b) {
        scn.assignment.is_balanced() && scn.layout.n_units == scn.assignment.n_workers
    } else {
        scn.assignment.is_balanced() || b <= 20
    }
}

/// Does a live cell make sense here? Small clusters only (one OS thread
/// per worker), upfront, reliable, exp-family (bounded injected sleeps).
fn live_applies(scn: &Scenario, fail_prob: f64) -> bool {
    scn.redundancy == Redundancy::Upfront
        && fail_prob == 0.0
        && !scn.layout.is_overlapping
        && scn.service.spec.exp_family().is_some()
        && scn.n_workers() <= 8
}

/// The analytic leg as an [`Estimate`]: a zero-width point when exact,
/// the provable bound interval under heterogeneous SExp speeds (also
/// cross-validating that the evaluator reports the interval midpoint).
fn analytic_estimate(scn: &Scenario) -> anyhow::Result<Estimate> {
    let st = AnalyticEvaluator.evaluate(scn)?;
    if let Some(speeds) = &scn.worker_speeds {
        let bounds = analysis::hetero_completion_bounds(
            &scn.assignment,
            &scn.service.spec,
            scn.layout.n_units as u64,
            speeds,
        )?;
        anyhow::ensure!(
            (st.mean - bounds.mid_mean()).abs() <= 1e-9 * bounds.mid_mean().abs().max(1.0),
            "AnalyticEvaluator mean {} drifted from the bound midpoint {}",
            st.mean,
            bounds.mid_mean()
        );
        Ok(Estimate { mean: st.mean, sem: 0.0, lo: bounds.lower.mean, hi: bounds.upper.mean })
    } else {
        Ok(point(&st))
    }
}

/// Check one cell: the distance between the two estimates' intervals
/// must not exceed the z-scaled combined standard error (plus the small
/// relative floor). Tallies the cell, then errors on disagreement.
fn check_cell(
    pair: Pair,
    a: &Estimate,
    b: &Estimate,
    z: f64,
    rel_floor: f64,
    context: &str,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    let gap = (a.lo.max(b.lo) - a.hi.min(b.hi)).max(0.0);
    let scale = a.mean.abs().max(b.mean.abs()).max(1e-12);
    let tol = z * (a.sem * a.sem + b.sem * b.sem).sqrt() + rel_floor * scale;
    {
        let mut r = report.lock().unwrap();
        r.cells += 1;
        match pair {
            Pair::AnalyticMc => r.analytic_mc += 1,
            Pair::AnalyticDes => r.analytic_des += 1,
            Pair::McDes => r.mc_des += 1,
            Pair::DesReference => r.des_reference += 1,
            Pair::DesLive => r.des_live += 1,
        }
        let ratio = gap / tol.max(1e-300);
        if ratio > r.worst_gap_over_tol {
            r.worst_gap_over_tol = ratio;
        }
    }
    anyhow::ensure!(
        gap <= tol,
        "conformance cell {} disagrees on E[T]: {:.6} (sem {:.3e}, interval [{:.6}, \
         {:.6}]) vs {:.6} (sem {:.3e}, interval [{:.6}, {:.6}]) — gap {:.6} > tol {:.6} \
         (z = {z}, floor {rel_floor})\n  scenario: {context}",
        pair.name(),
        a.mean,
        a.sem,
        a.lo,
        a.hi,
        b.mean,
        b.sem,
        b.lo,
        b.hi,
        gap,
        tol
    );
    Ok(())
}

/// Run every applicable backend-pair cell of one case. Backends draw
/// from distinct derived seeds, so each leg of a z-test is an
/// independent estimate.
fn check_case(
    case: &GeneratedCase,
    opts: &MatrixOptions,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    let scn = &case.scenario;
    let ctx = describe(case);
    report.lock().unwrap().scenarios += 1;

    // --- DES (fast engine), the one backend every cell shares. ---
    let des_scn = scn.clone().with_seed(scn.seed ^ 0x00DE_5EED);
    let des_ev = DesEvaluator {
        trials: opts.des_trials,
        threads: opts.threads,
        cancellation: true,
        fail_prob: case.fail_prob,
        relaunch_timeout_factor: 3.0,
    };
    let des = des_ev
        .evaluate(&des_scn)
        .map_err(|e| anyhow::anyhow!("des backend refused {ctx}: {e}"))?;
    let des_est = point(&des);

    // --- DES ↔ reference engine: two independent implementations, the
    // only pair that reaches speculative redundancy and failure
    // injection. ---
    let eng_cfg = EngineConfig {
        cancellation: true,
        redundancy: scn.redundancy,
        fail_prob: case.fail_prob,
        relaunch_timeout_factor: 3.0,
    };
    let refr = simulate_many_reference(
        scn,
        &eng_cfg,
        opts.des_trials,
        scn.seed ^ 0x5EED_0000_0001,
    );
    let ref_est = Estimate {
        mean: refr.completion.mean(),
        sem: refr.completion.sem(),
        lo: refr.completion.mean(),
        hi: refr.completion.mean(),
    };
    check_cell(Pair::DesReference, &des_est, &ref_est, opts.z, opts.rel_floor, &ctx, report)?;

    if scn.redundancy == Redundancy::Upfront && case.fail_prob == 0.0 {
        // --- Monte-Carlo ↔ DES: every upfront reliable scenario. ---
        let mc_ev = MonteCarloEvaluator { trials: opts.mc_trials, threads: opts.threads };
        let mc = mc_ev
            .evaluate(scn)
            .map_err(|e| anyhow::anyhow!("montecarlo backend refused {ctx}: {e}"))?;
        let mc_est = point(&mc);
        check_cell(Pair::McDes, &mc_est, &des_est, opts.z, opts.rel_floor, &ctx, report)?;

        // --- Analytic ↔ {MC, DES}: wherever a closed form exists. ---
        if analytic_applies(scn) {
            let an = analytic_estimate(scn)
                .map_err(|e| anyhow::anyhow!("analytic backend refused {ctx}: {e}"))?;
            check_cell(Pair::AnalyticMc, &an, &mc_est, opts.z, opts.rel_floor, &ctx, report)?;
            check_cell(Pair::AnalyticDes, &an, &des_est, opts.z, opts.rel_floor, &ctx, report)?;
            if scn.worker_speeds.is_some() {
                report.lock().unwrap().hetero_analytic_cells += 2;
            }
        }

        // --- DES ↔ Live: the real coordinator with injected time. ---
        if opts.include_live && case.live && live_applies(scn, case.fail_prob) {
            // Normalize wall time per round to a few ms: large enough
            // that injected-delay gaps dominate scheduler noise, small
            // enough that a cell stays well under a second.
            let time_scale = (0.004 / des.mean.max(1e-6)).clamp(0.000_8, 0.02);
            let live_ev = LiveEvaluator {
                rounds: opts.live_rounds,
                time_scale,
                n_samples: 32,
                dim: 4,
                ..LiveEvaluator::default()
            };
            let live_scn = scn.clone().with_seed(scn.seed ^ 0x11FE_5EED);
            let live = live_ev
                .evaluate(&live_scn)
                .map_err(|e| anyhow::anyhow!("live backend refused {ctx}: {e}"))?;
            check_cell(
                Pair::DesLive,
                &des_est,
                &point(&live),
                opts.z,
                opts.live_floor,
                &ctx,
                report,
            )?;
            if matches!(scn.k_of_b, Some(k) if k < scn.assignment.n_batches) {
                report.lock().unwrap().live_k_of_b_cells += 1;
            }
        }
    }
    Ok(())
}

/// Deterministic anchor cases: the corners the acceptance criteria name
/// (heterogeneous-speed analytic cells, live k-of-B, the k = 1 extreme,
/// speculative and failure-injected engine pairs, an overlapping
/// layout, a heavy-tail spec). They run before the random sweep on
/// every invocation, so the required coverage never depends on the
/// random draw.
///
/// The anchors are **enumerated through the study planner**: each
/// corner block is a small [`StudySpec`] grid whose compiled
/// `ExecutionPlan::scenarios` supply the cases, so the conformance
/// matrix and the study layer share one grid vocabulary (axes,
/// canonicalization, derived seeds). Only failure injection stays a
/// per-case knob — it is an engine parameter, not a scenario field.
/// (The old k = B anchor is gone by design: on disjoint layouts the
/// planner canonicalizes `k = B` onto the full-completion cell, and
/// that equivalence is pinned by the evaluator unit tests.)
fn anchor_cases() -> Vec<GeneratedCase> {
    use crate::study::{BatchAxis, KTarget, RedundancyAxis, SpeedAxis, StudySpec};
    let paper =
        |mu: f64, delta: f64| BatchService::paper(ServiceSpec::shifted_exp(mu, delta));
    let grid = |spec: StudySpec| -> Vec<Scenario> {
        spec.compile().expect("anchor grids are valid by construction").scenarios
    };
    let mut cases: Vec<GeneratedCase> = Vec::new();
    let mut push = |scenarios: Vec<Scenario>, fail_prob: f64, live: bool| {
        for scenario in scenarios {
            cases.push(GeneratedCase { scenario, fail_prob, live });
        }
    };

    // Heterogeneous-speed analytic corners: exact Exp cells and bounded
    // SExp cells across two cluster shapes (8 scenarios).
    push(
        grid(StudySpec {
            n_workers: vec![12, 8],
            batches: BatchAxis::Explicit(vec![2, 4]),
            services: vec![BatchService::paper(ServiceSpec::exp(1.3)), paper(1.0, 0.5)],
            speeds: vec![SpeedAxis::Ramp { lo: 0.6, hi: 1.8 }],
            seed: 9001,
            ..StudySpec::base("conformance-anchor-hetero")
        }),
        0.0,
        false,
    );
    // Live corners: k-of-B (round completes at the k-th finished batch)
    // and plain full completion on the same small cluster.
    push(
        grid(StudySpec {
            n_workers: vec![6],
            batches: BatchAxis::Explicit(vec![3]),
            services: vec![paper(2.0, 0.1)],
            k_targets: vec![KTarget::Exact(2), KTarget::Full],
            seed: 9002,
            ..StudySpec::base("conformance-anchor-live")
        }),
        0.0,
        true,
    );
    // Live heterogeneous.
    push(
        grid(StudySpec {
            n_workers: vec![6],
            batches: BatchAxis::Explicit(vec![2]),
            services: vec![paper(2.0, 0.05)],
            speeds: vec![SpeedAxis::Ramp { lo: 0.6, hi: 1.8 }],
            seed: 9003,
            ..StudySpec::base("conformance-anchor-live-hetero")
        }),
        0.0,
        true,
    );
    // k = 1 extreme.
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![BatchService::paper(ServiceSpec::exp(1.0))],
            k_targets: vec![KTarget::Exact(1)],
            seed: 9004,
            ..StudySpec::base("conformance-anchor-k1")
        }),
        0.0,
        false,
    );
    // Speculative redundancy (engine-pair cells only).
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![3]),
            services: vec![paper(1.0, 0.2)],
            redundancy: vec![RedundancyAxis::Speculative(1.5)],
            seed: 9005,
            ..StudySpec::base("conformance-anchor-speculative")
        }),
        0.0,
        false,
    );
    // Failure injection: same grid shape, the fail knob rides per case.
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![3]),
            services: vec![paper(1.0, 0.2)],
            seed: 9006,
            ..StudySpec::base("conformance-anchor-fail")
        }),
        0.3,
        false,
    );
    // Overlapping layout (MC↔DES + engine pair only).
    push(
        grid(StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![4]),
            policies: vec![ReplicationPolicy::OverlappingCyclic],
            services: vec![paper(1.0, 0.2)],
            seed: 9007,
            ..StudySpec::base("conformance-anchor-overlapping")
        }),
        0.0,
        false,
    );
    // Heavy-tail spec outside the closed forms' scope.
    push(
        grid(StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![BatchService::paper(ServiceSpec::pareto(0.8, 3.5))],
            seed: 9008,
            ..StudySpec::base("conformance-anchor-pareto")
        }),
        0.0,
        false,
    );
    cases
}

/// Run the full conformance matrix: the deterministic anchors first,
/// then `opts.scenarios` generated scenarios through every applicable
/// backend pair. Returns the tally on success; on any disagreement the
/// error carries the shrunk minimal case and its replay seed.
pub fn run_matrix(opts: &MatrixOptions) -> anyhow::Result<MatrixReport> {
    let report = Mutex::new(MatrixReport::default());
    for case in anchor_cases() {
        check_case(&case, opts, &report).map_err(|e| {
            anyhow::anyhow!(
                "conformance anchor failed (anchors are deterministic; rerun \
                 `batchrep conformance` with the same trial counts to reproduce):\n{e:#}"
            )
        })?;
    }
    // After the first failure every further property call comes from
    // the shrinker's candidate replays; run those at a reduced budget
    // so minimization costs seconds rather than re-paying the full
    // matrix per candidate. Standard errors grow only ~√8, so a
    // systematic disagreement still fails and shrinks; the printed
    // replay seed reproduces at full budget. Live cells are dropped
    // from the replays *unless the failing cell was itself a live
    // pair* — otherwise DES↔Live failures could never reproduce while
    // shrinking (they keep reduced rounds instead).
    const NOT_FAILED: u8 = 0;
    const FAILED: u8 = 1;
    const FAILED_LIVE: u8 = 2;
    let state = std::sync::atomic::AtomicU8::new(NOT_FAILED);
    let shrink_base = MatrixOptions {
        mc_trials: (opts.mc_trials / 8).max(1_000),
        des_trials: (opts.des_trials / 8).max(500),
        ..opts.clone()
    };
    let shrink_nolive = MatrixOptions { include_live: false, ..shrink_base.clone() };
    let shrink_live =
        MatrixOptions { live_rounds: (opts.live_rounds / 2).max(20), ..shrink_base };
    let sweep = catch_unwind(AssertUnwindSafe(|| {
        testkit::check_with("conformance-matrix", opts.scenarios, opts.seed, |g| {
            let case = gen_case(g);
            let o = match state.load(std::sync::atomic::Ordering::Relaxed) {
                FAILED => &shrink_nolive,
                FAILED_LIVE => &shrink_live,
                _ => opts,
            };
            if let Err(e) = check_case(&case, o, &report) {
                let text = format!("{e:#}");
                let mode = if text.contains(Pair::DesLive.name()) { FAILED_LIVE } else { FAILED };
                state.store(mode, std::sync::atomic::Ordering::Relaxed);
                panic!("{text}");
            }
        })
    }));
    if let Err(payload) = sweep {
        anyhow::bail!("conformance matrix failed:\n{}", testkit::payload_msg(&*payload));
    }
    Ok(report.into_inner().expect("no checker panicked while holding the report lock"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_generated_cases_are_valid_scenarios() {
        testkit::check("conformance-gen-valid", 200, |g| {
            let case = gen_case(g);
            let scn = &case.scenario;
            scn.layout.validate().unwrap();
            scn.assignment.validate().unwrap();
            assert_eq!(scn.layout.n_batches(), scn.assignment.n_batches);
            if let Some(k) = scn.k_of_b {
                assert!(k >= 1 && k <= scn.assignment.n_batches);
            }
            if let Some(speeds) = &scn.worker_speeds {
                assert_eq!(speeds.len(), scn.n_workers());
                assert!(speeds.iter().all(|&c| c > 0.0));
            }
            assert!((0.0..=0.4).contains(&case.fail_prob));
        });
    }

    #[test]
    fn prop_applicability_matches_the_evaluator() {
        // The matrix's applicability predicate and the evaluator's own
        // acceptance logic must be the same function, or cells silently
        // vanish (predicate too narrow) or spuriously error (too wide).
        testkit::check("conformance-analytic-scope", 120, |g| {
            let case = gen_case(g);
            let accepted = AnalyticEvaluator.evaluate(&case.scenario).is_ok();
            assert_eq!(
                analytic_applies(&case.scenario),
                accepted,
                "predicate disagrees with evaluator on {}",
                describe(&case)
            );
        });
    }

    #[test]
    fn anchors_cover_the_required_corners() {
        // The StudySpec-enumerated anchor grids must still reach every
        // corner the acceptance criteria name, independent of the
        // random sweep.
        let anchors = anchor_cases();
        let hetero = anchors
            .iter()
            .filter(|c| c.scenario.worker_speeds.is_some() && !c.live)
            .count();
        assert!(hetero >= 4, "hetero anchors: {hetero}");
        assert!(
            anchors.iter().any(|c| {
                let b = c.scenario.assignment.n_batches;
                c.live && matches!(c.scenario.k_of_b, Some(k) if k < b)
            }),
            "live k-of-B anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.live && c.scenario.worker_speeds.is_some()),
            "live hetero anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.scenario.k_of_b == Some(1)),
            "k = 1 anchor missing"
        );
        assert!(
            anchors
                .iter()
                .any(|c| matches!(c.scenario.redundancy, Redundancy::Speculative { .. })),
            "speculative anchor missing"
        );
        assert!(anchors.iter().any(|c| c.fail_prob > 0.0), "fail-injected anchor missing");
        assert!(
            anchors.iter().any(|c| c.scenario.layout.is_overlapping),
            "overlapping anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.scenario.service.spec.exp_family().is_none()),
            "heavy-tail anchor missing"
        );
        // Every anchor is a valid scenario with a planner-derived seed.
        for c in &anchors {
            c.scenario.layout.validate().unwrap();
            c.scenario.assignment.validate().unwrap();
        }
    }

    #[test]
    fn long_mode_extends_the_full_sweep() {
        let fast = MatrixOptions::fast();
        let full = MatrixOptions::full();
        let long = MatrixOptions::long();
        assert!(long.scenarios > full.scenarios && full.scenarios > fast.scenarios);
        assert!(long.mc_trials >= full.mc_trials && long.des_trials >= full.des_trials);
        assert!(long.include_live, "soak mode keeps the live cells");
    }

    #[test]
    fn cell_interval_logic() {
        let report = Mutex::new(MatrixReport::default());
        let exact = Estimate { mean: 1.0, sem: 0.0, lo: 1.0, hi: 1.0 };
        let close = Estimate { mean: 1.01, sem: 0.004, lo: 1.01, hi: 1.01 };
        check_cell(Pair::AnalyticMc, &exact, &close, 5.0, 0.004, "t", &report).unwrap();
        // Far beyond 5σ + floor: must fail.
        let far = Estimate { mean: 1.2, sem: 0.004, lo: 1.2, hi: 1.2 };
        assert!(check_cell(Pair::AnalyticMc, &exact, &far, 5.0, 0.004, "t", &report).is_err());
        // An interval that contains the point passes with zero gap even
        // at sem = 0.
        let bound = Estimate { mean: 1.1, sem: 0.0, lo: 0.9, hi: 1.3 };
        check_cell(Pair::AnalyticDes, &bound, &exact, 5.0, 0.0, "t", &report).unwrap();
        let r = report.lock().unwrap();
        assert_eq!(r.cells, 3);
        assert_eq!(r.analytic_mc, 2);
        assert!(r.worst_gap_over_tol > 1.0, "the failing cell must dominate the ratio");
    }

    #[test]
    fn small_matrix_passes_and_counts_required_cells() {
        // A scaled-down sweep (no live cells — those are exercised by
        // the integration tests and the CLI gate): every applicable
        // pair must appear and agree.
        let opts = MatrixOptions {
            scenarios: 15,
            mc_trials: 6_000,
            des_trials: 3_000,
            live_rounds: 1,
            threads: 2,
            include_live: false,
            seed: Some(7),
            z: 5.5,
            rel_floor: 0.01,
            live_floor: 0.2,
        };
        let report = run_matrix(&opts).unwrap();
        assert_eq!(
            report.scenarios,
            15 + anchor_cases().len() as u64,
            "15 random + the StudySpec-enumerated anchors"
        );
        assert!(report.des_reference >= report.scenarios, "engine pair runs everywhere");
        assert!(report.analytic_mc >= 3, "{report:?}");
        assert!(report.analytic_des >= 3, "{report:?}");
        assert!(report.mc_des >= 8, "{report:?}");
        assert!(report.hetero_analytic_cells >= 4, "{report:?}");
        assert_eq!(report.des_live, 0, "live disabled");
        assert!(report.worst_gap_over_tol <= 1.0, "{report:?}");
        assert!(
            report.cells
                >= report.analytic_mc
                    + report.analytic_des
                    + report.mc_des
                    + report.des_reference
        );
    }
}
