//! Scenario-space conformance harness: pin every evaluation backend
//! against every other over **generated** scenarios.
//!
//! The paper's claims are only as trustworthy as the agreement between
//! the closed forms, the two independent simulators, and the live
//! runtime — and straggler-mitigation results are notoriously sensitive
//! to which corner of scenario space is evaluated. This module sweeps a
//! [`testkit`]-driven random scenario generator (policy × redundancy
//! mode × k-of-B × worker speeds × failure injection × service spec,
//! all drawn from valid ranges, shrunk on failure) through a
//! [`cross_check_matrix`](run_matrix) of every applicable backend pair:
//!
//! * **Analytic ↔ Monte-Carlo** — upfront, no failures, disjoint,
//!   exp-family (including heterogeneous speeds: exact for Exp,
//!   bounded for SExp);
//! * **Analytic ↔ DES** — same scope as Analytic ↔ MC;
//! * **Monte-Carlo ↔ DES** — every upfront reliable scenario (any
//!   service spec, any layout, k-of-B);
//! * **DES ↔ DES-reference** — *every* scenario: the flat+block engine
//!   vs the retained heap+scalar engine on an independent substream —
//!   the only pair that covers speculative redundancy and failure
//!   injection;
//! * **DES ↔ Live** — small clusters, upfront, no failures, exp-family:
//!   the real coordinator with injected time, k-of-B included;
//! * **Live-crash ↔ Analytic** — a worker thread is crashed *mid-round*
//!   (not just a replica coin flip): its thread exits, the survivors
//!   must still complete every round, and their post-crash completion
//!   must match [`analysis::assignment_stats`] on the reduced
//!   (one-replica-poorer) assignment;
//! * **Live ↔ DES corruption** — the same silent-corruption
//!   [`crate::fault::FaultPlan`] drives the live coordinator's m-of-g
//!   vote and the corruption-aware DES fault model over the same round
//!   horizon (quarantine disarmed on both sides so the completion law
//!   is stationary), and the two mean verified completions must agree.
//!
//! Scenarios carrying [`Scenario::verify_m`] flow through the
//! analytic ↔ MC/DES and engine-pair cells like any other: the verified
//! m-of-g closed form meets both simulators wherever its scope allows.
//!
//! Tolerances are **statistically sound**: each cell compares two mean
//! estimates through an interval test — `|gap| ≤ z·√(sem_a² + sem_b²) +
//! floor·scale` where the analytic leg contributes a zero-width point
//! (exact) or its provable bound interval (heterogeneous SExp), and the
//! floor is a small relative guard for rounding/CLT-tail effects, not a
//! hand-tuned epsilon. Live cells carry a wider floor for wall-clock
//! scheduling noise.
//!
//! Every failure panics through [`testkit::check_with`], so it is
//! reported at its **shrunk minimal case** together with a
//! `BATCHREP_PROP_SEED` replay seed that reproduces it deterministically
//! (backend results are bit-reproducible per seed for *any* thread
//! count — the logical-shard plan guarantees it). The shrunk case is
//! also **appended to the adversarial corpus**
//! (`conformance/corpus.json` by default, [`MatrixOptions::corpus`]):
//! corpus cases replay *before* the anchors and the random sweep on
//! every run, so each bug the generator ever found becomes a permanent
//! regression gate. Run it as `batchrep conformance [--fast|--long]`;
//! `ci.sh` runs the fast mode as a merge gate, and `--long` is the
//! off-by-default soak sweep ([`MatrixOptions::long`]) for releases and
//! backend rewrites.
//!
//! The deterministic anchor corners are **enumerated through the study
//! planner** ([`crate::study::StudySpec`] grids compiled to scenario
//! lists), so the matrix and the planner share one grid vocabulary —
//! axes, canonicalization, and derived seeds.

use crate::analysis;
use crate::assignment::Assignment;
use crate::config::SystemConfig;
use crate::coordinator::{Backend, Coordinator};
use crate::des::engine::{simulate_many_reference, EngineConfig, Redundancy};
use crate::des::Scenario;
use crate::dist::{BatchModel, BatchService, ServiceSpec};
use crate::evaluator::{
    AnalyticEvaluator, CompletionStats, DesEvaluator, Evaluator, LiveEvaluator,
    MonteCarloEvaluator, ReplicationPolicy,
};
use crate::testkit::{self, Gen};
use crate::util::json::Json;
use crate::util::stats::Welford;
use crate::worker::JobSpec;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Knobs of one conformance-matrix run.
#[derive(Debug, Clone)]
pub struct MatrixOptions {
    /// Randomly generated scenarios to sweep (anchor scenarios run in
    /// addition to these).
    pub scenarios: u64,
    /// Monte-Carlo trials per cell.
    pub mc_trials: u64,
    /// DES trials per cell (fast engine and reference each).
    pub des_trials: u64,
    /// Live rounds per DES↔Live cell.
    pub live_rounds: u64,
    /// Evaluator worker threads — wall-clock only; results are
    /// identical for every thread count.
    pub threads: usize,
    /// Run the DES↔Live cells (real coordinator + worker threads).
    pub include_live: bool,
    /// Base seed override for the random sweep (`None` = the stable
    /// name-hash / `BATCHREP_PROP_SEED` default).
    pub seed: Option<u64>,
    /// z-multiplier of the combined standard error.
    pub z: f64,
    /// Relative tolerance floor of the simulation cells (rounding and
    /// CLT-tail guard).
    pub rel_floor: f64,
    /// Relative tolerance floor of the live cells (wall-clock
    /// scheduling noise rides on top of sampling error).
    pub live_floor: f64,
    /// Adversarial-corpus file: cases recorded from past failures are
    /// replayed before everything else, and a newly failing generated
    /// case is appended (shrunk) on the way out. `None` disables corpus
    /// I/O entirely (hermetic runs, unit tests).
    pub corpus: Option<PathBuf>,
}

impl MatrixOptions {
    /// The CI gate: ~200 scenarios at smoke-quality trial counts.
    pub fn fast() -> Self {
        Self {
            scenarios: 200,
            mc_trials: 24_000,
            des_trials: 12_000,
            live_rounds: 48,
            threads: crate::evaluator::auto_threads().min(8),
            include_live: true,
            seed: None,
            z: 5.0,
            rel_floor: 0.004,
            live_floor: 0.12,
            corpus: None,
        }
    }

    /// The thorough sweep: more scenarios, tighter standard errors.
    pub fn full() -> Self {
        Self {
            scenarios: 600,
            mc_trials: 120_000,
            des_trials: 50_000,
            live_rounds: 90,
            ..Self::fast()
        }
    }

    /// The soak sweep (`batchrep conformance --long`, off by default):
    /// a much larger scenario count at full-precision trial budgets.
    /// Expect minutes to hours of wall clock — run it before releases
    /// or after backend rewrites, not in CI. Failures replay exactly
    /// like the other modes: rerun `batchrep conformance --long` with
    /// the printed `BATCHREP_PROP_SEED` environment variable (or
    /// `--seed`) and the same trial counts.
    pub fn long() -> Self {
        Self {
            scenarios: 2_000,
            mc_trials: 240_000,
            des_trials: 100_000,
            live_rounds: 120,
            ..Self::fast()
        }
    }
}

/// Tally of a completed matrix run (what `batchrep conformance`
/// prints). Counters are advisory; any disagreement aborts the run
/// before the report is returned.
#[derive(Debug, Clone, Default)]
pub struct MatrixReport {
    /// Scenarios swept (anchors + random).
    pub scenarios: u64,
    /// Total backend-pair cells checked.
    pub cells: u64,
    /// Analytic ↔ Monte-Carlo cells.
    pub analytic_mc: u64,
    /// Analytic ↔ DES cells.
    pub analytic_des: u64,
    /// Monte-Carlo ↔ DES cells.
    pub mc_des: u64,
    /// Fast-engine ↔ reference-engine cells.
    pub des_reference: u64,
    /// DES ↔ Live cells.
    pub des_live: u64,
    /// Live-crash ↔ Analytic cells.
    pub live_crash: u64,
    /// Live ↔ DES fault-plan cells (shared `FaultPlan` on both sides).
    pub live_des_fault: u64,
    /// Live ↔ DES corruption cells (shared silent-corruption plan,
    /// m-of-g voting on both sides).
    pub live_des_corrupt: u64,
    /// Cells whose analytic leg used heterogeneous `worker_speeds`.
    pub hetero_analytic_cells: u64,
    /// Analytic ↔ MC/DES cells whose scenario carried `verify_m` (the
    /// m-of-g verified closed form against simulation).
    pub verify_m_analytic_cells: u64,
    /// DES ↔ Live cells with a `k_of_b` target below `B`.
    pub live_k_of_b_cells: u64,
    /// Corpus cases replayed before the anchors and the random sweep.
    pub corpus_replayed: u64,
    /// Largest observed `gap / tolerance` over all cells (1.0 = the
    /// tightest cell sat exactly on its bound).
    pub worst_gap_over_tol: f64,
}

/// Which backend pair a cell compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Pair {
    AnalyticMc,
    AnalyticDes,
    McDes,
    DesReference,
    DesLive,
    LiveCrash,
    LiveDesFault,
    LiveDesCorrupt,
}

impl Pair {
    fn name(self) -> &'static str {
        match self {
            Pair::AnalyticMc => "analytic<->montecarlo",
            Pair::AnalyticDes => "analytic<->des",
            Pair::McDes => "montecarlo<->des",
            Pair::DesReference => "des<->des-reference",
            Pair::DesLive => "des<->live",
            Pair::LiveCrash => "live-crash<->analytic",
            Pair::LiveDesFault => "live<->des-fault",
            Pair::LiveDesCorrupt => "live<->des-corrupt",
        }
    }
}

/// One backend's mean estimate: a point with a standard error, or an
/// interval (the heterogeneous-SExp analytic bound) with `sem = 0`.
#[derive(Debug, Clone, Copy)]
struct Estimate {
    mean: f64,
    sem: f64,
    lo: f64,
    hi: f64,
}

fn point(st: &CompletionStats) -> Estimate {
    Estimate { mean: st.mean, sem: st.sem, lo: st.mean, hi: st.mean }
}

/// One generated conformance case: the scenario plus the engine-level
/// knobs that are not scenario fields (failure injection) and the
/// generator's decision to pay for a live cell.
#[derive(Debug, Clone)]
pub struct GeneratedCase {
    /// The fully self-describing scenario every backend consumes.
    pub scenario: Scenario,
    /// Per-replica crash probability of the DES cells (0 = reliable).
    pub fail_prob: f64,
    /// Whether this case also runs a DES↔Live cell (live cells cost
    /// real wall-clock, so only a small fraction of cases draw one).
    pub live: bool,
    /// Whether this case also runs a live-crash cell: a worker thread
    /// is killed mid-round and the survivors' completion is checked
    /// against the reduced-assignment closed form.
    pub crash: bool,
    /// Whether this case also runs a live↔DES fault-plan cell: the same
    /// [`crate::fault::FaultPlan`] (transient crash + Markov slowdown)
    /// drives the live self-healing pipeline and the DES fault model,
    /// and their mean completions must agree.
    pub fault: bool,
    /// Whether this case also runs a live↔DES corruption cell: the same
    /// silent-corruption [`crate::fault::FaultPlan`] drives the live
    /// m-of-g vote and the corruption-aware DES fault model, and their
    /// mean verified completions must agree.
    pub corrupt: bool,
}

/// Draw one valid scenario from the full cross-product the backends
/// claim to support. Integer draws shrink toward the smallest cluster,
/// so a failing case is reported at (close to) its minimal shape.
pub fn gen_case(g: &mut Gen) -> GeneratedCase {
    let n = *g.pick(&[4usize, 6, 8, 12, 16, 24]);
    let divisors: Vec<usize> = (1..=n).filter(|b| n % b == 0).collect();
    let b = *g.pick(&divisors);
    let policy = *g.pick(ReplicationPolicy::all());
    let kind = g.usize_in(0, 9);
    let mu = g.f64_in(0.6, 2.0);
    let spec = match kind {
        0..=3 => ServiceSpec::exp(mu),
        4..=7 => ServiceSpec::shifted_exp(mu, g.f64_in(0.0, 0.8)),
        // Heavy-tail ablations keep α comfortably above 3 so the means
        // and standard errors the z-cells rely on are well-behaved.
        8 => ServiceSpec::pareto(g.f64_in(0.4, 1.0), g.f64_in(3.2, 4.5)),
        _ => ServiceSpec::weibull(g.f64_in(0.7, 1.5), g.f64_in(0.5, 1.5)),
    };
    let seed = g.u64_in(0, 1 << 40);
    let mut scn = Scenario::from_policy(policy, n, b, BatchService::paper(spec), seed)
        // lint:allow(D4): the generator draws B from the divisors of N, satisfying the constructor contract
        .expect("generated (policy, N, B | N) combinations are valid by construction");
    if g.coin(0.22) {
        scn = scn
            .with_redundancy(Redundancy::Speculative { deadline_factor: g.f64_in(0.8, 2.2) });
    }
    // Policies can change the effective batch count (FullDiversity → 1,
    // OverlappingCyclic → one window per worker), so k draws against
    // the scenario's own B.
    let eff_b = scn.assignment.n_batches;
    if g.coin(0.35) {
        let k = g.usize_in(1, eff_b);
        // lint:allow(D4): k is drawn from [1, eff_b], the exact with_k_of_b contract
        scn = scn.with_k_of_b(k).expect("1 <= k <= B by construction");
    }
    if g.coin(0.35) {
        let speeds: Vec<f64> = (0..n).map(|_| g.f64_in(0.5, 2.0)).collect();
        // lint:allow(D4): the generator draws one positive speed per worker, the with_speeds contract
        scn = scn.with_speeds(speeds).expect("one positive speed per worker");
    }
    let fail_prob = if g.coin(0.2) { g.f64_in(0.05, 0.4) } else { 0.0 };
    // m-of-g verification: only where every batch can seat m votes and
    // the DES evaluator accepts the combination (upfront, reliable).
    // The live-side cells stay off for verified cases — the live↔DES
    // integrity comparison has its own dedicated corruption cell.
    let min_degree = (0..scn.assignment.n_batches)
        .map(|b| scn.assignment.replication(b))
        .min()
        .unwrap_or(0);
    let mut verified = false;
    if g.coin(0.3)
        && fail_prob == 0.0
        && scn.redundancy == Redundancy::Upfront
        && min_degree >= 2
    {
        let m = g.usize_in(2, min_degree);
        // lint:allow(D4): m is drawn from [2, min_degree], the with_verify_m contract
        scn = scn.with_verify_m(m).expect("2 <= m <= min replication degree by construction");
        verified = true;
    }
    let live = g.coin(0.05) && !verified;
    let crash = g.coin(0.04) && !verified;
    let fault = g.coin(0.04) && !verified;
    let corrupt = g.coin(0.04) && !verified;
    GeneratedCase { scenario: scn, fail_prob, live, crash, fault, corrupt }
}

/// Human-readable cell context (embedded in every failure message so a
/// disagreement identifies its scenario without replaying).
pub fn describe(case: &GeneratedCase) -> String {
    let scn = &case.scenario;
    let speeds = scn
        .worker_speeds
        .as_ref()
        .map(|s| format!("{s:.2?}"))
        .unwrap_or_else(|| "homogeneous".into());
    format!(
        "N={} B={} policy={} service={} redundancy={:?} k_of_b={:?} speeds={speeds} \
         verify_m={:?} fail_prob={:.3} crash={} fault={} corrupt={} seed={}",
        scn.n_workers(),
        scn.assignment.n_batches,
        scn.policy.name(),
        scn.service.spec.name(),
        scn.redundancy,
        scn.k_of_b,
        scn.verify_m,
        case.fail_prob,
        case.crash,
        case.fault,
        case.corrupt,
        scn.seed,
    )
}

/// Serialize a case for the adversarial corpus (inverse of
/// [`case_from_json`]). Everything a replay needs is captured: the
/// policy/shape/service/seed quadruple rebuilds the scenario
/// bit-identically, and the optional knobs ride alongside.
pub fn case_to_json(case: &GeneratedCase) -> Json {
    let scn = &case.scenario;
    // Record the *constructor's* B, not the effective batch count: an
    // overlapping-cyclic build always ends with `n_batches = N` (one
    // window per worker), and the original B survives only in the
    // window size — `from_policy(.., N, b_ctor, ..)` then rebuilds the
    // identical layout.
    let b_ctor = if scn.layout.is_overlapping {
        scn.n_workers() / scn.layout.batch_units()
    } else {
        scn.assignment.n_batches
    };
    let mut pairs: Vec<(&str, Json)> = vec![
        ("n", Json::from(scn.n_workers())),
        ("b", Json::from(b_ctor)),
        ("policy", Json::from(scn.policy.name())),
        ("service", Json::from(scn.service.spec.name())),
        ("model", Json::from(scn.service.model.name())),
        ("seed", Json::from(scn.seed as i64)),
        ("fail_prob", Json::from(case.fail_prob)),
        ("live", Json::from(case.live)),
        ("crash", Json::from(case.crash)),
        ("fault", Json::from(case.fault)),
        ("corrupt", Json::from(case.corrupt)),
    ];
    if let Redundancy::Speculative { deadline_factor } = scn.redundancy {
        pairs.push(("speculative", Json::from(deadline_factor)));
    }
    if let Some(k) = scn.k_of_b {
        pairs.push(("k_of_b", Json::from(k)));
    }
    if let Some(speeds) = &scn.worker_speeds {
        pairs.push(("speeds", Json::Array(speeds.iter().map(|&s| Json::from(s)).collect())));
    }
    if let Some(m) = scn.verify_m {
        pairs.push(("verify_m", Json::from(m)));
    }
    Json::obj(pairs)
}

/// Rebuild a corpus case from its JSON form.
pub fn case_from_json(v: &Json) -> anyhow::Result<GeneratedCase> {
    let field = |k: &str| {
        v.get(k).ok_or_else(|| anyhow::anyhow!("corpus case is missing field '{k}'"))
    };
    let int = |k: &str| -> anyhow::Result<i64> {
        field(k)?.as_i64().ok_or_else(|| anyhow::anyhow!("corpus field '{k}' is not an integer"))
    };
    let text = |k: &str| -> anyhow::Result<String> {
        Ok(field(k)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("corpus field '{k}' is not a string"))?
            .to_string())
    };
    let n = int("n")? as usize;
    let b = int("b")? as usize;
    let policy = ReplicationPolicy::parse(&text("policy")?)?;
    let spec = ServiceSpec::parse(&text("service")?)?;
    let model = match v.get("model") {
        Some(m) => BatchModel::parse(
            m.as_str().ok_or_else(|| anyhow::anyhow!("corpus field 'model' is not a string"))?,
        )?,
        None => BatchModel::SizeScaled,
    };
    let seed = int("seed")? as u64;
    let mut scn = Scenario::from_policy(policy, n, b, BatchService { spec, model }, seed)?;
    if let Some(df) = v.get("speculative").and_then(Json::as_f64) {
        scn = scn.with_redundancy(Redundancy::Speculative { deadline_factor: df });
    }
    if let Some(k) = v.get("k_of_b").and_then(Json::as_i64) {
        scn = scn.with_k_of_b(k as usize)?;
    }
    if let Some(arr) = v.get("speeds").and_then(Json::as_array) {
        let speeds = arr
            .iter()
            .map(|s| s.as_f64().ok_or_else(|| anyhow::anyhow!("corpus speed is not a number")))
            .collect::<anyhow::Result<Vec<f64>>>()?;
        scn = scn.with_speeds(speeds)?;
    }
    if let Some(m) = v.get("verify_m").and_then(Json::as_i64) {
        scn = scn.with_verify_m(m as usize)?;
    }
    let fail_prob = v.get("fail_prob").and_then(Json::as_f64).unwrap_or(0.0);
    let live = v.get("live").and_then(Json::as_bool).unwrap_or(false);
    let crash = v.get("crash").and_then(Json::as_bool).unwrap_or(false);
    let fault = v.get("fault").and_then(Json::as_bool).unwrap_or(false);
    let corrupt = v.get("corrupt").and_then(Json::as_bool).unwrap_or(false);
    Ok(GeneratedCase { scenario: scn, fail_prob, live, crash, fault, corrupt })
}

/// The default adversarial-corpus location: `$BATCHREP_CORPUS`, else
/// `conformance/corpus.json` found by walking up from the working
/// directory (the repo checkout), else a fresh `conformance/corpus.json`
/// relative to the working directory.
pub fn default_corpus_path() -> PathBuf {
    if let Ok(p) = std::env::var("BATCHREP_CORPUS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("conformance").join("corpus.json");
        if cand.exists() {
            return cand;
        }
        if !dir.pop() {
            return Path::new("conformance").join("corpus.json");
        }
    }
}

/// Load every case in a corpus file (missing file = empty corpus; a
/// malformed file is an error — silently skipping recorded regressions
/// would defeat the point).
pub fn load_corpus(path: &Path) -> anyhow::Result<Vec<GeneratedCase>> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let body = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read corpus {}: {e}", path.display()))?;
    let v = Json::parse(&body)
        .map_err(|e| anyhow::anyhow!("corpus {} is not valid JSON: {e:?}", path.display()))?;
    let arr = v
        .as_array()
        .ok_or_else(|| anyhow::anyhow!("corpus {} must be a JSON array", path.display()))?;
    arr.iter()
        .map(case_from_json)
        .collect::<anyhow::Result<Vec<_>>>()
        .map_err(|e| anyhow::anyhow!("corpus {}: {e}", path.display()))
}

/// Append a case to the corpus (creating the file if needed), deduped
/// by serialized form.
pub fn append_to_corpus(path: &Path, case: &GeneratedCase) -> anyhow::Result<()> {
    let mut entries: Vec<Json> = if path.exists() {
        let body = std::fs::read_to_string(path)?;
        match Json::parse(&body) {
            Ok(Json::Array(items)) => items,
            _ => anyhow::bail!("corpus {} is not a JSON array", path.display()),
        }
    } else {
        Vec::new()
    };
    let new = case_to_json(case);
    let key = new.to_string();
    if entries.iter().any(|e| e.to_string() == key) {
        return Ok(());
    }
    entries.push(new);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, format!("{}\n", Json::Array(entries)))
        .map_err(|e| anyhow::anyhow!("cannot write corpus {}: {e}", path.display()))?;
    Ok(())
}

/// Does the analytic backend cover this scenario? (Mirror of
/// `AnalyticEvaluator`'s acceptance rules — kept in sync by
/// `prop_applicability_matches_the_evaluator`.)
fn analytic_applies(scn: &Scenario) -> bool {
    if scn.layout.is_overlapping || scn.redundancy != Redundancy::Upfront {
        return false;
    }
    if scn.service.spec.exp_family().is_none() {
        return false;
    }
    let b = scn.assignment.n_batches;
    if scn.verify_m.is_some() {
        // The m-of-g verified closed form: homogeneous balanced
        // disjoint with the paper normalization U = N and exact f64
        // binomials (N <= 32); a k-of-B target composes freely.
        return scn.worker_speeds.is_none()
            && scn.assignment.is_balanced()
            && scn.layout.n_units == scn.assignment.n_workers
            && scn.n_workers() <= 32;
    }
    if scn.worker_speeds.is_some() {
        // Exact (Exp) or bounded (SExp) — full completion only.
        !matches!(scn.k_of_b, Some(k) if k < b) && b <= 20
    } else if matches!(scn.k_of_b, Some(k) if k < b) {
        scn.assignment.is_balanced() && scn.layout.n_units == scn.assignment.n_workers
    } else {
        scn.assignment.is_balanced() || b <= 20
    }
}

/// Does a live cell make sense here? Small clusters only (one OS thread
/// per worker), upfront, reliable, exp-family (bounded injected sleeps).
fn live_applies(scn: &Scenario, fail_prob: f64) -> bool {
    scn.redundancy == Redundancy::Upfront
        && fail_prob == 0.0
        && !scn.layout.is_overlapping
        && scn.service.spec.exp_family().is_some()
        && scn.n_workers() <= 8
}

/// Does a live-crash cell make sense here? Live constraints, plus:
/// every batch must survive losing one replica (balanced, g ≥ 2), the
/// reduced-assignment closed form needs full completion, homogeneous
/// speeds, and equal-size batches (`B | U`).
fn crash_applies(scn: &Scenario, fail_prob: f64) -> bool {
    live_applies(scn, fail_prob)
        && scn.worker_speeds.is_none()
        && scn.k_of_b.is_none()
        && scn.assignment.is_balanced()
        && scn.assignment.n_batches >= 1
        && scn.assignment.replication(0) >= 2
        && scn.layout.n_units % scn.assignment.n_batches == 0
}

/// Does a live↔DES fault-plan cell make sense here? The crash-cell
/// constraints (the plan's transient crash must leave every batch
/// covered, so g ≥ 2), plus the fault-round DES model's own scope:
/// `U = N` units over a balanced disjoint layout, homogeneous speeds,
/// full completion.
fn fault_applies(scn: &Scenario, fail_prob: f64) -> bool {
    crash_applies(scn, fail_prob)
        && scn.n_workers() >= 2
        && scn.layout.n_units == scn.n_workers()
}

/// Does a live↔DES corruption cell make sense here? The fault-cell
/// scope (balanced disjoint, U = N, homogeneous, full completion,
/// small cluster), plus: replication degree ≥ 3 — so after worker 0's
/// corrupt replica is out-voted every batch still seats two honest
/// agreeing votes — and no generator-set `verify_m` (the cell installs
/// its own m = 2 on both sides).
fn corrupt_applies(scn: &Scenario, fail_prob: f64) -> bool {
    fault_applies(scn, fail_prob)
        && scn.verify_m.is_none()
        && scn.assignment.replication(0) >= 3
}

/// The live↔DES fault-plan cell: one shared [`FaultPlan`] — a transient
/// crash with backoff respawn on worker 0 and a Markov-modulated
/// slowdown on worker 1 — drives both the live coordinator's
/// self-healing pipeline and the DES fault model
/// ([`crate::des::engine::simulate_fault_rounds`]) over the same round
/// horizon. The per-round fault schedule (who is dead, how slow, when
/// respawned) is plan-deterministic and identical on both sides; only
/// the service draws differ, so the two mean completions over the
/// horizon estimate the same mixture and must agree within the live
/// z-bound.
fn check_fault_cell(
    case: &GeneratedCase,
    opts: &MatrixOptions,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    use crate::fault::{FaultEvent, FaultPlan};
    let scn = &case.scenario;
    let ctx = describe(case);
    let rounds = opts.live_rounds.max(12);
    let plan = FaultPlan {
        name: "conformance".into(),
        seed: scn.seed ^ 0xFA17_0001,
        events: vec![
            (0, FaultEvent::TransientCrash { round: 2, fraction: 0.5, respawn_after: 2 }),
            (
                1,
                FaultEvent::Slowdown {
                    from_round: 1,
                    rounds: 8,
                    params: crate::trace::MarkovTraceParams::default(),
                },
            ),
        ],
    };

    // DES leg: replicates of the identical fault-round schedule. Every
    // (replicate, round) completion is one draw from the same
    // round-mixture the live leg samples once per round.
    let compiled = plan.compile(scn.n_workers())?;
    let eng_cfg = EngineConfig::default();
    let trials = (opts.des_trials / rounds.max(1)).clamp(40, 400);
    let mut des = Welford::new();
    let mut rng = crate::util::rng::Rng::new(scn.seed ^ 0x00DE_5EED ^ 0xFA17);
    for _ in 0..trials {
        let stats =
            crate::des::engine::simulate_fault_rounds(scn, &compiled, rounds, &eng_cfg, &mut rng)?;
        for st in stats {
            des.push(st.completion);
        }
    }
    let des_est = Estimate { mean: des.mean(), sem: des.sem(), lo: des.mean(), hi: des.mean() };

    // Live leg: the real coordinator with the plan installed.
    let time_scale = (0.004 / des.mean().max(1e-6)).clamp(0.000_8, 0.02);
    let cfg = SystemConfig {
        time_scale,
        n_samples: 32.max(scn.n_workers()),
        dim: 4,
        cancellation: true,
        ..SystemConfig::default()
    };
    let scn_live = scn.clone().with_seed(scn.seed ^ 0x11FE_5EED ^ 0xFA17);
    let mut coord = Coordinator::from_scenario(&scn_live, cfg, Backend::Mock)?;
    coord.install_fault_plan(&plan)?;
    let w = Arc::new(vec![0.0f32; 4]);
    let mut run = || -> anyhow::Result<Welford> {
        for _ in 0..rounds {
            coord.run_round(JobSpec::Grad { w: w.clone() })?;
        }
        let totals = coord.metrics.fault_totals();
        anyhow::ensure!(
            totals.crashes >= 1 && totals.respawns >= 1,
            "the fault plan did not fire on the live side (totals {totals:?})"
        );
        anyhow::ensure!(
            coord.live_workers() == scn.n_workers(),
            "the transient crash never healed: {}/{} workers live",
            coord.live_workers(),
            scn.n_workers()
        );
        let mut acc = Welford::new();
        for rec in coord.metrics.records() {
            acc.push(rec.injected_s / time_scale);
        }
        Ok(acc)
    };
    let outcome = run();
    coord.shutdown();
    let live = outcome.map_err(|e| anyhow::anyhow!("live-des-fault cell failed on {ctx}: {e}"))?;
    let live_est =
        Estimate { mean: live.mean(), sem: live.sem(), lo: live.mean(), hi: live.mean() };
    check_cell(Pair::LiveDesFault, &des_est, &live_est, opts.z, opts.live_floor, &ctx, report)
}

/// The live↔DES corruption cell: one shared silent-corruption
/// [`FaultPlan`] — worker 0 perturbs every result from round 1 on —
/// drives both the live coordinator's m-of-g vote (`verify_m = 2`) and
/// the corruption-aware DES fault model over the same round horizon.
/// Quarantine is disarmed on both sides (`verify_strikes = u64::MAX`):
/// flag *timing* is arrival-order-dependent on the live side, so with
/// strikes armed the two liveness trajectories could diverge; with
/// strikes disarmed both sides accept every batch at its second honest
/// replica — an identical, stationary completion law the z-test can
/// compare. The live leg must still observe the injection (corrupted
/// total ≥ 1); detection bookkeeping itself is pinned by the
/// coordinator and engine unit tests.
fn check_corrupt_cell(
    case: &GeneratedCase,
    opts: &MatrixOptions,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    use crate::fault::{FaultEvent, FaultPlan};
    let scn = case
        .scenario
        .clone()
        .with_verify_m(2)
        // lint:allow(D4): corrupt_applies pre-filters for replication degree >= 3
        .expect("corrupt_applies guarantees replication degree >= 3");
    let ctx = describe(case);
    let rounds = opts.live_rounds.max(12);
    let plan = FaultPlan {
        name: "conformance-corrupt".into(),
        seed: scn.seed ^ 0x00C0_2207,
        events: vec![(0, FaultEvent::Corruption { from_round: 1, prob: 1.0 })],
    };

    // DES leg: replicates of the identical corruption schedule.
    let compiled = plan.compile(scn.n_workers())?;
    let eng_cfg = EngineConfig { verify_strikes: u64::MAX, ..EngineConfig::default() };
    let trials = (opts.des_trials / rounds.max(1)).clamp(40, 400);
    let mut des = Welford::new();
    let mut corrupted = 0u64;
    let mut rng = crate::util::rng::Rng::new(scn.seed ^ 0x00DE_5EED ^ 0xC022);
    for _ in 0..trials {
        let stats = crate::des::engine::simulate_fault_rounds(
            &scn, &compiled, rounds, &eng_cfg, &mut rng,
        )?;
        for st in stats {
            des.push(st.completion);
            corrupted += st.corrupted;
        }
    }
    anyhow::ensure!(
        corrupted >= 1,
        "the corruption plan never fired on the DES side ({ctx})"
    );
    let des_est = Estimate { mean: des.mean(), sem: des.sem(), lo: des.mean(), hi: des.mean() };

    // Live leg: the real coordinator votes the corrupt replica out of
    // every aggregate while the round still completes.
    let time_scale = (0.004 / des.mean().max(1e-6)).clamp(0.000_8, 0.02);
    let cfg = SystemConfig {
        time_scale,
        n_samples: 32.max(scn.n_workers()),
        dim: 4,
        cancellation: true,
        verify_strikes: u64::MAX,
        ..SystemConfig::default()
    };
    let scn_live = scn.clone().with_seed(scn.seed ^ 0x11FE_5EED ^ 0xC022);
    let mut coord = Coordinator::from_scenario(&scn_live, cfg, Backend::Mock)?;
    coord.install_fault_plan(&plan)?;
    let w = Arc::new(vec![0.0f32; 4]);
    let mut run = || -> anyhow::Result<Welford> {
        for _ in 0..rounds {
            coord.run_round(JobSpec::Grad { w: w.clone() })?;
        }
        let totals = coord.metrics.fault_totals();
        anyhow::ensure!(
            totals.corrupted >= 1,
            "the corruption plan did not fire on the live side (totals {totals:?})"
        );
        anyhow::ensure!(
            totals.quarantined == 0,
            "quarantine fired with verify_strikes disarmed (totals {totals:?})"
        );
        let mut acc = Welford::new();
        for rec in coord.metrics.records() {
            acc.push(rec.injected_s / time_scale);
        }
        Ok(acc)
    };
    let outcome = run();
    coord.shutdown();
    let live =
        outcome.map_err(|e| anyhow::anyhow!("live-des-corrupt cell failed on {ctx}: {e}"))?;
    let live_est =
        Estimate { mean: live.mean(), sem: live.sem(), lo: live.mean(), hi: live.mean() };
    check_cell(Pair::LiveDesCorrupt, &des_est, &live_est, opts.z, opts.live_floor, &ctx, report)
}

/// The live-crash cell: run a few warm-up rounds with the full cluster,
/// kill one worker thread halfway through its straggle, then check that
/// (a) the crash round and every later round still complete, and
/// (b) the survivors' mean injected completion matches
/// [`analysis::assignment_stats`] on the assignment with the dead
/// worker's replica removed (survivor indices reindexed).
fn check_crash_cell(
    case: &GeneratedCase,
    opts: &MatrixOptions,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    let scn = &case.scenario;
    let ctx = describe(case);
    let n_units = scn.layout.n_units as u64;

    // Scale wall time off the full-cluster closed form, exactly like
    // the DES↔Live cells scale off the DES mean.
    let full = analysis::assignment_stats(&scn.assignment, &scn.service.spec, n_units)?;
    let time_scale = (0.004 / full.mean.max(1e-6)).clamp(0.000_8, 0.02);
    let cfg = SystemConfig {
        time_scale,
        n_samples: 32.max(scn.n_workers()),
        dim: 4,
        cancellation: true,
        ..SystemConfig::default()
    };
    let scn_run = scn.clone().with_seed(scn.seed ^ 0xC4A5_11ED);
    let mut coord = Coordinator::from_scenario(&scn_run, cfg, Backend::Mock)?;
    let w = Arc::new(vec![0.0f32; 4]);
    let pre = 3u64;
    let victim = 0usize;
    let mut run = || -> anyhow::Result<Welford> {
        for _ in 0..pre {
            coord.run_round(JobSpec::Grad { w: w.clone() })?;
        }
        coord.crash_worker_next_round(victim, 0.5)?;
        coord
            .run_round(JobSpec::Grad { w: w.clone() })
            .map_err(|e| anyhow::anyhow!("crash round did not complete: {e}"))?;
        anyhow::ensure!(
            coord.live_workers() == scn.n_workers() - 1,
            "expected exactly one dead worker"
        );
        for _ in 0..opts.live_rounds {
            coord.run_round(JobSpec::Grad { w: w.clone() })?;
        }
        // Post-crash rounds only: skip the warm-up and the crash round
        // itself (its completion law is a mixture).
        let mut post = Welford::new();
        for rec in coord.metrics.records().iter().skip(pre as usize + 1) {
            post.push(rec.injected_s / time_scale);
        }
        Ok(post)
    };
    let outcome = run();
    coord.shutdown();
    let post = outcome.map_err(|e| anyhow::anyhow!("live-crash cell failed on {ctx}: {e}"))?;

    // Reduced assignment: drop the victim, reindex the survivors.
    let bow: Vec<usize> = scn
        .assignment
        .batch_of_worker
        .iter()
        .enumerate()
        .filter(|&(wk, _)| wk != victim)
        .map(|(_, &b)| b)
        .collect();
    let mut workers_of_batch = vec![Vec::new(); scn.assignment.n_batches];
    for (wk, &b) in bow.iter().enumerate() {
        workers_of_batch[b].push(wk);
    }
    let reduced = Assignment {
        n_workers: scn.n_workers() - 1,
        n_batches: scn.assignment.n_batches,
        workers_of_batch,
        batch_of_worker: bow,
    };
    reduced.validate()?;
    let want = analysis::assignment_stats(&reduced, &scn.service.spec, n_units)?;
    let an = Estimate { mean: want.mean, sem: 0.0, lo: want.mean, hi: want.mean };
    let live =
        Estimate { mean: post.mean(), sem: post.sem(), lo: post.mean(), hi: post.mean() };
    check_cell(Pair::LiveCrash, &an, &live, opts.z, opts.live_floor, &ctx, report)
}

/// The analytic leg as an [`Estimate`]: a zero-width point when exact,
/// the provable bound interval under heterogeneous SExp speeds (also
/// cross-validating that the evaluator reports the interval midpoint).
fn analytic_estimate(scn: &Scenario) -> anyhow::Result<Estimate> {
    let st = AnalyticEvaluator.evaluate(scn)?;
    if let Some(speeds) = &scn.worker_speeds {
        let bounds = analysis::hetero_completion_bounds(
            &scn.assignment,
            &scn.service.spec,
            scn.layout.n_units as u64,
            speeds,
        )?;
        anyhow::ensure!(
            (st.mean - bounds.mid_mean()).abs() <= 1e-9 * bounds.mid_mean().abs().max(1.0),
            "AnalyticEvaluator mean {} drifted from the bound midpoint {}",
            st.mean,
            bounds.mid_mean()
        );
        Ok(Estimate { mean: st.mean, sem: 0.0, lo: bounds.lower.mean, hi: bounds.upper.mean })
    } else {
        Ok(point(&st))
    }
}

/// Check one cell: the distance between the two estimates' intervals
/// must not exceed the z-scaled combined standard error (plus the small
/// relative floor). Tallies the cell, then errors on disagreement.
fn check_cell(
    pair: Pair,
    a: &Estimate,
    b: &Estimate,
    z: f64,
    rel_floor: f64,
    context: &str,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    let gap = (a.lo.max(b.lo) - a.hi.min(b.hi)).max(0.0);
    let scale = a.mean.abs().max(b.mean.abs()).max(1e-12);
    let tol = z * (a.sem * a.sem + b.sem * b.sem).sqrt() + rel_floor * scale;
    {
        let mut r = report.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        r.cells += 1;
        match pair {
            Pair::AnalyticMc => r.analytic_mc += 1,
            Pair::AnalyticDes => r.analytic_des += 1,
            Pair::McDes => r.mc_des += 1,
            Pair::DesReference => r.des_reference += 1,
            Pair::DesLive => r.des_live += 1,
            Pair::LiveCrash => r.live_crash += 1,
            Pair::LiveDesFault => r.live_des_fault += 1,
            Pair::LiveDesCorrupt => r.live_des_corrupt += 1,
        }
        let ratio = gap / tol.max(1e-300);
        if ratio > r.worst_gap_over_tol {
            r.worst_gap_over_tol = ratio;
        }
    }
    anyhow::ensure!(
        gap <= tol,
        "conformance cell {} disagrees on E[T]: {:.6} (sem {:.3e}, interval [{:.6}, \
         {:.6}]) vs {:.6} (sem {:.3e}, interval [{:.6}, {:.6}]) — gap {:.6} > tol {:.6} \
         (z = {z}, floor {rel_floor})\n  scenario: {context}",
        pair.name(),
        a.mean,
        a.sem,
        a.lo,
        a.hi,
        b.mean,
        b.sem,
        b.lo,
        b.hi,
        gap,
        tol
    );
    Ok(())
}

/// Run every applicable backend-pair cell of one case. Backends draw
/// from distinct derived seeds, so each leg of a z-test is an
/// independent estimate.
fn check_case(
    case: &GeneratedCase,
    opts: &MatrixOptions,
    report: &Mutex<MatrixReport>,
) -> anyhow::Result<()> {
    let scn = &case.scenario;
    let ctx = describe(case);
    report.lock().unwrap_or_else(std::sync::PoisonError::into_inner).scenarios += 1;

    // --- DES (fast engine), the one backend every cell shares. ---
    let des_scn = scn.clone().with_seed(scn.seed ^ 0x00DE_5EED);
    let des_ev = DesEvaluator {
        trials: opts.des_trials,
        threads: opts.threads,
        cancellation: true,
        fail_prob: case.fail_prob,
        relaunch_timeout_factor: 3.0,
    };
    let des = des_ev
        .evaluate(&des_scn)
        .map_err(|e| anyhow::anyhow!("des backend refused {ctx}: {e}"))?;
    let des_est = point(&des);

    // --- DES ↔ reference engine: two independent implementations, the
    // only pair that reaches speculative redundancy and failure
    // injection. ---
    let eng_cfg = EngineConfig {
        cancellation: true,
        redundancy: scn.redundancy,
        fail_prob: case.fail_prob,
        relaunch_timeout_factor: 3.0,
        ..EngineConfig::default()
    };
    let refr = simulate_many_reference(
        scn,
        &eng_cfg,
        opts.des_trials,
        scn.seed ^ 0x5EED_0000_0001,
    );
    let ref_est = Estimate {
        mean: refr.completion.mean(),
        sem: refr.completion.sem(),
        lo: refr.completion.mean(),
        hi: refr.completion.mean(),
    };
    check_cell(Pair::DesReference, &des_est, &ref_est, opts.z, opts.rel_floor, &ctx, report)?;

    if scn.redundancy == Redundancy::Upfront && case.fail_prob == 0.0 {
        // --- Monte-Carlo ↔ DES: every upfront reliable scenario. ---
        let mc_ev = MonteCarloEvaluator { trials: opts.mc_trials, threads: opts.threads };
        let mc = mc_ev
            .evaluate(scn)
            .map_err(|e| anyhow::anyhow!("montecarlo backend refused {ctx}: {e}"))?;
        let mc_est = point(&mc);
        check_cell(Pair::McDes, &mc_est, &des_est, opts.z, opts.rel_floor, &ctx, report)?;

        // --- Analytic ↔ {MC, DES}: wherever a closed form exists. ---
        if analytic_applies(scn) {
            let an = analytic_estimate(scn)
                .map_err(|e| anyhow::anyhow!("analytic backend refused {ctx}: {e}"))?;
            check_cell(Pair::AnalyticMc, &an, &mc_est, opts.z, opts.rel_floor, &ctx, report)?;
            check_cell(Pair::AnalyticDes, &an, &des_est, opts.z, opts.rel_floor, &ctx, report)?;
            if scn.worker_speeds.is_some() {
                let mut r = report.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                r.hetero_analytic_cells += 2;
            }
            if scn.verify_m.is_some() {
                let mut r = report.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                r.verify_m_analytic_cells += 2;
            }
        }

        // --- DES ↔ Live: the real coordinator with injected time. ---
        if opts.include_live && case.live && live_applies(scn, case.fail_prob) {
            // Normalize wall time per round to a few ms: large enough
            // that injected-delay gaps dominate scheduler noise, small
            // enough that a cell stays well under a second.
            let time_scale = (0.004 / des.mean.max(1e-6)).clamp(0.000_8, 0.02);
            let live_ev = LiveEvaluator {
                rounds: opts.live_rounds,
                time_scale,
                n_samples: 32,
                dim: 4,
                ..LiveEvaluator::default()
            };
            let live_scn = scn.clone().with_seed(scn.seed ^ 0x11FE_5EED);
            let live = live_ev
                .evaluate(&live_scn)
                .map_err(|e| anyhow::anyhow!("live backend refused {ctx}: {e}"))?;
            check_cell(
                Pair::DesLive,
                &des_est,
                &point(&live),
                opts.z,
                opts.live_floor,
                &ctx,
                report,
            )?;
            if matches!(scn.k_of_b, Some(k) if k < scn.assignment.n_batches) {
                let mut r = report.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                r.live_k_of_b_cells += 1;
            }
        }

        // --- Live-crash ↔ Analytic: a worker dies mid-round. ---
        if opts.include_live && case.crash && crash_applies(scn, case.fail_prob) {
            check_crash_cell(case, opts, report)?;
        }

        // --- Live ↔ DES under one shared fault plan: the self-healing
        // pipeline vs the DES fault model. ---
        if opts.include_live && case.fault && fault_applies(scn, case.fail_prob) {
            check_fault_cell(case, opts, report)?;
        }

        // --- Live ↔ DES under one shared corruption plan: the m-of-g
        // vote vs the corruption-aware DES fault model. ---
        if opts.include_live && case.corrupt && corrupt_applies(scn, case.fail_prob) {
            check_corrupt_cell(case, opts, report)?;
        }
    }
    Ok(())
}

/// Deterministic anchor cases: the corners the acceptance criteria name
/// (heterogeneous-speed analytic cells, live k-of-B, the k = 1 extreme,
/// speculative and failure-injected engine pairs, an overlapping
/// layout, a heavy-tail spec). They run before the random sweep on
/// every invocation, so the required coverage never depends on the
/// random draw.
///
/// The anchors are **enumerated through the study planner**: each
/// corner block is a small [`StudySpec`] grid whose compiled
/// `ExecutionPlan::scenarios` supply the cases, so the conformance
/// matrix and the study layer share one grid vocabulary (axes,
/// canonicalization, derived seeds). Only failure injection stays a
/// per-case knob — it is an engine parameter, not a scenario field.
/// (The old k = B anchor is gone by design: on disjoint layouts the
/// planner canonicalizes `k = B` onto the full-completion cell, and
/// that equivalence is pinned by the evaluator unit tests.)
fn anchor_cases() -> Vec<GeneratedCase> {
    use crate::study::{BatchAxis, KTarget, RedundancyAxis, SpeedAxis, StudySpec};
    let paper =
        |mu: f64, delta: f64| BatchService::paper(ServiceSpec::shifted_exp(mu, delta));
    let grid = |spec: StudySpec| -> Vec<Scenario> {
        // lint:allow(D4): the anchor grids are fixed in-source specs, compile-checked by the matrix tests
        spec.compile().expect("anchor grids are valid by construction").scenarios
    };
    let mut cases: Vec<GeneratedCase> = Vec::new();
    let mut push = |scenarios: Vec<Scenario>, fail_prob: f64, live: bool, crash: bool| {
        for scenario in scenarios {
            cases.push(GeneratedCase {
                scenario,
                fail_prob,
                live,
                crash,
                fault: false,
                corrupt: false,
            });
        }
    };

    // Heterogeneous-speed analytic corners: exact Exp cells and bounded
    // SExp cells across two cluster shapes (8 scenarios).
    push(
        grid(StudySpec {
            n_workers: vec![12, 8],
            batches: BatchAxis::Explicit(vec![2, 4]),
            services: vec![BatchService::paper(ServiceSpec::exp(1.3)), paper(1.0, 0.5)],
            speeds: vec![SpeedAxis::Ramp { lo: 0.6, hi: 1.8 }],
            seed: 9001,
            ..StudySpec::base("conformance-anchor-hetero")
        }),
        0.0,
        false,
        false,
    );
    // Live corners: k-of-B (round completes at the k-th finished batch)
    // and plain full completion on the same small cluster.
    push(
        grid(StudySpec {
            n_workers: vec![6],
            batches: BatchAxis::Explicit(vec![3]),
            services: vec![paper(2.0, 0.1)],
            k_targets: vec![KTarget::Exact(2), KTarget::Full],
            seed: 9002,
            ..StudySpec::base("conformance-anchor-live")
        }),
        0.0,
        true,
        false,
    );
    // Live heterogeneous.
    push(
        grid(StudySpec {
            n_workers: vec![6],
            batches: BatchAxis::Explicit(vec![2]),
            services: vec![paper(2.0, 0.05)],
            speeds: vec![SpeedAxis::Ramp { lo: 0.6, hi: 1.8 }],
            seed: 9003,
            ..StudySpec::base("conformance-anchor-live-hetero")
        }),
        0.0,
        true,
        false,
    );
    // k = 1 extreme.
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![BatchService::paper(ServiceSpec::exp(1.0))],
            k_targets: vec![KTarget::Exact(1)],
            seed: 9004,
            ..StudySpec::base("conformance-anchor-k1")
        }),
        0.0,
        false,
        false,
    );
    // Speculative redundancy (engine-pair cells only).
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![3]),
            services: vec![paper(1.0, 0.2)],
            redundancy: vec![RedundancyAxis::Speculative(1.5)],
            seed: 9005,
            ..StudySpec::base("conformance-anchor-speculative")
        }),
        0.0,
        false,
        false,
    );
    // Failure injection: same grid shape, the fail knob rides per case.
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![3]),
            services: vec![paper(1.0, 0.2)],
            seed: 9006,
            ..StudySpec::base("conformance-anchor-fail")
        }),
        0.3,
        false,
        false,
    );
    // Overlapping layout (MC↔DES + engine pair only).
    push(
        grid(StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![4]),
            policies: vec![ReplicationPolicy::OverlappingCyclic],
            services: vec![paper(1.0, 0.2)],
            seed: 9007,
            ..StudySpec::base("conformance-anchor-overlapping")
        }),
        0.0,
        false,
        false,
    );
    // Heavy-tail spec outside the closed forms' scope.
    push(
        grid(StudySpec {
            n_workers: vec![8],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![BatchService::paper(ServiceSpec::pareto(0.8, 3.5))],
            seed: 9008,
            ..StudySpec::base("conformance-anchor-pareto")
        }),
        0.0,
        false,
        false,
    );
    // m-of-g verification: the verify knob rides the planner grid, so
    // the verified closed form meets MC and DES on planner-derived
    // seeds (m = 2 over g = 3, with and without a k-of-B target).
    push(
        grid(StudySpec {
            n_workers: vec![12],
            batches: BatchAxis::Explicit(vec![4]),
            services: vec![paper(1.0, 0.2)],
            k_targets: vec![KTarget::Full, KTarget::Exact(3)],
            verify_m: 2,
            seed: 9011,
            ..StudySpec::base("conformance-anchor-verify")
        }),
        0.0,
        false,
        false,
    );
    // Live crash: a worker thread dies mid-round (g = 3, so every batch
    // survives), survivors checked against the reduced closed form.
    push(
        grid(StudySpec {
            n_workers: vec![6],
            batches: BatchAxis::Explicit(vec![2]),
            services: vec![paper(2.0, 0.1)],
            seed: 9009,
            ..StudySpec::base("conformance-anchor-crash")
        }),
        0.0,
        false,
        true,
    );
    // Live↔DES fault conformance: one shared FaultPlan (transient crash
    // with backoff respawn + Markov slowdown) on both backends; g = 3,
    // so the crash never costs coverage.
    for scenario in grid(StudySpec {
        n_workers: vec![6],
        batches: BatchAxis::Explicit(vec![2]),
        services: vec![paper(1.0, 0.25)],
        seed: 9010,
        ..StudySpec::base("conformance-anchor-fault")
    }) {
        cases.push(GeneratedCase {
            scenario,
            fail_prob: 0.0,
            live: false,
            crash: false,
            fault: true,
            corrupt: false,
        });
    }
    // Live↔DES corruption conformance: one shared silent-corruption
    // plan, voted out by m = 2 verification on both backends; g = 3,
    // so every batch keeps two honest agreeing votes.
    for scenario in grid(StudySpec {
        n_workers: vec![6],
        batches: BatchAxis::Explicit(vec![2]),
        services: vec![paper(1.0, 0.25)],
        seed: 9012,
        ..StudySpec::base("conformance-anchor-corrupt")
    }) {
        cases.push(GeneratedCase {
            scenario,
            fail_prob: 0.0,
            live: false,
            crash: false,
            fault: false,
            corrupt: true,
        });
    }
    cases
}

/// Run the full conformance matrix: the deterministic anchors first,
/// then `opts.scenarios` generated scenarios through every applicable
/// backend pair. Returns the tally on success; on any disagreement the
/// error carries the shrunk minimal case and its replay seed.
pub fn run_matrix(opts: &MatrixOptions) -> anyhow::Result<MatrixReport> {
    let report = Mutex::new(MatrixReport::default());
    // Adversarial corpus first: every shrunk case a past sweep recorded
    // replays before anything else, so a regression on a previously
    // found bug fails in seconds, deterministically.
    if let Some(path) = &opts.corpus {
        for case in load_corpus(path)? {
            check_case(&case, opts, &report).map_err(|e| {
                anyhow::anyhow!(
                    "conformance corpus case failed (recorded in {}):\n  case: {}\n{e:#}",
                    path.display(),
                    describe(&case)
                )
            })?;
            report.lock().unwrap_or_else(std::sync::PoisonError::into_inner).corpus_replayed += 1;
        }
    }
    for case in anchor_cases() {
        check_case(&case, opts, &report).map_err(|e| {
            anyhow::anyhow!(
                "conformance anchor failed (anchors are deterministic; rerun \
                 `batchrep conformance` with the same trial counts to reproduce):\n{e:#}"
            )
        })?;
    }
    // After the first failure every further property call comes from
    // the shrinker's candidate replays; run those at a reduced budget
    // so minimization costs seconds rather than re-paying the full
    // matrix per candidate. Standard errors grow only ~√8, so a
    // systematic disagreement still fails and shrinks; the printed
    // replay seed reproduces at full budget. Live cells are dropped
    // from the replays *unless the failing cell was itself a live
    // pair* — otherwise DES↔Live failures could never reproduce while
    // shrinking (they keep reduced rounds instead).
    const NOT_FAILED: u8 = 0;
    const FAILED: u8 = 1;
    const FAILED_LIVE: u8 = 2;
    let state = std::sync::atomic::AtomicU8::new(NOT_FAILED);
    let shrink_base = MatrixOptions {
        mc_trials: (opts.mc_trials / 8).max(1_000),
        des_trials: (opts.des_trials / 8).max(500),
        ..opts.clone()
    };
    let shrink_nolive = MatrixOptions { include_live: false, ..shrink_base.clone() };
    let shrink_live =
        MatrixOptions { live_rounds: (opts.live_rounds / 2).max(20), ..shrink_base };
    // The last case the checker rejected — by the time the shrinker
    // stops, this is the minimal failing case it reports, and the one
    // worth recording in the corpus.
    let last_failed: Mutex<Option<GeneratedCase>> = Mutex::new(None);
    let sweep = catch_unwind(AssertUnwindSafe(|| {
        testkit::check_with("conformance-matrix", opts.scenarios, opts.seed, |g| {
            let case = gen_case(g);
            let o = match state.load(std::sync::atomic::Ordering::Relaxed) {
                FAILED => &shrink_nolive,
                FAILED_LIVE => &shrink_live,
                _ => opts,
            };
            if let Err(e) = check_case(&case, o, &report) {
                let text = format!("{e:#}");
                let mode = if text.contains(Pair::DesLive.name())
                    || text.contains(Pair::LiveDesFault.name())
                    || text.contains(Pair::LiveDesCorrupt.name())
                {
                    FAILED_LIVE
                } else {
                    FAILED
                };
                state.store(mode, std::sync::atomic::Ordering::Relaxed);
                *last_failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = Some(case);
                panic!("{text}"); // lint:allow(D4): the testkit shrinker protocol propagates failures by panic
            }
        })
    }));
    if let Err(payload) = sweep {
        let mut note = String::new();
        if let Some(path) = &opts.corpus {
            let taken =
                last_failed.lock().unwrap_or_else(std::sync::PoisonError::into_inner).take();
            if let Some(case) = taken {
                note = match append_to_corpus(path, &case) {
                    Ok(()) => format!(
                        "\n  shrunk case appended to {} — it will replay first on every \
                         future run",
                        path.display()
                    ),
                    Err(e) => format!("\n  (failed to record the case in the corpus: {e})"),
                };
            }
        }
        anyhow::bail!(
            "conformance matrix failed:{note}\n{}",
            testkit::payload_msg(&*payload)
        );
    }
    Ok(report.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_generated_cases_are_valid_scenarios() {
        testkit::check("conformance-gen-valid", 200, |g| {
            let case = gen_case(g);
            let scn = &case.scenario;
            scn.layout.validate().unwrap();
            scn.assignment.validate().unwrap();
            assert_eq!(scn.layout.n_batches(), scn.assignment.n_batches);
            if let Some(k) = scn.k_of_b {
                assert!(k >= 1 && k <= scn.assignment.n_batches);
            }
            if let Some(speeds) = &scn.worker_speeds {
                assert_eq!(speeds.len(), scn.n_workers());
                assert!(speeds.iter().all(|&c| c > 0.0));
            }
            assert!((0.0..=0.4).contains(&case.fail_prob));
            if let Some(m) = scn.verify_m {
                // Verified cases stay inside the scope every backend
                // accepts: reliable, upfront, m votes seatable on every
                // batch, and no live-side cells.
                assert!(m >= 2);
                assert_eq!(case.fail_prob, 0.0);
                assert_eq!(scn.redundancy, Redundancy::Upfront);
                assert!(!case.live && !case.crash && !case.fault && !case.corrupt);
                let min_degree = (0..scn.assignment.n_batches)
                    .map(|b| scn.assignment.replication(b))
                    .min()
                    .unwrap();
                assert!(m <= min_degree);
            }
        });
    }

    #[test]
    fn prop_applicability_matches_the_evaluator() {
        // The matrix's applicability predicate and the evaluator's own
        // acceptance logic must be the same function, or cells silently
        // vanish (predicate too narrow) or spuriously error (too wide).
        testkit::check("conformance-analytic-scope", 120, |g| {
            let case = gen_case(g);
            let accepted = AnalyticEvaluator.evaluate(&case.scenario).is_ok();
            assert_eq!(
                analytic_applies(&case.scenario),
                accepted,
                "predicate disagrees with evaluator on {}",
                describe(&case)
            );
        });
    }

    #[test]
    fn anchors_cover_the_required_corners() {
        // The StudySpec-enumerated anchor grids must still reach every
        // corner the acceptance criteria name, independent of the
        // random sweep.
        let anchors = anchor_cases();
        let hetero = anchors
            .iter()
            .filter(|c| c.scenario.worker_speeds.is_some() && !c.live)
            .count();
        assert!(hetero >= 4, "hetero anchors: {hetero}");
        assert!(
            anchors.iter().any(|c| {
                let b = c.scenario.assignment.n_batches;
                c.live && matches!(c.scenario.k_of_b, Some(k) if k < b)
            }),
            "live k-of-B anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.live && c.scenario.worker_speeds.is_some()),
            "live hetero anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.scenario.k_of_b == Some(1)),
            "k = 1 anchor missing"
        );
        assert!(
            anchors
                .iter()
                .any(|c| matches!(c.scenario.redundancy, Redundancy::Speculative { .. })),
            "speculative anchor missing"
        );
        assert!(anchors.iter().any(|c| c.fail_prob > 0.0), "fail-injected anchor missing");
        assert!(
            anchors.iter().any(|c| c.scenario.layout.is_overlapping),
            "overlapping anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.scenario.service.spec.exp_family().is_none()),
            "heavy-tail anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.crash && c.scenario.assignment.replication(0) >= 2),
            "live-crash anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.fault
                && c.scenario.assignment.replication(0) >= 2
                && fault_applies(&c.scenario, c.fail_prob)),
            "live-des-fault anchor missing or out of the fault cell's scope"
        );
        assert!(
            anchors
                .iter()
                .any(|c| c.scenario.verify_m == Some(2) && analytic_applies(&c.scenario)),
            "verified-analytic anchor missing or out of the closed form's scope"
        );
        assert!(
            anchors.iter().any(|c| c.scenario.verify_m.is_some()
                && matches!(c.scenario.k_of_b, Some(k) if k < c.scenario.assignment.n_batches)),
            "verified k-of-B anchor missing"
        );
        assert!(
            anchors.iter().any(|c| c.corrupt && corrupt_applies(&c.scenario, c.fail_prob)),
            "live-des-corrupt anchor missing or out of the corruption cell's scope"
        );
        // Every anchor is a valid scenario with a planner-derived seed.
        for c in &anchors {
            c.scenario.layout.validate().unwrap();
            c.scenario.assignment.validate().unwrap();
        }
    }

    #[test]
    fn corpus_round_trips_and_dedupes() {
        // Serialization is the regression record: a case must survive
        // JSON → case → JSON bit-identically, and appending the same
        // case twice must not grow the file.
        let scn = Scenario::from_policy(
            ReplicationPolicy::BalancedDisjoint,
            8,
            4,
            BatchService::paper(ServiceSpec::shifted_exp(1.5, 0.25)),
            42,
        )
        .unwrap()
        .with_redundancy(Redundancy::Speculative { deadline_factor: 1.25 })
        .with_k_of_b(3)
        .unwrap()
        .with_speeds(vec![0.5, 1.0, 1.5, 2.0, 0.5, 1.0, 1.5, 2.0])
        .unwrap();
        let case = GeneratedCase {
            scenario: scn,
            fail_prob: 0.125,
            live: true,
            crash: false,
            fault: false,
            corrupt: false,
        };
        let round = case_from_json(&case_to_json(&case)).unwrap();
        assert_eq!(case_to_json(&round).to_string(), case_to_json(&case).to_string());
        assert_eq!(describe(&round), describe(&case));

        let dir = std::env::temp_dir().join(format!("batchrep-corpus-{}", std::process::id()));
        let path = dir.join("corpus.json");
        let _ = std::fs::remove_file(&path);
        assert!(load_corpus(&path).unwrap().is_empty(), "missing file = empty corpus");
        append_to_corpus(&path, &case).unwrap();
        append_to_corpus(&path, &case).unwrap();
        assert_eq!(load_corpus(&path).unwrap().len(), 1, "dedup by serialized form");
        let other = GeneratedCase {
            scenario: Scenario::from_policy(
                ReplicationPolicy::BalancedDisjoint,
                6,
                2,
                BatchService::paper(ServiceSpec::exp(2.0)),
                9009,
            )
            .unwrap()
            .with_verify_m(2)
            .unwrap(),
            fail_prob: 0.0,
            live: false,
            crash: true,
            fault: true,
            corrupt: true,
        };
        append_to_corpus(&path, &other).unwrap();
        let loaded = load_corpus(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert!(loaded.iter().any(|c| c.crash), "crash flag survives the file");
        assert!(loaded.iter().any(|c| c.fault), "fault flag survives the file");
        assert!(loaded.iter().any(|c| c.corrupt), "corrupt flag survives the file");
        assert!(
            loaded.iter().any(|c| c.scenario.verify_m == Some(2)),
            "verify_m survives the file"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn long_mode_extends_the_full_sweep() {
        let fast = MatrixOptions::fast();
        let full = MatrixOptions::full();
        let long = MatrixOptions::long();
        assert!(long.scenarios > full.scenarios && full.scenarios > fast.scenarios);
        assert!(long.mc_trials >= full.mc_trials && long.des_trials >= full.des_trials);
        assert!(long.include_live, "soak mode keeps the live cells");
    }

    #[test]
    fn cell_interval_logic() {
        let report = Mutex::new(MatrixReport::default());
        let exact = Estimate { mean: 1.0, sem: 0.0, lo: 1.0, hi: 1.0 };
        let close = Estimate { mean: 1.01, sem: 0.004, lo: 1.01, hi: 1.01 };
        check_cell(Pair::AnalyticMc, &exact, &close, 5.0, 0.004, "t", &report).unwrap();
        // Far beyond 5σ + floor: must fail.
        let far = Estimate { mean: 1.2, sem: 0.004, lo: 1.2, hi: 1.2 };
        assert!(check_cell(Pair::AnalyticMc, &exact, &far, 5.0, 0.004, "t", &report).is_err());
        // An interval that contains the point passes with zero gap even
        // at sem = 0.
        let bound = Estimate { mean: 1.1, sem: 0.0, lo: 0.9, hi: 1.3 };
        check_cell(Pair::AnalyticDes, &bound, &exact, 5.0, 0.0, "t", &report).unwrap();
        let r = report.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        assert_eq!(r.cells, 3);
        assert_eq!(r.analytic_mc, 2);
        assert!(r.worst_gap_over_tol > 1.0, "the failing cell must dominate the ratio");
    }

    #[test]
    fn small_matrix_passes_and_counts_required_cells() {
        // A scaled-down sweep (no live cells — those are exercised by
        // the integration tests and the CLI gate): every applicable
        // pair must appear and agree.
        let opts = MatrixOptions {
            scenarios: 15,
            mc_trials: 6_000,
            des_trials: 3_000,
            live_rounds: 1,
            threads: 2,
            include_live: false,
            seed: Some(7),
            z: 5.5,
            rel_floor: 0.01,
            live_floor: 0.2,
            corpus: None,
        };
        let report = run_matrix(&opts).unwrap();
        assert_eq!(
            report.scenarios,
            15 + anchor_cases().len() as u64,
            "15 random + the StudySpec-enumerated anchors"
        );
        assert!(report.des_reference >= report.scenarios, "engine pair runs everywhere");
        assert!(report.analytic_mc >= 3, "{report:?}");
        assert!(report.analytic_des >= 3, "{report:?}");
        assert!(report.mc_des >= 8, "{report:?}");
        assert!(report.hetero_analytic_cells >= 4, "{report:?}");
        assert!(
            report.verify_m_analytic_cells >= 4,
            "the verify anchor alone contributes two scenarios x two cells: {report:?}"
        );
        assert_eq!(report.des_live, 0, "live disabled");
        assert_eq!(report.live_des_corrupt, 0, "live disabled");
        assert!(report.worst_gap_over_tol <= 1.0, "{report:?}");
        assert!(
            report.cells
                >= report.analytic_mc
                    + report.analytic_des
                    + report.mc_des
                    + report.des_reference
        );
    }
}
